"""CI perf-regression gate for the cluster benchmark.

Compares a freshly produced ``BENCH_cluster.json`` against the committed
baseline (``benchmarks/baselines/BENCH_cluster.json``) inside a tolerance
band and exits non-zero on regression, so the ``bench-smoke`` job *fails*
instead of merely uploading an artifact:

- ``speedup_vs_sync`` (async-vs-sync at equal gradient evaluations) may not
  fall more than ``--tol-speedup`` below the baseline, and must stay > 1;
- W2-at-budget (``final_w2_async``, the chain cloud's empirical W2 against
  the Gibbs posterior after the full commit budget) may not rise more than
  ``--tol-w2`` above the baseline.

Both runs are seeded, so the bands only absorb cross-platform float noise —
keep them tight.  To accept an intentional change, re-run the benchmark and
commit the new JSON as the baseline.

    python scripts/check_bench.py BENCH_cluster.json \
        --baseline benchmarks/baselines/BENCH_cluster.json
"""

from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, *, tol_speedup: float,
          tol_w2: float) -> list[str]:
    """Returns a list of human-readable regression messages (empty = pass)."""
    failures = []
    sp, sp0 = current["speedup_vs_sync"], baseline["speedup_vs_sync"]
    floor = sp0 * (1.0 - tol_speedup)
    if sp <= 1.0:
        failures.append(f"async-vs-sync speedup {sp:.3f} does not exceed 1")
    elif sp < floor:
        failures.append(
            f"async-vs-sync speedup regressed: {sp:.3f} < {floor:.3f} "
            f"(baseline {sp0:.3f}, tolerance {tol_speedup:.0%})")
    w2, w20 = current["final_w2_async"], baseline["final_w2_async"]
    ceil = w20 * (1.0 + tol_w2)
    if w2 > ceil:
        failures.append(
            f"W2-at-budget regressed: {w2:.4f} > {ceil:.4f} "
            f"(baseline {w20:.4f}, tolerance {tol_w2:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="fresh BENCH_cluster.json to validate")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_cluster.json")
    ap.add_argument("--tol-speedup", type=float, default=0.20,
                    help="allowed fractional speedup drop (default 0.20)")
    ap.add_argument("--tol-w2", type=float, default=0.50,
                    help="allowed fractional W2 increase (default 0.50)")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    cfg, cfg0 = current.get("config", {}), baseline.get("config", {})
    if cfg != cfg0:
        diff = {k for k in set(cfg) | set(cfg0) if cfg.get(k) != cfg0.get(k)}
        print(f"check_bench: config drift vs baseline in {sorted(diff)} — "
              "comparing anyway; recommit the baseline if intentional")

    failures = check(current, baseline, tol_speedup=args.tol_speedup,
                     tol_w2=args.tol_w2)
    print(f"speedup_vs_sync {current['speedup_vs_sync']:.3f} "
          f"(baseline {baseline['speedup_vs_sync']:.3f}), "
          f"final_w2_async {current['final_w2_async']:.4f} "
          f"(baseline {baseline['final_w2_async']:.4f})")
    for msg in failures:
        print(f"REGRESSION: {msg}")
    if not failures:
        print("check_bench: PASS")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

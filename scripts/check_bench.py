"""CI perf-regression gates for the cluster, serve, and decode benchmarks.

Compares a freshly produced ``BENCH_cluster.json`` / ``BENCH_serve.json`` /
``BENCH_decode.json`` against the committed baseline under
``benchmarks/baselines/`` inside a tolerance band and exits non-zero on
regression, so the ``bench-smoke``, ``serve-smoke``, and ``decode-smoke``
jobs *fail* instead of merely uploading an artifact.  The payload kind is
detected from its contents (a decode payload declares ``kind``, a serve
payload carries ``rows``).

Cluster gate (simulated, machine-independent — keep the bands tight):

- ``speedup_vs_sync`` (async-vs-sync at equal gradient evaluations) may not
  fall more than ``--tol-speedup`` below the baseline, and must stay > 1;
- W2-at-budget (``final_w2_async``) may not rise more than ``--tol-w2``
  above the baseline;
- ``batch_policy.het_wallclock_advantage`` (inverse-speed batching reaching
  the fixed-batch final W2 at equal grad evals) must stay > 1;
- every sampler-zoo scenario row the baseline records (``scenarios.rows``:
  sgld / svrg / stale / sghmc / ar1) must still be present, non-NaN, and
  its ``final_w2`` may not rise more than ``--tol-w2`` above the baseline;
- wherever the baseline records a ``chaos`` block (also shipped standalone
  as a ``kind: cluster-chaos`` payload by ``bench_cluster.py --chaos``),
  the fault-injected storm arm must keep a finite W2 inside a band of the
  fault-free arm and of the baseline, with the seeded fault accounting
  (lost commits, NaN poisons, respawns, final healthy-chain count) and the
  per-arm trace counts matched exactly.

Serve gate (wall-clock, machine-dependent — the bands are wide because CI
runners differ in absolute throughput; order-of-magnitude regressions, e.g.
a retrace slipping into the request stream, still trip them):

- per (chains, shards) row, QPS may not fall below
  ``baseline * (1 - tol_qps)``;
- p99 latency may not rise above ``baseline * (1 + tol_p99)``;
- ``retraced_in_stream`` must stay False (exact, no band);
- every baseline row must still be present.

Decode gate (wall-clock, machine-dependent — like the serve gate, the
throughput floor sits at 25% of baseline because CI runners differ in
absolute speed; the *structural* invariants below are exact):

- per (chains, shards) row, tokens/sec may not fall below
  ``baseline * (1 - tol_tps)`` (floor at 25% of baseline by default);
- per-token p99 latency may not rise above ``baseline * (1 + tol_p99)``;
- the in-stream retrace count must match the baseline **exactly** (the
  trace count is a program-structure invariant, not a timing), and
  ``retraced_in_stream`` / ``pad_allocs_in_stream`` must stay falsy;
- sharded decode must stay sublinear in C (``sublinear.pass``) wherever the
  baseline recorded it;
- wherever the baseline records a ``continuous`` block, continuous batching
  must keep its sustained-QPS uplift over the convoyed static baseline
  (uplift > 1, exact), hold the paged QPS floor / p99-TTFT ceiling inside
  the same wall-clock bands, keep the paged trace count exact, and show
  zero in-stream traces and zero host pad allocations on either server;
- wherever the baseline records a ``deadline`` block, deadline shedding
  must keep its goodput uplift over the no-deadline arm under burst
  overload (relative, so machine speed cancels), return a terminal status
  for every request, and stay trace-free inside both bursts.

The structural fields the exact gates read (``traces``,
``retraced_in_stream``, ``pad_allocs_in_stream``) are produced by the
benchmarks from :mod:`repro.analysis.instrument` reports — a trace or a
host pad allocation inside the timed stream raises the flag.

When a ``BENCH_*.metrics.json`` registry snapshot (written by the
benchmarks next to the payload) exists beside both the fresh JSON and the
baseline, the script also prints per-metric deltas — informative only,
never part of the gate.

To accept an intentional change, re-run the benchmark and commit the new
JSON as the baseline.

    python scripts/check_bench.py BENCH_cluster.json \
        --baseline benchmarks/baselines/BENCH_cluster.json
    python scripts/check_bench.py BENCH_serve.json \
        --baseline benchmarks/baselines/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import sys

#: storm-arm W2 acceptance band, mirrored from benchmarks/bench_cluster.py
#: (this script stays stdlib-only, so the constants are duplicated — keep
#: them in sync with CHAOS_W2_FACTOR / CHAOS_W2_FLOOR there)
CHAOS_W2_FACTOR = 2.0
CHAOS_W2_FLOOR = 0.8


def check_chaos(cur: dict | None, base: dict, *, tol_w2: float) -> list[str]:
    """Chaos-arm regressions (empty list = pass).

    The storm arm (worker crashes + pauses + NaN-poisoned chains, with
    quarantine/respawn on) must keep a finite W2 inside a band of the
    fault-free arm on the same harness and inside the usual tolerance of
    the committed baseline.  The fault *accounting* — lost commits, poison
    events, respawns, final healthy-chain count — and the per-arm trace
    counts are gated exactly: the injection is seeded and deterministic,
    so any drift there is a code change, not machine noise.
    """
    if cur is None:
        return ["chaos: baseline records a chaos block but the fresh "
                "benchmark has none"]
    failures = []
    w2c, w2s = cur["final_w2_clean"], cur["final_w2_storm"]
    if not w2s == w2s:  # NaN guard: NaN compares false everywhere
        failures.append("chaos: storm-arm W2 is NaN (the quarantine/respawn "
                        "path failed to keep the ensemble finite)")
    else:
        band = max(CHAOS_W2_FACTOR * w2c, CHAOS_W2_FLOOR)
        if w2s > band:
            failures.append(
                f"chaos: storm-arm W2 {w2s:.4f} left the self-healing band "
                f"{band:.4f} (clean {w2c:.4f} x {CHAOS_W2_FACTOR}, floor "
                f"{CHAOS_W2_FLOOR})")
        ceil = base["final_w2_storm"] * (1.0 + tol_w2)
        if w2s > ceil:
            failures.append(
                f"chaos: storm-arm W2 regressed: {w2s:.4f} > {ceil:.4f} "
                f"(baseline {base['final_w2_storm']:.4f}, "
                f"tolerance {tol_w2:.0%})")
    for key in ("lost_commits", "poison_events", "respawned",
                "chains_healthy_final"):
        if cur.get(key) != base.get(key):
            failures.append(
                f"chaos: {key} changed: {cur.get(key)} != baseline "
                f"{base.get(key)} (fault injection is seeded and "
                "deterministic — drift here is a code change)")
    if cur["traces_in_run"] != base["traces_in_run"]:
        failures.append(
            f"chaos: per-arm trace count changed: {cur['traces_in_run']} "
            f"!= baseline {base['traces_in_run']} (fault handling must be "
            "masking + host bookkeeping, never a retrace)")
    return failures


def check_cluster(current: dict, baseline: dict, *, tol_speedup: float,
                  tol_w2: float) -> list[str]:
    """Cluster-bench regressions (empty list = pass)."""
    failures = []
    sp, sp0 = current["speedup_vs_sync"], baseline["speedup_vs_sync"]
    floor = sp0 * (1.0 - tol_speedup)
    if sp <= 1.0:
        failures.append(f"async-vs-sync speedup {sp:.3f} does not exceed 1")
    elif sp < floor:
        failures.append(
            f"async-vs-sync speedup regressed: {sp:.3f} < {floor:.3f} "
            f"(baseline {sp0:.3f}, tolerance {tol_speedup:.0%})")
    w2, w20 = current["final_w2_async"], baseline["final_w2_async"]
    ceil = w20 * (1.0 + tol_w2)
    if w2 > ceil:
        failures.append(
            f"W2-at-budget regressed: {w2:.4f} > {ceil:.4f} "
            f"(baseline {w20:.4f}, tolerance {tol_w2:.0%})")
    bp = current.get("batch_policy")
    if bp is not None:
        adv = bp.get("het_wallclock_advantage")
        if adv is None or adv <= 1.0:
            failures.append(
                "inverse-speed batching lost its wall-clock advantage at "
                f"equal grad evals (het_wallclock_advantage {adv})")
    scen0 = baseline.get("scenarios")
    if scen0 is not None:
        rows = current.get("scenarios", {}).get("rows", {})
        for name, row0 in scen0["rows"].items():
            row = rows.get(name)
            if row is None:
                failures.append(
                    f"scenario {name!r}: row missing from the fresh "
                    "benchmark (the zoo matrix must cover every baseline "
                    "sampler)")
                continue
            w2, w20 = row["final_w2"], row0["final_w2"]
            ceil = w20 * (1.0 + tol_w2)
            if not w2 == w2:  # NaN guard: NaN compares false everywhere
                failures.append(f"scenario {name!r}: final W2 is NaN")
            elif w2 > ceil:
                failures.append(
                    f"scenario {name!r}: W2-at-budget regressed: "
                    f"{w2:.4f} > {ceil:.4f} (baseline {w20:.4f}, "
                    f"tolerance {tol_w2:.0%})")
    if baseline.get("chaos") is not None:
        failures.extend(check_chaos(current.get("chaos"), baseline["chaos"],
                                    tol_w2=tol_w2))
    return failures


def _serve_rows(payload: dict) -> dict:
    return {(r["chains"], r["shards"]): r for r in payload["rows"]}


def _check_rows(current: dict, baseline: dict, *, tput_key: str,
                tput_label: str, tol_tput: float, lat_key: str,
                lat_label: str, tol_lat: float, extra=None) -> list[str]:
    """Shared per-(chains, shards)-row gate: throughput floor, latency
    ceiling, row presence; ``extra(label, row, row0)`` adds gate-specific
    exact checks.  One implementation so the serve and decode gates cannot
    drift apart."""
    failures = []
    cur = _serve_rows(current)
    for key, row0 in _serve_rows(baseline).items():
        label = f"chains={key[0]} shards={key[1]}"
        row = cur.get(key)
        if row is None:
            failures.append(f"{label}: row missing from the fresh benchmark")
            continue
        floor = row0[tput_key] * (1.0 - tol_tput)
        if row[tput_key] < floor:
            failures.append(
                f"{label}: {tput_label} regressed: {row[tput_key]:.1f} < "
                f"{floor:.1f} (baseline {row0[tput_key]:.1f}, "
                f"tolerance {tol_tput:.0%})")
        ceil = row0[lat_key] * (1.0 + tol_lat)
        if row[lat_key] > ceil:
            failures.append(
                f"{label}: {lat_label} regressed: {row[lat_key]:.3f}ms > "
                f"{ceil:.3f}ms (baseline {row0[lat_key]:.3f}ms, "
                f"tolerance {tol_lat:.0%})")
        if extra is not None:
            failures.extend(extra(label, row, row0))
    return failures


def check_serve(current: dict, baseline: dict, *, tol_qps: float,
                tol_p99: float) -> list[str]:
    """Serve-bench regressions (empty list = pass)."""

    def extra(label, row, _row0):
        if row.get("retraced_in_stream"):
            return [f"{label}: serve path retraced inside the request "
                    "stream (more than one trace per shape bucket)"]
        return []

    return _check_rows(current, baseline, tput_key="qps", tput_label="QPS",
                       tol_tput=tol_qps, lat_key="p99_ms",
                       lat_label="p99 latency", tol_lat=tol_p99, extra=extra)


def check_decode(current: dict, baseline: dict, *, tol_tps: float,
                 tol_p99: float) -> list[str]:
    """Decode-bench regressions (empty list = pass)."""

    def extra(label, row, row0):
        msgs = []
        if row["traces"] != row0["traces"]:
            msgs.append(
                f"{label}: trace count changed: {row['traces']} != baseline "
                f"{row0['traces']} (one trace per (bucket, max_new) pair is "
                "a program-structure invariant)")
        if row.get("retraced_in_stream"):
            msgs.append(
                f"{label}: decode path retraced inside the prompt stream")
        if row.get("pad_allocs_in_stream"):
            msgs.append(
                f"{label}: prompt padding allocated per request "
                f"({row['pad_allocs_in_stream']} allocs in stream)")
        return msgs

    failures = _check_rows(current, baseline, tput_key="tokens_per_s",
                           tput_label="tokens/sec", tol_tput=tol_tps,
                           lat_key="per_token_p99_ms",
                           lat_label="per-token p99", tol_lat=tol_p99,
                           extra=extra)
    if baseline.get("sublinear") is not None:
        sub = current.get("sublinear")
        if sub is None or not sub.get("pass"):
            failures.append(
                "sharded decode lost sublinearity in C: per-token cost "
                f"{sub and sub.get('sharded_per_token_ms')}ms vs linear "
                f"bound {sub and sub.get('linear_bound_ms')}ms")
    if baseline.get("continuous") is not None:
        failures.extend(_check_continuous(current.get("continuous"),
                                          baseline["continuous"],
                                          tol_tps=tol_tps, tol_p99=tol_p99))
    if baseline.get("deadline") is not None:
        failures.extend(_check_deadline(current.get("deadline")))
    return failures


def _check_deadline(dl: dict | None) -> list[str]:
    """Deadline-shedding gate: under the benchmark's burst overload, the
    deadline-armed paged server must raise goodput over the no-deadline
    arm (relative, so machine speed cancels), account for every request
    with a terminal status, and never trace inside either burst — the
    structural facts, not the wall-clock numbers, are the contract."""
    if dl is None:
        return ["deadline: baseline records a deadline-shedding block but "
                "the fresh benchmark has none"]
    failures = []
    arm = dl["deadline"]
    served = arm["ok"] + arm["shed"] + arm["timeout"]
    if served != dl["config"]["requests"]:
        failures.append(
            f"deadline: {served} terminal statuses for "
            f"{dl['config']['requests']} requests (every submitted request "
            "must come back ok, shed, or timeout)")
    if not dl.get("pass") or (dl["goodput_uplift"] or 0) <= 1.0:
        failures.append(
            "deadline: shedding lost its goodput uplift under burst "
            f"overload: {dl['goodput_uplift']}x <= 1 (on-time completions "
            "per second of busy time must go up when deadlines are armed)")
    for name in ("deadline", "no_deadline"):
        if dl[name].get("new_traces_in_stream") \
                or dl[name].get("retraced_in_stream"):
            failures.append(
                f"deadline: paged engine traced inside the {name} burst "
                f"({dl[name].get('new_traces_in_stream')} new traces — "
                "deadline handling must stay host-side)")
    return failures


def _check_continuous(cont: dict | None, cont0: dict, *, tol_tps: float,
                      tol_p99: float) -> list[str]:
    """Continuous-batching gate: the paged engine must keep its sustained-
    QPS uplift over the convoyed static batch (exact pass flag), hold a QPS
    floor and a p99-TTFT ceiling vs the baseline (wall-clock bands), and
    keep the stream structurally clean — paged trace count exact, zero
    in-stream traces, zero host pad allocations on either server."""
    if cont is None:
        failures = ["continuous: baseline records a continuous-batching "
                    "block but the fresh benchmark has none"]
        return failures
    failures = []
    paged, paged0 = cont["paged"], cont0["paged"]
    if not cont.get("pass") or cont["qps_uplift"] <= 1.0:
        failures.append(
            f"continuous: batching lost its sustained-QPS uplift over the "
            f"convoyed static batch: {cont['qps_uplift']}x <= 1 "
            f"(baseline {cont0['qps_uplift']}x)")
    floor = paged0["qps"] * (1.0 - tol_tps)
    if paged["qps"] < floor:
        failures.append(
            f"continuous: paged QPS regressed: {paged['qps']:.2f} < "
            f"{floor:.2f} (baseline {paged0['qps']:.2f}, "
            f"tolerance {tol_tps:.0%})")
    ceil = paged0["p99_ttft_ms"] * (1.0 + tol_p99)
    if paged["p99_ttft_ms"] > ceil:
        failures.append(
            f"continuous: paged p99 TTFT regressed: "
            f"{paged['p99_ttft_ms']:.1f}ms > {ceil:.1f}ms "
            f"(baseline {paged0['p99_ttft_ms']:.1f}ms, "
            f"tolerance {tol_p99:.0%})")
    if paged["traces"] != paged0["traces"]:
        failures.append(
            f"continuous: paged trace count changed: {paged['traces']} != "
            f"baseline {paged0['traces']} (one prefill trace per prompt "
            "rung + one step trace is a program-structure invariant)")
    if paged.get("new_traces_in_stream") or paged.get("retraced_in_stream"):
        failures.append(
            "continuous: paged engine retraced inside the arrival stream "
            f"({paged.get('new_traces_in_stream')} new traces)")
    for name, side in (("paged", paged), ("static", cont["static"])):
        if side.get("pad_allocs_in_stream"):
            failures.append(
                f"continuous: {name} server allocated host pad scratch "
                f"inside the arrival stream "
                f"({side['pad_allocs_in_stream']} allocs)")
    return failures


def check(current: dict, baseline: dict, *, tol_speedup: float = 0.20,
          tol_w2: float = 0.50, tol_qps: float = 0.75,
          tol_p99: float = 4.0, tol_tps: float = 0.75) -> list[str]:
    """Returns human-readable regression messages (empty = pass); dispatches
    on the payload kind (decode and chaos-only payloads declare ``kind``,
    serve payloads carry ``rows``)."""
    if current.get("kind") == "cluster-chaos":
        return check_chaos(current.get("chaos"), baseline["chaos"],
                           tol_w2=tol_w2)
    if current.get("kind") == "decode":
        return check_decode(current, baseline, tol_tps=tol_tps,
                            tol_p99=tol_p99)
    if "rows" in current:
        return check_serve(current, baseline, tol_qps=tol_qps,
                           tol_p99=tol_p99)
    return check_cluster(current, baseline, tol_speedup=tol_speedup,
                         tol_w2=tol_w2)


def _chaos_line(ch: dict, ch0: dict) -> str:
    return (f"chaos: clean W2 {ch['final_w2_clean']:.4f} storm "
            f"{ch['final_w2_storm']:.4f} (baseline storm "
            f"{ch0['final_w2_storm']:.4f}), {ch['lost_commits']} commits "
            f"lost, {ch['poison_events']} poisons, {ch['respawned']} "
            f"respawns, {ch['chains_healthy_final']} chains healthy")


def _summary(current: dict, baseline: dict) -> str:
    if current.get("kind") == "cluster-chaos":
        return _chaos_line(current["chaos"], baseline["chaos"])
    if current.get("kind") == "decode":
        cur, base = _serve_rows(current), _serve_rows(baseline)
        parts = []
        for key in sorted(base):
            c, b = cur.get(key), base[key]
            got = (f"tok/s {c['tokens_per_s']:.0f} "
                   f"p99 {c['per_token_p99_ms']:.2f}ms "
                   f"traces {c['traces']}" if c else "MISSING")
            parts.append(f"chains={key[0]} shards={key[1]}: {got} "
                         f"(baseline tok/s {b['tokens_per_s']:.0f} "
                         f"traces {b['traces']})")
        cont, cont0 = current.get("continuous"), baseline.get("continuous")
        if cont0 is not None:
            got = (f"uplift {cont['qps_uplift']}x, paged "
                   f"{cont['paged']['qps']:.2f} qps" if cont else "MISSING")
            parts.append(f"continuous: {got} (baseline uplift "
                         f"{cont0['qps_uplift']}x, paged "
                         f"{cont0['paged']['qps']:.2f} qps)")
        dl, dl0 = current.get("deadline"), baseline.get("deadline")
        if dl0 is not None:
            got = (f"goodput uplift {dl['goodput_uplift']}x "
                   f"({dl['deadline']['ok']} ok / {dl['deadline']['shed']} "
                   f"shed / {dl['deadline']['timeout']} cut)" if dl
                   else "MISSING")
            parts.append(f"deadline: {got} (baseline uplift "
                         f"{dl0['goodput_uplift']}x)")
        return "\n".join(parts)
    if "rows" in current:
        cur, base = _serve_rows(current), _serve_rows(baseline)
        parts = []
        for key in sorted(base):
            c, b = cur.get(key), base[key]
            got = (f"qps {c['qps']:.0f} p99 {c['p99_ms']:.2f}ms" if c
                   else "MISSING")
            parts.append(f"chains={key[0]} shards={key[1]}: {got} "
                         f"(baseline qps {b['qps']:.0f} "
                         f"p99 {b['p99_ms']:.2f}ms)")
        return "\n".join(parts)
    line = (f"speedup_vs_sync {current['speedup_vs_sync']:.3f} "
            f"(baseline {baseline['speedup_vs_sync']:.3f}), "
            f"final_w2_async {current['final_w2_async']:.4f} "
            f"(baseline {baseline['final_w2_async']:.4f})")
    rows0 = baseline.get("scenarios", {}).get("rows", {})
    rows = current.get("scenarios", {}).get("rows", {})
    if rows0:
        line += "\nscenarios: " + ", ".join(
            f"{name} W2 "
            f"{rows[name]['final_w2'] if name in rows else float('nan'):.4f}"
            f" (baseline {rows0[name]['final_w2']:.4f})"
            for name in sorted(rows0))
    if baseline.get("chaos") is not None and current.get("chaos") is not None:
        line += "\n" + _chaos_line(current["chaos"], baseline["chaos"])
    return line


def _metrics_path(bench_path: str) -> str:
    """``BENCH_x.json`` → the ``BENCH_x.metrics.json`` snapshot the
    benchmark writes next to it (repro.obs.metrics registry)."""
    return (bench_path[:-5] if bench_path.endswith(".json")
            else bench_path) + ".metrics.json"


def _metric_scalars(snapshot: dict) -> dict:
    """Flatten a registry snapshot to comparable scalars: counter/gauge
    values plus ``<hist>.count`` / ``<hist>.mean`` per histogram."""
    out = {}
    for name, d in snapshot.items():
        if d.get("type") in ("counter", "gauge"):
            out[name] = d["value"]
        elif d.get("type") == "histogram":
            out[f"{name}.count"] = d["count"]
            if d["count"]:
                out[f"{name}.mean"] = d["sum"] / d["count"]
    return out


def metric_deltas(current: dict, baseline: dict) -> list[str]:
    """Non-gating deltas between two registry snapshots, one line per
    metric both sides report (new/vanished metrics are called out but
    never fail the gate — the snapshots are observability, not contract)."""
    cur, base = _metric_scalars(current), _metric_scalars(baseline)
    lines = []
    for name in sorted(set(cur) & set(base)):
        c, b = cur[name], base[name]
        rel = f" ({(c - b) / b:+.1%})" if b else ""
        if c != b:
            lines.append(f"  {name}: {b:g} -> {c:g}{rel}")
    only_cur = sorted(set(cur) - set(base))
    only_base = sorted(set(base) - set(cur))
    if only_cur:
        lines.append(f"  new metrics (no baseline): {', '.join(only_cur)}")
    if only_base:
        lines.append(f"  baseline metrics missing from this run: "
                     f"{', '.join(only_base)}")
    return lines


def report_metric_deltas(bench_path: str, baseline_path: str,
                         out=None) -> None:
    """Print metric-snapshot deltas when both sides have one (informative
    only; never affects the exit status)."""
    import os

    out = out if out is not None else sys.stdout
    paths = _metrics_path(bench_path), _metrics_path(baseline_path)
    if not all(os.path.exists(p) for p in paths):
        return
    with open(paths[0]) as f:
        current = json.load(f)
    with open(paths[1]) as f:
        baseline = json.load(f)
    lines = metric_deltas(current, baseline)
    if lines:
        print("metric deltas vs baseline snapshot (non-gating):", file=out)
        for line in lines:
            print(line, file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="fresh BENCH_*.json to validate")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_cluster.json")
    ap.add_argument("--tol-speedup", type=float, default=0.20,
                    help="allowed fractional speedup drop (default 0.20)")
    ap.add_argument("--tol-w2", type=float, default=0.50,
                    help="allowed fractional W2 increase (default 0.50)")
    ap.add_argument("--tol-qps", type=float, default=0.75,
                    help="allowed fractional QPS drop (default 0.75 — wide, "
                    "absolute throughput is machine-dependent)")
    ap.add_argument("--tol-p99", type=float, default=4.0,
                    help="allowed fractional p99 increase (default 4.0)")
    ap.add_argument("--tol-tps", type=float, default=0.75,
                    help="allowed fractional tokens/sec drop for the decode "
                    "gate (default 0.75 — wide, absolute throughput is "
                    "machine-dependent; the floor sits at 25% of baseline)")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    cfg, cfg0 = current.get("config", {}), baseline.get("config", {})
    if cfg != cfg0:
        diff = {k for k in set(cfg) | set(cfg0) if cfg.get(k) != cfg0.get(k)}
        print(f"check_bench: config drift vs baseline in {sorted(diff)} — "
              "comparing anyway; recommit the baseline if intentional")

    failures = check(current, baseline, tol_speedup=args.tol_speedup,
                     tol_w2=args.tol_w2, tol_qps=args.tol_qps,
                     tol_p99=args.tol_p99, tol_tps=args.tol_tps)
    print(_summary(current, baseline))
    report_metric_deltas(args.bench, args.baseline)
    for msg in failures:
        print(f"REGRESSION: {msg}")
    if not failures:
        print("check_bench: PASS")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""CI perf-regression gates for the cluster and serve benchmarks.

Compares a freshly produced ``BENCH_cluster.json`` / ``BENCH_serve.json``
against the committed baseline under ``benchmarks/baselines/`` inside a
tolerance band and exits non-zero on regression, so the ``bench-smoke`` and
``serve-smoke`` jobs *fail* instead of merely uploading an artifact.  The
payload kind is detected from its contents (a serve payload carries
``rows``).

Cluster gate (simulated, machine-independent — keep the bands tight):

- ``speedup_vs_sync`` (async-vs-sync at equal gradient evaluations) may not
  fall more than ``--tol-speedup`` below the baseline, and must stay > 1;
- W2-at-budget (``final_w2_async``) may not rise more than ``--tol-w2``
  above the baseline;
- ``batch_policy.het_wallclock_advantage`` (inverse-speed batching reaching
  the fixed-batch final W2 at equal grad evals) must stay > 1.

Serve gate (wall-clock, machine-dependent — the bands are wide because CI
runners differ in absolute throughput; order-of-magnitude regressions, e.g.
a retrace slipping into the request stream, still trip them):

- per (chains, shards) row, QPS may not fall below
  ``baseline * (1 - tol_qps)``;
- p99 latency may not rise above ``baseline * (1 + tol_p99)``;
- ``retraced_in_stream`` must stay False (exact, no band);
- every baseline row must still be present.

To accept an intentional change, re-run the benchmark and commit the new
JSON as the baseline.

    python scripts/check_bench.py BENCH_cluster.json \
        --baseline benchmarks/baselines/BENCH_cluster.json
    python scripts/check_bench.py BENCH_serve.json \
        --baseline benchmarks/baselines/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import sys


def check_cluster(current: dict, baseline: dict, *, tol_speedup: float,
                  tol_w2: float) -> list[str]:
    """Cluster-bench regressions (empty list = pass)."""
    failures = []
    sp, sp0 = current["speedup_vs_sync"], baseline["speedup_vs_sync"]
    floor = sp0 * (1.0 - tol_speedup)
    if sp <= 1.0:
        failures.append(f"async-vs-sync speedup {sp:.3f} does not exceed 1")
    elif sp < floor:
        failures.append(
            f"async-vs-sync speedup regressed: {sp:.3f} < {floor:.3f} "
            f"(baseline {sp0:.3f}, tolerance {tol_speedup:.0%})")
    w2, w20 = current["final_w2_async"], baseline["final_w2_async"]
    ceil = w20 * (1.0 + tol_w2)
    if w2 > ceil:
        failures.append(
            f"W2-at-budget regressed: {w2:.4f} > {ceil:.4f} "
            f"(baseline {w20:.4f}, tolerance {tol_w2:.0%})")
    bp = current.get("batch_policy")
    if bp is not None:
        adv = bp.get("het_wallclock_advantage")
        if adv is None or adv <= 1.0:
            failures.append(
                "inverse-speed batching lost its wall-clock advantage at "
                f"equal grad evals (het_wallclock_advantage {adv})")
    return failures


def _serve_rows(payload: dict) -> dict:
    return {(r["chains"], r["shards"]): r for r in payload["rows"]}


def check_serve(current: dict, baseline: dict, *, tol_qps: float,
                tol_p99: float) -> list[str]:
    """Serve-bench regressions (empty list = pass)."""
    failures = []
    cur = _serve_rows(current)
    for key, row0 in _serve_rows(baseline).items():
        chains, shards = key
        label = f"chains={chains} shards={shards}"
        row = cur.get(key)
        if row is None:
            failures.append(f"{label}: row missing from the fresh benchmark")
            continue
        floor = row0["qps"] * (1.0 - tol_qps)
        if row["qps"] < floor:
            failures.append(
                f"{label}: QPS regressed: {row['qps']:.1f} < {floor:.1f} "
                f"(baseline {row0['qps']:.1f}, tolerance {tol_qps:.0%})")
        ceil = row0["p99_ms"] * (1.0 + tol_p99)
        if row["p99_ms"] > ceil:
            failures.append(
                f"{label}: p99 latency regressed: {row['p99_ms']:.3f}ms > "
                f"{ceil:.3f}ms (baseline {row0['p99_ms']:.3f}ms, "
                f"tolerance {tol_p99:.0%})")
        if row.get("retraced_in_stream"):
            failures.append(
                f"{label}: serve path retraced inside the request stream "
                "(more than one trace per shape bucket)")
    return failures


def check(current: dict, baseline: dict, *, tol_speedup: float = 0.20,
          tol_w2: float = 0.50, tol_qps: float = 0.75,
          tol_p99: float = 4.0) -> list[str]:
    """Returns human-readable regression messages (empty = pass); dispatches
    on the payload kind (serve payloads carry ``rows``)."""
    if "rows" in current:
        return check_serve(current, baseline, tol_qps=tol_qps,
                           tol_p99=tol_p99)
    return check_cluster(current, baseline, tol_speedup=tol_speedup,
                         tol_w2=tol_w2)


def _summary(current: dict, baseline: dict) -> str:
    if "rows" in current:
        cur, base = _serve_rows(current), _serve_rows(baseline)
        parts = []
        for key in sorted(base):
            c, b = cur.get(key), base[key]
            got = (f"qps {c['qps']:.0f} p99 {c['p99_ms']:.2f}ms" if c
                   else "MISSING")
            parts.append(f"chains={key[0]} shards={key[1]}: {got} "
                         f"(baseline qps {b['qps']:.0f} "
                         f"p99 {b['p99_ms']:.2f}ms)")
        return "\n".join(parts)
    return (f"speedup_vs_sync {current['speedup_vs_sync']:.3f} "
            f"(baseline {baseline['speedup_vs_sync']:.3f}), "
            f"final_w2_async {current['final_w2_async']:.4f} "
            f"(baseline {baseline['final_w2_async']:.4f})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="fresh BENCH_*.json to validate")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_cluster.json")
    ap.add_argument("--tol-speedup", type=float, default=0.20,
                    help="allowed fractional speedup drop (default 0.20)")
    ap.add_argument("--tol-w2", type=float, default=0.50,
                    help="allowed fractional W2 increase (default 0.50)")
    ap.add_argument("--tol-qps", type=float, default=0.75,
                    help="allowed fractional QPS drop (default 0.75 — wide, "
                    "absolute throughput is machine-dependent)")
    ap.add_argument("--tol-p99", type=float, default=4.0,
                    help="allowed fractional p99 increase (default 4.0)")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    cfg, cfg0 = current.get("config", {}), baseline.get("config", {})
    if cfg != cfg0:
        diff = {k for k in set(cfg) | set(cfg0) if cfg.get(k) != cfg0.get(k)}
        print(f"check_bench: config drift vs baseline in {sorted(diff)} — "
              "comparing anyway; recommit the baseline if intentional")

    failures = check(current, baseline, tol_speedup=args.tol_speedup,
                     tol_w2=args.tol_w2, tol_qps=args.tol_qps,
                     tol_p99=args.tol_p99)
    print(_summary(current, baseline))
    for msg in failures:
        print(f"REGRESSION: {msg}")
    if not failures:
        print("check_bench: PASS")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""jaxlint CLI — run the repo's JAX/Pallas invariant linter.

    python scripts/jaxlint.py src benchmarks examples
    python scripts/jaxlint.py --baseline src > jaxlint-baseline.json

Exit status is 1 when any non-suppressed finding exists (0 with
``--baseline``, which always writes the full JSON report, suppressed
findings included, for the CI artifact).

Pure stdlib + the linter module itself — no JAX import, so the lint CI
job can run it without the accelerator stack.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# runnable straight from a checkout, no install step
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.lint import RULES, lint_paths  # noqa: E402


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="jaxlint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--baseline", action="store_true",
                        help="emit the full findings report (suppressed "
                             "included) as JSON on stdout and exit 0")
    parser.add_argument("--select", default=None,
                        help="comma-separated rule ids (default: all)")
    parser.add_argument("--exclude", action="append", default=[],
                        help="path component to skip (repeatable)")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="also print pragma-suppressed findings")
    args = parser.parse_args(argv)

    selected = (set(r.strip().upper() for r in args.select.split(","))
                if args.select else set(RULES) | {"JL000"})
    findings = [f for f in lint_paths(args.paths, exclude=args.exclude)
                if f.rule in selected]

    if args.baseline:
        report = {
            "rules": RULES,
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "col": f.col, "message": f.message,
                 "suppressed": f.suppressed}
                for f in findings
            ],
            "counts": {
                "active": sum(not f.suppressed for f in findings),
                "suppressed": sum(f.suppressed for f in findings),
            },
        }
        json.dump(report, sys.stdout, indent=2)
        sys.stdout.write("\n")
        return 0

    active = [f for f in findings if not f.suppressed]
    shown = findings if args.show_suppressed else active
    for f in shown:
        print(f.format())
    n_sup = len(findings) - len(active)
    summary = f"jaxlint: {len(active)} finding(s)"
    if n_sup:
        summary += f", {n_sup} suppressed"
    print(summary, file=sys.stderr)
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())

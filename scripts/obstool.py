#!/usr/bin/env python
"""obstool CLI — summarize repro.obs timelines and metrics snapshots.

    python scripts/obstool.py BENCH_cluster.timeline.json
    python scripts/obstool.py BENCH_decode.timeline.json \
        --metrics BENCH_decode.metrics.json
    python scripts/obstool.py --metrics BENCH_serve.metrics.json

Reads the Chrome-trace-event JSON the benchmarks write next to each
``BENCH_*.json`` (or a bare span dump — a JSON list of span dicts) and
prints the critical path (busiest row of the timeline), per-row busy time
and utilization, the staleness histogram over cluster commit spans, and
tokens/sec per decode rung.  ``--metrics`` pretty-prints a registry
snapshot (``registry().write_snapshot``) alongside, or alone.

Pure stdlib + :mod:`repro.obs.timeline` — no JAX import, so it runs
anywhere the artifacts land (CI log steps, laptops without the
accelerator stack).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# runnable straight from a checkout, no install step
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.timeline import (  # noqa: E402
    _spans_or_trace,
    summarize,
    validate_chrome_trace,
)


def _fmt_rows(rows, limit: int) -> str:
    lines = [f"{'row':<40} {'busy s':>10} {'end s':>10} {'util':>6}"]
    for r in rows[:limit]:
        lines.append(f"{r['label']:<40} {r['busy_s']:>10.4f} "
                     f"{r['end_s']:>10.4f} {r['utilization']:>6.1%}")
    if len(rows) > limit:
        lines.append(f"... {len(rows) - limit} more rows")
    return "\n".join(lines)


def print_timeline(path: str, *, limit: int = 12, out=None) -> int:
    out = out if out is not None else sys.stdout
    with open(path) as f:
        trace = _spans_or_trace(json.load(f))
    problems = validate_chrome_trace(trace)
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=out)
        return 1
    s = summarize(trace)
    print(f"== {path}", file=out)
    print(f"makespan: {s['makespan_s']:.4f}s over "
          f"{len(s['rows'])} timeline rows", file=out)
    if s["critical"]:
        c = s["critical"]
        print(f"critical path: {c['label']} "
              f"(busy {c['busy_s']:.4f}s, {c['utilization']:.1%} of "
              "makespan)", file=out)
    print(_fmt_rows(s["rows"], limit), file=out)
    if s["staleness_hist"]:
        total = sum(s["staleness_hist"].values())
        print("staleness over commit spans:", file=out)
        for tau, n in s["staleness_hist"].items():
            bar = "#" * max(1, round(40 * n / total))
            print(f"  tau={tau:>4} {n:>7} {bar}", file=out)
    if s["tokens_by_rung"]:
        print("decode tokens/sec by rung (amortized):", file=out)
        for label, r in sorted(s["tokens_by_rung"].items()):
            tps = r["tokens_per_s"]
            print(f"  {label:<16} {r['tokens']:>7} tokens"
                  + (f"  {tps:>10.1f} tok/s" if tps else ""), file=out)
    return 0


def _hist_quantile(bounds, counts, total, q) -> float:
    """Upper bucket bound holding the q-quantile (mirrors
    Histogram.quantile, recomputed from the snapshot)."""
    rank, acc = q * total, 0
    for i, c in enumerate(counts):
        acc += c
        if acc >= rank:
            return bounds[i] if i < len(bounds) else float("inf")
    return float("inf")


def print_metrics(path: str, out=None) -> int:
    out = out if out is not None else sys.stdout
    with open(path) as f:
        snap = json.load(f)
    print(f"== {path}", file=out)
    for name, d in sorted(snap.items()):
        if d["type"] in ("counter", "gauge"):
            print(f"  {d['type']:<9} {name:<38} {d['value']:>14.4f}",
                  file=out)
        else:
            n = d["count"]
            mean = d["sum"] / n if n else float("nan")
            p50 = _hist_quantile(d["bounds"], d["counts"], n, 0.5)
            p99 = _hist_quantile(d["bounds"], d["counts"], n, 0.99)
            print(f"  histogram {name:<38} n={n} mean={mean:.4f} "
                  f"p50<={p50:g} p99<={p99:g}", file=out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="obstool", description=__doc__)
    ap.add_argument("timeline", nargs="?",
                    help="Chrome-trace JSON (or bare span-dump list)")
    ap.add_argument("--metrics", help="metrics snapshot JSON to pretty-print")
    ap.add_argument("--rows", type=int, default=12,
                    help="timeline rows to print (default 12)")
    args = ap.parse_args(argv)
    if not args.timeline and not args.metrics:
        ap.error("give a timeline file and/or --metrics")
    rc = 0
    if args.timeline:
        rc = print_timeline(args.timeline, limit=args.rows)
    if args.metrics:
        rc = max(rc, print_metrics(args.metrics))
    return rc


if __name__ == "__main__":
    sys.exit(main())

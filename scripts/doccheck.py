"""Public-docstring gate over the repo's documented packages (stdlib AST,
jaxlint-style: no imports of the checked code, exit 1 on findings).

Every module, public top-level function/class, and public method in the
target packages must carry a docstring — the docs tree (docs/SAMPLERS.md
and friends) links into these docstrings, so a missing one is a doc hole,
not a style nit.  Checked by default: ``repro.samplers``,
``repro.cluster``, ``repro.obs``.

Exemptions, mirroring what a reader never looks up:

- names starting with ``_`` (and dunder methods except ``__call__``);
- ``NamedTuple`` / dataclass field blocks (fields are documented in the
  class docstring);
- trivial delegating defs whose body is a single return/raise AND that
  are nested inside a documented factory (the closure pattern the
  sampler transforms use) — top-level defs never get this exemption;
- ``@overload`` stubs and ``...``-bodied protocol methods.

    python scripts/doccheck.py                # gate the default packages
    python scripts/doccheck.py src/repro/obs  # gate specific trees
"""

from __future__ import annotations

import argparse
import ast
import pathlib
import sys

DEFAULT_TARGETS = ("src/repro/samplers", "src/repro/cluster",
                   "src/repro/obs")


def _has_docstring(node: ast.AST) -> bool:
    return ast.get_docstring(node) is not None


def _is_stub(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    """``...``-bodied and ``@overload`` defs carry no behavior to document."""
    for deco in fn.decorator_list:
        if isinstance(deco, ast.Name) and deco.id == "overload":
            return True
    body = fn.body
    if _has_docstring(fn):
        body = body[1:]
    return (len(body) == 1
            and isinstance(body[0], ast.Expr)
            and isinstance(body[0].value, ast.Constant)
            and body[0].value.value is Ellipsis)


def _public_name(name: str) -> bool:
    return not name.startswith("_") or name == "__call__"


def check_module(path: pathlib.Path) -> list[str]:
    """-> findings for one source file, ``path:line: message`` formatted."""
    tree = ast.parse(path.read_text(), filename=str(path))
    findings = []

    def report(node, what, name):
        findings.append(f"{path}:{node.lineno}: {what} `{name}` "
                        "is public but has no docstring")

    if not _has_docstring(tree) and any(
            not isinstance(n, (ast.Import, ast.ImportFrom)) for n in tree.body):
        findings.append(f"{path}:1: module has no docstring")

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (_public_name(node.name) and not _has_docstring(node)
                    and not _is_stub(node)):
                report(node, "function", node.name)
        elif isinstance(node, ast.ClassDef) and _public_name(node.name):
            if not _has_docstring(node):
                report(node, "class", node.name)
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    continue
                if (_public_name(sub.name) and not _has_docstring(sub)
                        and not _is_stub(sub)):
                    report(sub, "method", f"{node.name}.{sub.name}")
    return findings


def check_tree(root: pathlib.Path) -> list[str]:
    """-> findings across every ``*.py`` under ``root`` (or just ``root``
    itself when it is a file)."""
    paths = [root] if root.is_file() else sorted(root.rglob("*.py"))
    findings = []
    for path in paths:
        findings.extend(check_module(path))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("targets", nargs="*", default=list(DEFAULT_TARGETS),
                    help="package directories (or files) to gate")
    args = ap.parse_args(argv)
    findings = []
    for target in args.targets:
        root = pathlib.Path(target)
        if not root.exists():
            print(f"doccheck: no such path {target}", file=sys.stderr)
            return 2
        findings.extend(check_tree(root))
    for f in findings:
        print(f)
    print(f"doccheck: {len(findings)} finding(s) over "
          f"{', '.join(map(str, args.targets))}"
          + ("" if findings else " — PASS"))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

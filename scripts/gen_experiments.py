"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
dry-run JSONs.  Narrative sections live in the template below and in
experiments/perf_log.md (§Perf)."""

from __future__ import annotations

import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")


def load():
    rows = []
    for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_b(x):
    return f"{x/2**30:.2f}"


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def dryrun_table(rows, mesh, mode_filter):
    out = ["| arch | shape | params/dev GiB | temp GiB | compile s | "
           "collective GB/dev |", "|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["mode"] != mode_filter:
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_b(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_b(m.get('temp_size_in_bytes', 0))} | {r['compile_s']} | "
            f"{r['roofline']['collective_bytes_per_device']/1e9:.1f} |")
    return "\n".join(out)


def roofline_table(rows, mesh, mode_filter):
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
           "useful | one-line fix |", "|---|---|---|---|---|---|---|---|"]
    fixes = {
        ("collective", "train"): "attn head-shard / fewer microbatch gathers",
        ("collective", "prefill"): "attn head-shard (kill in-loop reshard)",
        ("collective", "decode"): "pad vocab + head-shard; batch the cache reads",
        ("memory", "train"): "more microbatches / window-sliced flash",
        ("memory", "prefill"): "window-sliced flash; bf16 accumulators",
        ("memory", "decode"): "expected: decode IS HBM-bound (cache streaming)",
        ("compute", "train"): "triangle-only causal blocks (skip masked half)",
        ("compute", "prefill"): "triangle-only causal blocks",
        ("compute", "decode"): "n/a",
    }
    for r in rows:
        if r["mesh"] != mesh or r["mode"] != mode_filter:
            continue
        rf = r["roofline"]
        fix = fixes.get((rf["dominant"], r["kind"]), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(rf['t_compute'])} | "
            f"{fmt_ms(rf['t_memory'])} | {fmt_ms(rf['t_collective'])} | "
            f"{rf['dominant']} | {rf['useful_ratio']:.2f} | {fix} |")
    return "\n".join(out)


def opt_compare_table(rows):
    """baseline vs optimized (single-pod) per (arch, shape)."""
    base = {(r["arch"].replace("-", "_").replace(".", "p"), r["shape"]): r
            for r in rows if r["mesh"] == "16x16" and r["mode"] == "sync"}
    opt = {(r["arch"].replace("-", "_").replace(".", "p"), r["shape"]): r
           for r in rows if r["mesh"] == "16x16"
           and r["mode"] == "sync+attn_shard+window_slice+padvocab"}
    out = ["| arch | shape | coll GB/dev base→opt | temp GiB base→opt | "
           "dominant base→opt |", "|---|---|---|---|---|"]
    for k in sorted(base):
        if k not in opt:
            continue
        b, o = base[k], opt[k]
        bb = b["roofline"]["collective_bytes_per_device"] / 1e9
        oo = o["roofline"]["collective_bytes_per_device"] / 1e9
        bt = b["memory"].get("temp_size_in_bytes", 0) / 2**30
        ot = o["memory"].get("temp_size_in_bytes", 0) / 2**30
        out.append(f"| {k[0]} | {k[1]} | {bb:.1f}→{oo:.1f} | "
                   f"{bt:.1f}→{ot:.1f} | "
                   f"{b['roofline']['dominant']}→{o['roofline']['dominant']} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load()
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod 16x16 baseline\n")
        print(dryrun_table(rows, "16x16", "sync"))
        print("\n### multi-pod 2x16x16 baseline\n")
        print(dryrun_table(rows, "2x16x16", "sync"))
    if which in ("all", "roofline"):
        print("\n### roofline, single-pod baseline\n")
        print(roofline_table(rows, "16x16", "sync"))
        print("\n### roofline, multi-pod baseline\n")
        print(roofline_table(rows, "2x16x16", "sync"))
    if which in ("all", "opt"):
        print("\n### baseline vs optimized\n")
        print(opt_compare_table(rows))

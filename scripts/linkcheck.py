"""Local markdown link gate over the docs tree (stdlib only, no network).

Every inline ``[text](target)`` link in README.md, ANALYSIS.md, CHANGES.md,
ROADMAP.md and ``docs/*.md`` is resolved relative to its source file:

- ``path`` / ``path#anchor`` — the file must exist inside the repo; when
  an anchor is given and the target is markdown, a matching heading must
  exist (GitHub slugging: lowercase, spaces to ``-``, punctuation dropped);
- ``#anchor`` — same-file heading check;
- ``http(s)://`` / ``mailto:`` — skipped (this gate never touches the
  network; external rot is not a CI failure).

Fenced code blocks are masked first so ``](`` inside examples is ignored.

    python scripts/linkcheck.py            # gate the default file set
    python scripts/linkcheck.py docs/CI.md # gate specific files
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^```.*?^```\s*$", re.M | re.S)
HEADING = re.compile(r"^#{1,6}\s+(.+?)\s*#*\s*$", re.M)
_SLUG_DROP = re.compile(r"[^\w\- ]")


def default_files() -> list[pathlib.Path]:
    """README/ANALYSIS/CHANGES/ROADMAP plus every page under docs/."""
    names = ["README.md", "ANALYSIS.md", "CHANGES.md", "ROADMAP.md"]
    files = [ROOT / n for n in names if (ROOT / n).exists()]
    return files + sorted((ROOT / "docs").glob("*.md"))


def slugify(heading: str) -> str:
    """GitHub's anchor slug: strip inline code/links, lowercase, drop
    punctuation, spaces to hyphens."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = _SLUG_DROP.sub("", text.lower())
    return text.strip().replace(" ", "-")


def anchors(path: pathlib.Path) -> set[str]:
    """Every heading slug in a markdown file (fences masked)."""
    text = FENCE.sub("", path.read_text())
    return {slugify(m.group(1)) for m in HEADING.finditer(text)}


def check_file(path: pathlib.Path) -> list[str]:
    """-> findings for one markdown file, ``path: message`` formatted."""
    text = FENCE.sub(lambda m: "\n" * m.group(0).count("\n"), path.read_text())
    findings = []
    for m in LINK.finditer(text):
        target = m.group(1)
        line = text[: m.start()].count("\n") + 1
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if ref:
            if not dest.exists():
                findings.append(f"{path}:{line}: broken link `{target}` "
                                f"(no such file {ref})")
                continue
            if ROOT not in dest.parents and dest != ROOT:
                findings.append(f"{path}:{line}: link `{target}` escapes "
                                "the repo")
                continue
        if anchor and dest.suffix == ".md":
            if anchor not in anchors(dest):
                findings.append(f"{path}:{line}: broken anchor `{target}` "
                                f"(no heading slugs to `#{anchor}` "
                                f"in {dest.name})")
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*", help="markdown files to gate "
                    "(default: README/ANALYSIS/CHANGES/ROADMAP + docs/*.md)")
    args = ap.parse_args(argv)
    files = ([pathlib.Path(f) for f in args.files] if args.files
             else default_files())
    findings = []
    for path in files:
        if not path.exists():
            print(f"linkcheck: no such file {path}", file=sys.stderr)
            return 2
        findings.extend(check_file(path))
    for f in findings:
        print(f)
    print(f"linkcheck: {len(findings)} finding(s) over {len(files)} file(s)"
          + ("" if findings else " — PASS"))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python examples/serve_batch.py [--arch qwen3-4b] [--tokens 16]

Posterior-sampled weights (a few async-SGLD steps) -> prefill the prompt
batch through the parallel forward -> greedy-decode ``--tokens`` steps
through the ring KV cache, reporting per-step decode latency.  Uses the
reduced config of any assigned architecture.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_reduced
from repro.core import SGLDConfig
from repro.data import make_batch
from repro.models.transformer import Model, init_params
from repro.train import Engine, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--warm-steps", type=int, default=5)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if cfg.block_pattern[0] not in ("attn_mlp", "attn_moe"):
        raise SystemExit(f"{args.arch}: prefill->cache path is attention-only; "
                         "recurrent archs serve via init_cache + replay")
    model = Model(cfg, mesh=None)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    # a few SGLD steps so the served weights are a posterior sample
    shape = ShapeConfig("warm", seq_len=64, global_batch=2, kind="train")
    sampler, _ = make_train_step(
        model, SGLDConfig(mode="pipeline", gamma=1e-3, sigma=1e-8))
    if args.warm_steps > 0:
        key, init_key = jax.random.split(key)
        state = sampler.init(params, init_key)
        engine = Engine(sampler,
                        batch_fn=lambda k: make_batch(cfg, shape, k, "train"),
                        chunk_size=args.warm_steps)
        state, _ = engine.run(state, steps=args.warm_steps, key=key)
        params = state.params

    # prefill
    key, pk = jax.random.split(key)
    prompts = jax.random.randint(pk, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    jprefill = jax.jit(model.prefill)
    t0 = time.time()
    logits, cache = jprefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.3f}s")

    # the prefill cache covers prompt positions; extend into a decode cache
    max_seq = args.prompt_len + args.tokens
    dcache = model.init_cache(args.batch, max_seq, prefill_len=args.prompt_len)
    dcache["attn"]["k"] = dcache["attn"]["k"].at[:, :, :args.prompt_len].set(
        cache["attn"]["k"])
    dcache["attn"]["v"] = dcache["attn"]["v"].at[:, :, :args.prompt_len].set(
        cache["attn"]["v"])

    jserve = jax.jit(model.serve_step)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    outs = [tok]
    lat = []
    for t in range(args.tokens):
        t0 = time.time()
        logits, dcache = jserve(params, dcache, tok,
                                jnp.int32(args.prompt_len + t))
        jax.block_until_ready(logits)
        lat.append(time.time() - t0)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    lat_ms = [round(x * 1e3, 1) for x in lat]
    print(f"decode: median {sorted(lat_ms)[len(lat_ms)//2]}ms/token "
          f"(first {lat_ms[0]}ms incl. compile)")
    for b in range(args.batch):
        print(f"  seq{b}: {[int(x) for x in gen[b][:10]]}...")


if __name__ == "__main__":
    main()

"""repro.cluster quickstart: a 32-chain async-SGLD ensemble on device.

    PYTHONPATH=src python examples/cluster_quickstart.py

Each chain replays its own P-worker asynchronous execution (an executable
``WorkerSchedule`` compiled from the event-driven simulator); one jitted
``lax.scan`` chunk advances all 32 chains through the full sampler transform
chain, ring buffers included.  The chain cloud is compared against the
closed-form Gibbs posterior with empirical W2 — convergence *in measure*,
measured directly, on both the commit and the simulated wall-clock axis.
"""

import jax
import jax.numpy as jnp

from repro import samplers
from repro.cluster import ClusterEngine, ensemble_async, w2_recorder
from repro.core import Quadratic, WorkerModel

CHAINS, WORKERS, COMMITS = 32, 8, 600

quad = Quadratic.make(jax.random.PRNGKey(0), d=2, m=1.0, L=3.0)
sigma = 0.5
target = quad.x_star + jnp.sqrt(quad.stationary_cov(sigma)) * jax.random.normal(
    jax.random.PRNGKey(1), (256, quad.d))

# One executable schedule per chain: worker ids, read versions, commit times.
schedules = ensemble_async(WorkerModel(num_workers=WORKERS, seed=0),
                           COMMITS, CHAINS, seed=0)
tau = max(s.max_delay for s in schedules)
print(f"{CHAINS} chains x {WORKERS} workers, realized max staleness {tau}")

sampler = samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                        gamma=0.05, sigma=sigma, tau=tau)
w2 = w2_recorder(target, every=50)
engine = ClusterEngine(sampler, num_chains=CHAINS, chunk_size=50, hooks=[w2])

state = engine.init(jnp.zeros(quad.d), jax.random.PRNGKey(2), jitter=2.0)
state, _ = engine.run(state, steps=COMMITS, schedule=schedules)

print(f"{'commit':>7} {'sim wall clock':>14} {'empirical W2':>12}")
for row in w2.record:
    print(f"{row['step']:7d} {row['commit_time']:14.1f} {row['w2']:12.4f}")
print(f"jit traces: {engine.num_traces} (one per distinct chunk length)")

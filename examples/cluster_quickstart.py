"""repro.cluster quickstart: a 32-chain async-SGLD ensemble on device.

    PYTHONPATH=src python examples/cluster_quickstart.py
    PYTHONPATH=src python examples/cluster_quickstart.py --sampler svrg

Each chain replays its own P-worker asynchronous execution (an executable
``WorkerSchedule`` compiled from the event-driven simulator); one jitted
``lax.scan`` chunk advances all 32 chains through the full sampler transform
chain, ring buffers included.  The chain cloud is compared against the
closed-form Gibbs posterior with empirical W2 — convergence *in measure*,
measured directly, on both the commit and the simulated wall-clock axis.

The second half turns on the heterogeneous batch policy: the same worker
pool re-simulated with ``batch_policy="inverse-speed"``, so slow workers
amortize their staleness over large (bucket-snapped) minibatches while fast
workers commit fresh small-batch gradients, and the executor scans masked
bucket-padded windows of a data stream — one jit trace per ladder rung.

``--sampler`` swaps the ensemble's chain for a zoo variant: ``svrg``
(exact full gradient as the control-variate anchor — the quadratic makes
it free) or ``sghmc`` (momentum buffer vmapped across all 32 chains).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers
from repro.cluster import ClusterEngine, ensemble_async, w2_recorder
from repro.core import Quadratic, WorkerModel

CHAINS, WORKERS, COMMITS = 32, 8, 600

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--sampler", choices=("sgld", "svrg", "sghmc"),
                default="sgld", help="zoo preset for the chain ensemble")
args = ap.parse_args()

quad = Quadratic.make(jax.random.PRNGKey(0), d=2, m=1.0, L=3.0)
sigma = 0.5
target = quad.x_star + jnp.sqrt(quad.stationary_cov(sigma)) * jax.random.normal(
    jax.random.PRNGKey(1), (256, quad.d))

# One executable schedule per chain: worker ids, read versions, commit times.
schedules = ensemble_async(WorkerModel(num_workers=WORKERS, seed=0),
                           COMMITS, CHAINS, seed=0)
tau = max(s.max_delay for s in schedules)
print(f"{CHAINS} chains x {WORKERS} workers, realized max staleness {tau}")

grad_fn = lambda p, b: quad.grad(p, b)  # noqa: E731
if args.sampler == "svrg":
    sampler = samplers.svrg("consistent", grad_fn,
                            lambda p: quad.grad(p, None), anchor_every=64,
                            gamma=0.05, sigma=sigma, tau=tau)
elif args.sampler == "sghmc":
    sampler = samplers.sghmc("consistent", grad_fn, gamma=0.05, sigma=sigma,
                             friction=2.0, tau=tau)
else:
    sampler = samplers.sgld("consistent", grad_fn, gamma=0.05, sigma=sigma,
                            tau=tau)
print(f"sampler: {args.sampler}")
w2 = w2_recorder(target, every=50)
engine = ClusterEngine(sampler, num_chains=CHAINS, chunk_size=50, hooks=[w2])

state = engine.init(jnp.zeros(quad.d), jax.random.PRNGKey(2), jitter=2.0)
state, _ = engine.run(state, steps=COMMITS, schedule=schedules)

print(f"{'commit':>7} {'sim wall clock':>14} {'empirical W2':>12}")
for row in w2.record:
    print(f"{row['step']:7d} {row['commit_time']:14.1f} {row['w2']:12.4f}")
print(f"jit traces: {engine.num_traces} (one per distinct chunk length)")

# -- heterogeneous batch policy: slow workers amortize staleness ------------
BASE_BATCH = 8
wm = WorkerModel(num_workers=WORKERS, heterogeneity=0.6, update_cost=0.6,
                 seed=0)
print(f"\nper-worker batch sizes (inverse-speed, base {BASE_BATCH}): "
      f"{wm.batch_sizes('inverse-speed', base_batch=BASE_BATCH).tolist()}")
het_scheds = ensemble_async(wm, COMMITS, CHAINS, seed=0,
                            batch_policy="inverse-speed",
                            base_batch=BASE_BATCH)
het_tau = max(s.max_delay for s in het_scheds)

# a *per-example* oracle: quadratic drift + per-example gradient noise, so
# batch size genuinely trades variance; gamma scales linearly with the batch
per_example = lambda p, e: quad.grad(p, None) + e  # noqa: E731
het_sampler = samplers.sgld("consistent", per_example, gamma=0.02,
                            sigma=sigma, tau=het_tau, base_batch=BASE_BATCH)
data = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (8192, quad.d)),
                  np.float32)

het_w2 = w2_recorder(target, every=50)
het_engine = ClusterEngine(het_sampler, num_chains=CHAINS, chunk_size=50,
                           batch_policy="inverse-speed", hooks=[het_w2])
state = het_engine.init(jnp.zeros(quad.d), jax.random.PRNGKey(2), jitter=2.0)
state, _ = het_engine.run(state, steps=COMMITS, schedule=het_scheds,
                          data=data)

print(f"{'commit':>7} {'grad evals':>11} {'sim wall clock':>14} "
      f"{'empirical W2':>12}")
for row in het_w2.record:
    print(f"{row['step']:7d} {row['grad_evals']:11.0f} "
          f"{row['commit_time']:14.1f} {row['w2']:12.4f}")
print(f"jit traces: {het_engine.num_traces} (one per bucket-ladder rung "
      "per chunk length)")

"""End-to-end driver: train a ~100M-parameter LM with async-SGLD.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --mode pipeline

A GPT-small-scale decoder (12L, d=768, 32k vocab ~ 110M params) trained on
the synthetic token stream for a few hundred steps on CPU, with periodic
checkpointing and a final decode sanity check.  Modes: sync (paper baseline)
/ consistent / inconsistent / pipeline (the beyond-paper overlapped mode).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import SGLDConfig, WorkerModel, simulate_async
from repro.data import make_batch
from repro.models.transformer import Model, init_params
from repro.train import Engine, checkpoint_hook, make_train_step

LM_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    source="GPT-small scale (example driver)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32_000,
    dtype="float32",
    block_pattern=("attn_mlp",),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mode", default="consistent",
                    choices=["sync", "consistent", "inconsistent", "pipeline"])
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--gamma", type=float, default=3e-4)
    ap.add_argument("--sigma", type=float, default=1e-8)
    ap.add_argument("--ckpt", default="/tmp/lm100m.npz")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--chunk", type=int, default=10,
                    help="steps per jitted scan chunk")
    args = ap.parse_args()

    cfg = LM_100M
    shape = ShapeConfig("lm", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    model = Model(cfg, mesh=None)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, mode={args.mode}, "
          f"tokens/step={args.batch * args.seq}")

    sgld = SGLDConfig(
        mode=args.mode, gamma=args.gamma, sigma=args.sigma,
        tau=args.tau if args.mode in ("consistent", "inconsistent") else 0)
    sampler, _ = make_train_step(model, sgld)
    key, init_key = jax.random.split(key)
    state = sampler.init(params, init_key)

    delays = None
    if args.mode in ("consistent", "inconsistent"):
        tr = simulate_async(WorkerModel(num_workers=8, seed=0), args.steps,
                            seed=0)
        delays = np.minimum(tr.delays, args.tau)
        print(f"delay trace: mean {tr.mean_delay:.1f} max {tr.max_delay}")

    t0 = time.time()

    last_log = [-args.log_every]

    def tok_log(step_end, _state, aux):
        if step_end - last_log[0] < args.log_every and step_end != args.steps:
            return
        last_log[0] = step_end
        loss = float(np.asarray(aux["loss"])[-1])
        tps = args.batch * args.seq * step_end / (time.time() - t0)
        print(f"step {step_end - 1:4d}  loss {loss:7.4f}  "
              f"{tps:,.0f} tok/s  ({time.time()-t0:5.1f}s)", flush=True)

    hooks = [tok_log]
    if args.ckpt:
        hooks.append(checkpoint_hook(args.ckpt, every=100))
    engine = Engine(sampler, batch_fn=lambda k: make_batch(cfg, shape, k, "train"),
                    chunk_size=args.chunk, hooks=hooks)
    state, metrics = engine.run(state, steps=args.steps, delays=delays, key=key)
    losses = np.asarray(metrics["loss"])

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params, step=args.steps)
        print("checkpoint:", args.ckpt)

    # decode sanity check
    cache = model.init_cache(1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    sampled = []
    for t in range(8):
        logits, cache = jax.jit(model.serve_step)(state.params, cache, tok,
                                                  jnp.int32(t))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        sampled.append(int(tok[0, 0]))
    print("greedy decode:", sampled)


if __name__ == "__main__":
    main()

"""Paper §3.2 reproduction: polynomial regression, Sync vs W-Con vs W-Icon.

    PYTHONPATH=src python examples/regression_sgld.py [--P 18] [--nu 0.1]

Reproduces Figure 1/2/3-style panels: (a) W2 to the posterior vs commits,
(b) W2 vs simulated wall clock + relative speedup, (c) the trajectory of the
first two coordinates.  Saves PNGs next to this script if matplotlib is
available, and always prints the summary table.
"""

import argparse
import os


from repro.experiments import run_regression_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--P", type=int, default=18)
    ap.add_argument("--nu", type=float, default=0.1)
    ap.add_argument("--steps", type=int, default=6000)
    args = ap.parse_args()

    res = run_regression_experiment(P=args.P, nu=args.nu, steps=args.steps)
    print(f"\npolynomial regression, P={args.P} workers, nu={args.nu}")
    print(f"{'scheme':14s} {'final W2':>10s} {'speedup':>8s}")
    label = {"sync": "Sync", "consistent": "W-Con", "inconsistent": "W-Icon"}
    for mode, c in res.items():
        print(f"{label[mode]:14s} {c.w2[-1]:10.4f} {c.speedup:8.2f}x")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("matplotlib not available — skipping plots")
        return

    fig, axes = plt.subplots(1, 3, figsize=(15, 4))
    for mode, c in res.items():
        axes[0].semilogy(c.iters, c.w2, label=label[mode])
        axes[1].semilogy(c.times, c.w2, label=label[mode])
        axes[2].plot(c.traj2d[::10, 0], c.traj2d[::10, 1], ".",
                     ms=2, alpha=0.5, label=label[mode])
    axes[0].set(xlabel="commits", ylabel="W2(x_t, posterior)",
                title=f"(a) convergence / iteration, P={args.P}")
    axes[1].set(xlabel="simulated wall clock",
                title="(b) convergence / time")
    axes[2].set(xlabel="x[0]", ylabel="x[1]", title="(c) trajectory")
    for ax in axes:
        ax.legend()
    out = os.path.join(os.path.dirname(__file__),
                       f"regression_P{args.P}_nu{args.nu}.png")
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print("wrote", out)


if __name__ == "__main__":
    main()

"""Paper §3.3 reproduction: Reconstruction ICA under async SGLD.

    PYTHONPATH=src python examples/rica_patches.py [--P 4] [--nu 0.01]

The paper ran RICA on CIFAR-10 patches on a GPU with MPS concurrency
(P in {2,4,8}); offline we use seeded 1/f synthetic patches and the M2-like
worker model (DESIGN.md §2).  Prints the objective / distance-to-optimum
table and saves the figure if matplotlib is present.
"""

import argparse
import os

from repro.experiments import run_rica_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--P", type=int, default=4)
    ap.add_argument("--nu", type=float, default=0.01)
    ap.add_argument("--steps", type=int, default=600)
    args = ap.parse_args()

    res = run_rica_experiment(P=args.P, nu=args.nu, steps=args.steps)
    label = {"sync": "Sync", "consistent": "W-Con", "inconsistent": "W-Icon"}
    print(f"\nRICA, P={args.P} concurrent processes, nu={args.nu}")
    print(f"{'scheme':9s} {'objective':>10s} {'dist(opt)':>10s} {'speedup':>8s}")
    for mode, c in res.items():
        print(f"{label[mode]:9s} {c.objective[-1]:10.3f} "
              f"{c.dist_to_opt[-1]:10.3f} {c.speedup:8.2f}x")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return
    fig, axes = plt.subplots(1, 3, figsize=(15, 4))
    for mode, c in res.items():
        axes[0].plot(c.iters, c.objective, label=label[mode])
        axes[1].plot(c.times, c.objective, label=label[mode])
        axes[2].plot(c.iters, c.dist_to_opt, label=label[mode])
    axes[0].set(xlabel="commits", ylabel="RICA objective",
                title=f"(a) objective / iteration, P={args.P}")
    axes[1].set(xlabel="simulated wall clock", title="(b) objective / time")
    axes[2].set(xlabel="commits", ylabel="||W - W*||_F",
                title="(c) distance to SGLD optimum")
    for ax in axes:
        ax.legend()
    out = os.path.join(os.path.dirname(__file__),
                       f"rica_P{args.P}_nu{args.nu}.png")
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print("wrote", out)


if __name__ == "__main__":
    main()

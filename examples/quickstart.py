"""Quickstart: async-SGLD (the paper's algorithm) on a tiny decoder LM.

    PYTHONPATH=src python examples/quickstart.py

Trains a reduced qwen3-style model for 30 steps with the W-Con (consistent
stale read) sampler using delays from the virtual-worker simulator, then
decodes a few tokens through the KV cache — the whole public API in ~60
lines.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_reduced
from repro.core import SGLDConfig, WorkerModel, simulate_async
from repro.data import make_batch
from repro.models.transformer import Model, init_params
from repro.train.loop import make_train_step

ARCH = "qwen3-4b"
STEPS = 30

cfg = get_reduced(ARCH)
shape = ShapeConfig("quickstart", seq_len=128, global_batch=8, kind="train")
model = Model(cfg, mesh=None)

key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
print(f"{cfg.name}: {n/1e6:.1f}M params")

# The paper's W-Con sampler: stale whole-vector reads with delays from the
# event-driven virtual-worker model (8 asynchronous workers).
sgld = SGLDConfig(mode="consistent", gamma=5e-4, sigma=1e-7, tau=4)
trace = simulate_async(WorkerModel(num_workers=8, seed=0), STEPS, seed=0)
delays = np.minimum(trace.delays, 4)
print(f"simulated delays: mean {trace.mean_delay:.1f}, max {trace.max_delay}")

sampler, step_fn = make_train_step(model, sgld)
state = sampler.init(params, key)
jstep = jax.jit(step_fn)
for k in range(STEPS):
    key, bk = jax.random.split(key)
    batch = make_batch(cfg, shape, bk, "train")
    state, metrics = jstep(state, batch, int(delays[k]))
    if k % 5 == 0 or k == STEPS - 1:
        print(f"step {k:3d}  loss {float(metrics['loss']):.4f}  "
              f"delay {int(delays[k])}")

# decode a few tokens greedily from the sampled posterior weights
tokens = jnp.zeros((1, 1), jnp.int32)
cache = model.init_cache(1, 32)
out = []
for t in range(8):
    logits, cache = jax.jit(model.serve_step)(state.params, cache, tokens,
                                              jnp.int32(t))
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out.append(int(tokens[0, 0]))
print("greedy sample:", out)

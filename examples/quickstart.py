"""Quickstart: async-SGLD (the paper's algorithm) on a tiny decoder LM.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --sampler sghmc

Trains a reduced qwen3-style model for 30 steps with the W-Con (consistent
stale read) sampler — built from the composable ``repro.samplers`` API and
driven by the scan-chunked Engine — using delays from the virtual-worker
simulator, then decodes a few tokens through the KV cache.  The whole
public API in ~60 lines.  ``--sampler`` swaps in the zoo variants: ``svrg``
(variance-reduced oracle anchored on a fixed reference batch) or ``sghmc``
(underdamped momentum chain) — same Engine, same schedule, same delays.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers
from repro.configs import ShapeConfig, get_reduced
from repro.core import WorkerModel, simulate_async
from repro.data import make_batch
from repro.models.transformer import Model, init_params
from repro.train import Engine, log_hook
from repro.train.loop import make_grad_fn

ARCH = "qwen3-4b"
STEPS = 30

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--sampler", choices=("sgld", "svrg", "sghmc"),
                default="sgld", help="which zoo preset drives the chain")
args = ap.parse_args()

cfg = get_reduced(ARCH)
shape = ShapeConfig("quickstart", seq_len=128, global_batch=8, kind="train")
model = Model(cfg, mesh=None)

key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
print(f"{cfg.name}: {n/1e6:.1f}M params")

# The paper's W-Con sampler: stale whole-vector reads with delays from the
# event-driven virtual-worker model (8 asynchronous workers).  The sgld
# preset expands to chain(delay_read(TraceDelay(4)), gradients(...),
# langevin_noise(1e-7), apply_sgld_update()); the zoo variants swap the
# gradient stage (svrg) or the commit stage (sghmc) and nothing else.
grad_fn = make_grad_fn(model)
if args.sampler == "svrg":
    # anchor the control variate on one fixed reference batch — the LM data
    # stream is synthetic, so a pinned batch stands in for "the full data"
    anchor_batch = make_batch(cfg, shape, jax.random.PRNGKey(42), "train")
    sampler = samplers.svrg("consistent", grad_fn,
                            lambda p: grad_fn(p, anchor_batch)[0],
                            anchor_every=10, gamma=5e-4, sigma=1e-7, tau=4,
                            has_aux=True)
elif args.sampler == "sghmc":
    sampler = samplers.sghmc("consistent", grad_fn, gamma=5e-4, sigma=1e-7,
                             friction=2.0, tau=4, has_aux=True)
else:
    sampler = samplers.sgld("consistent", grad_fn, gamma=5e-4,
                            sigma=1e-7, tau=4, has_aux=True)
print(f"sampler: {args.sampler}")
trace = simulate_async(WorkerModel(num_workers=8, seed=0), STEPS, seed=0)
delays = np.minimum(trace.delays, 4)
print(f"simulated delays: mean {trace.mean_delay:.1f}, max {trace.max_delay}")

key, init_key = jax.random.split(key)
state = sampler.init(params, init_key)
engine = Engine(sampler, batch_fn=lambda k: make_batch(cfg, shape, k, "train"),
                chunk_size=5, hooks=[log_hook(every=5)])
state, metrics = engine.run(state, steps=STEPS, delays=delays, key=key)
print(f"final loss {float(metrics['loss'][-1]):.4f}")

# decode a few tokens greedily from the sampled posterior weights
tokens = jnp.zeros((1, 1), jnp.int32)
cache = model.init_cache(1, 32)
out = []
for t in range(8):
    logits, cache = jax.jit(model.serve_step)(state.params, cache, tokens,
                                              jnp.int32(t))
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out.append(int(tokens[0, 0]))
print("greedy sample:", out)

"""cluster.serve quickstart: train a chain bank, checkpoint it, serve
posterior-predictive intervals from the restored bank.

    PYTHONPATH=src python examples/serve_quickstart.py

A 32-chain async-SGLD ensemble samples the paper's polynomial-regression
posterior (each chain replaying its own P-worker asynchronous schedule),
the bank is exported with ``ClusterEngine.save_ensemble``, restored with
``ServeEngine.from_checkpoint``, and queried: ensemble-averaged predictions
with 90% credible intervals, checked against the closed-form Gaussian
posterior predictive.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers
from repro.cluster import ClusterEngine, ServeEngine, ensemble_async
from repro.core import PolyRegression, WorkerModel
from repro.models import regression_predict

CHAINS, WORKERS, COMMITS = 32, 8, 4000
GAMMA, SIGMA, BATCH = 2e-4, 1e-3, 256

reg = PolyRegression.make(jax.random.PRNGKey(0), nu_std=0.1)
mu, cov, _ = reg.posterior_moments(sigma=SIGMA)

# -- train: every chain replays its own asynchronous P-worker execution -----
schedules = ensemble_async(WorkerModel(num_workers=WORKERS, seed=0),
                           COMMITS, CHAINS, seed=0)
tau = max(s.max_delay for s in schedules)
sampler = samplers.sgld("consistent", lambda w, b: reg.grad(w, b),
                        gamma=GAMMA, sigma=SIGMA, tau=tau)
engine = ClusterEngine(sampler, num_chains=CHAINS, chunk_size=500,
                       batch_fn=lambda k: reg.sample_batch(k, BATCH))
state = engine.init(mu, jax.random.PRNGKey(1), jitter=0.05)
state, _ = engine.run(state, steps=COMMITS, schedule=schedules,
                      key=jax.random.PRNGKey(2))
print(f"trained {CHAINS} chains x {COMMITS} commits "
      f"(P={WORKERS}, realized max staleness {tau})")

# -- checkpoint the bank, restore it into a ServeEngine ---------------------
path = os.path.join(tempfile.mkdtemp(), "bank.npz")
engine.save_ensemble(state, path)
serve = ServeEngine.from_checkpoint(path, like=jnp.zeros(reg.d),
                                    predict_fn=regression_predict(reg),
                                    quantiles=(0.05, 0.5, 0.95))
print(f"restored {serve.num_chains}-chain bank from {path}")

# -- serve: predictive mean + 90% credible interval vs. closed form ---------
zs = jnp.linspace(-1.0, 1.0, 9)
res = serve(zs)

psi = np.concatenate([np.asarray(reg.features(zs)), np.ones((9, 1))], axis=1)
cf_mean = psi @ np.asarray(mu)
cf_std = np.sqrt(np.einsum("qi,ij,qj->q", psi, np.asarray(cov), psi))

print(f"{'z':>6} {'mean':>8} {'90% interval':>20} {'closed-form mean':>17} "
      f"{'+-1.645 std':>12}")
for i, z in enumerate(np.asarray(zs)):
    lo, hi = float(res.quantiles[0, i]), float(res.quantiles[-1, i])
    print(f"{z:6.2f} {float(res.mean[i]):8.3f} "
          f"{'[' + f'{lo:7.3f}, {hi:7.3f}' + ']':>20} "
          f"{cf_mean[i]:17.3f} {1.645 * cf_std[i]:12.3f}")
print(f"jit traces: {serve.num_traces} (one per shape bucket)")

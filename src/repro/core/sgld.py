"""Delayed-gradient SGLD — the paper's algorithm as a composable JAX sampler.

Update rule (paper eq. (4)):

    X_{k+1} = X_k - gamma_k * grad U(X_hat_k) + sqrt(2 sigma gamma_k) * G_k

with four read models for ``X_hat_k``:

- ``sync``         X_hat = X_k (paper's **Sync**: barrier + summed gradients —
                   the standard data-parallel baseline; tau = 0).
- ``consistent``   X_hat = X_{k - tau_k} whole-vector stale read (**W-Con**).
- ``inconsistent`` [X_hat]_i = [X_{s_i}]_i per-coordinate stale read
                   (**W-Icon**, Assumption 2.3).
- ``pipeline``     X_{k+1} = X_k - gamma * AllReduce(grad U(X_{k-1})) + noise:
                   the beyond-paper production mode — tau = 1 W-Con whose
                   gradient all-reduce overlaps the next step's compute.

Everything operates on arbitrary pytrees, jits cleanly, and shards
transparently (the update is elementwise so it follows the parameter
sharding; Langevin noise is generated shard-locally).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import delay as delay_lib
from repro.core.schedules import Schedule, constant
from repro.utils import tree_keys, tree_zeros_like

PyTree = Any
GradFn = Callable[..., PyTree]  # grad_fn(params, batch) -> pytree of grads


@dataclass(frozen=True)
class SGLDConfig:
    mode: str = "sync"  # sync | consistent | inconsistent | pipeline
    gamma: float | Schedule = 1e-2
    sigma: float = 1.0  # temperature (paper's sigma; nu^2 of injected noise)
    tau: int = 0        # max delay == ring depth - 1 (consistent/inconsistent)
    noise_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.mode not in ("sync", "consistent", "inconsistent", "pipeline"):
            raise ValueError(f"unknown SGLD mode {self.mode!r}")
        if self.mode in ("consistent", "inconsistent") and self.tau < 1:
            raise ValueError(f"mode {self.mode!r} needs tau >= 1")

    def gamma_at(self, step: jnp.ndarray) -> jnp.ndarray:
        if callable(self.gamma):
            return self.gamma(step)
        return jnp.asarray(self.gamma, jnp.float32)


class SGLDState(NamedTuple):
    params: PyTree
    step: jax.Array                       # int32
    key: jax.Array                        # PRNG key
    ring: Optional[delay_lib.RingBuffer]  # consistent / inconsistent modes
    pending_grad: Optional[PyTree]        # pipeline mode


def langevin_noise(key: jax.Array, params: PyTree, scale: jnp.ndarray, dtype) -> PyTree:
    """sqrt(2 sigma gamma) * G_k, one independent key per leaf, shard-local."""
    keytree = tree_keys(key, params)
    return jax.tree_util.tree_map(
        lambda k, p: (scale * jax.random.normal(k, jnp.shape(p), dtype)).astype(p.dtype),
        keytree,
        params,
    )


def apply_update(params: PyTree, grads: PyTree, gamma: jnp.ndarray, noise: PyTree) -> PyTree:
    """x - gamma*g + noise, leafwise (the fused Pallas path lives in kernels/)."""
    return jax.tree_util.tree_map(
        lambda p, g, n: (p - gamma.astype(p.dtype) * g.astype(p.dtype) + n).astype(p.dtype),
        params,
        grads,
        noise,
    )


class SGLDSampler:
    """Stateless-functional sampler; hold an instance, thread SGLDState.

    ``grad_fn(params, batch)`` may return either a gradient pytree or a
    ``(grads, aux)`` tuple; aux (e.g. the loss) is surfaced by ``step``.
    """

    def __init__(self, config: SGLDConfig, grad_fn: GradFn, has_aux: bool = False):
        self.config = config
        self.grad_fn = grad_fn
        self.has_aux = has_aux

    def _grads(self, params, batch):
        out = self.grad_fn(params, batch)
        if self.has_aux:
            return out
        return out, None

    # -- init ---------------------------------------------------------------
    def init(self, params: PyTree, key: jax.Array) -> SGLDState:
        cfg = self.config
        ring = None
        pending = None
        if cfg.mode in ("consistent", "inconsistent"):
            ring = delay_lib.init_ring(params, cfg.tau)
        elif cfg.mode == "pipeline":
            pending = tree_zeros_like(params)
        return SGLDState(params=params, step=jnp.int32(0), key=key, ring=ring,
                         pending_grad=pending)

    # -- one update ----------------------------------------------------------
    def step(self, state: SGLDState, batch, delay_k: jax.Array | int = 0):
        """One SGLD commit.  ``delay_k`` is the realized staleness for this
        commit (from a DelayTrace); ignored by sync/pipeline modes.
        Returns (new_state, aux)."""
        cfg = self.config
        key, k_noise, k_delay = jax.random.split(state.key, 3)
        gamma = cfg.gamma_at(state.step)
        scale = jnp.sqrt(2.0 * cfg.sigma * gamma)
        noise = langevin_noise(k_noise, state.params, scale, cfg.noise_dtype)
        delay_k = jnp.asarray(delay_k, jnp.int32)

        if cfg.mode == "sync":
            grads, aux = self._grads(state.params, batch)
            params = apply_update(state.params, grads, gamma, noise)
            return SGLDState(params, state.step + 1, key, None, None), aux

        if cfg.mode == "pipeline":
            new_grad, aux = self._grads(state.params, batch)
            # Apply the PREVIOUS step's (already all-reduced) gradient: tau=1
            # W-Con. new_grad's all-reduce has no consumer this step -> XLA
            # overlaps it with the next step's compute.
            params = apply_update(state.params, state.pending_grad, gamma, noise)
            return SGLDState(params, state.step + 1, key, None, new_grad), aux

        ring = state.ring
        if cfg.mode == "consistent":
            x_hat = delay_lib.read_consistent(ring, delay_k)
        else:  # inconsistent
            delays = delay_lib.sample_coordinate_delays(k_delay, ring, delay_k)
            x_hat = delay_lib.read_inconsistent(ring, delays)
        grads, aux = self._grads(x_hat, batch)
        params = apply_update(state.params, grads, gamma, noise)
        ring = delay_lib.push(ring, params)
        return SGLDState(params, state.step + 1, key, ring, None), aux

    # -- a jit-compiled multi-step runner -------------------------------------
    def run(self, state: SGLDState, batches, delays, *, collect: bool = True):
        """lax.scan over pre-generated (batches, delays); returns final state
        and (optionally) the iterate trajectory stacked on axis 0."""

        def body(s, inp):
            batch, d = inp
            s, _ = self.step(s, batch, d)
            out = s.params if collect else None
            return s, out

        return jax.lax.scan(body, state, (batches, delays))


def make_minibatch_grad(potential, batch_size: int):
    """grad U from a potential object (autodiff through potential.value)."""

    def grad_fn(params, batch):
        return jax.grad(potential.value)(params, batch)

    return grad_fn

"""Deprecated string-dispatched SGLD front end — use :mod:`repro.samplers`.

``SGLDSampler`` is now a thin shim over the composable sampler-transform
API: ``SGLDConfig(mode=...)`` maps one-to-one onto the
``samplers.sgld(mode=...)`` presets (see the README migration table), and
the trajectories are bit-identical because both front ends share the same
leafwise math (``repro.samplers.transforms``).

Update rule (paper eq. (4)):

    X_{k+1} = X_k - gamma_k * grad U(X_hat_k) + sqrt(2 sigma gamma_k) * G_k

with four read models for ``X_hat_k``: ``sync`` (X_hat = X_k), ``consistent``
(W-Con whole-vector stale read), ``inconsistent`` (W-Icon per-coordinate
read), ``pipeline`` (previous gradient; its all-reduce overlaps the next
step's compute).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.schedules import Schedule
# The leafwise update math moved to the composable API; these aliases keep
# the historical import sites (launch/steps.py, benchmarks) working.
from repro.samplers.base import Sampler, SamplerState
from repro.samplers.transforms import noise_like as langevin_noise  # noqa: F401
from repro.samplers.transforms import sgld_apply as apply_update  # noqa: F401

PyTree = Any
GradFn = Callable[..., PyTree]  # grad_fn(params, batch) -> pytree of grads

#: Deprecated alias — the driver state no longer special-cases ring buffers
#: or pending gradients; transform state lives in ``state.inner``.
SGLDState = SamplerState


@dataclass(frozen=True)
class SGLDConfig:
    mode: str = "sync"  # sync | consistent | inconsistent | pipeline
    gamma: float | Schedule = 1e-2
    sigma: float = 1.0  # temperature (paper's sigma; nu^2 of injected noise)
    tau: int = 0        # max delay == ring depth - 1 (consistent/inconsistent)
    noise_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.mode not in ("sync", "consistent", "inconsistent", "pipeline"):
            raise ValueError(f"unknown SGLD mode {self.mode!r}")
        if self.mode in ("consistent", "inconsistent") and self.tau < 1:
            raise ValueError(f"mode {self.mode!r} needs tau >= 1")

    def gamma_at(self, step: jnp.ndarray) -> jnp.ndarray:
        if callable(self.gamma):
            return self.gamma(step)
        return jnp.asarray(self.gamma, jnp.float32)


class SGLDSampler:
    """Deprecated shim: delegates to ``repro.samplers.sgld(mode=...)``.

    ``grad_fn(params, batch)`` may return either a gradient pytree or a
    ``(grads, aux)`` tuple; aux (e.g. the loss) is surfaced by ``step``.
    """

    def __init__(self, config: SGLDConfig, grad_fn: GradFn, has_aux: bool = False):
        warnings.warn(
            "SGLDSampler is deprecated; build the equivalent preset with "
            "repro.samplers.sgld(mode=...) (or compose transforms with "
            "repro.samplers.chain).",
            DeprecationWarning, stacklevel=2)
        from repro.samplers.presets import from_config  # lazy: import cycle

        self.config = config
        self.grad_fn = grad_fn
        self.has_aux = has_aux
        self._sampler: Sampler = from_config(config, grad_fn, has_aux)

    # -- delegation ----------------------------------------------------------
    def init(self, params: PyTree, key: jax.Array) -> SamplerState:
        return self._sampler.init(params, key)

    def step(self, state: SamplerState, batch, delay_k: jax.Array | int = 0):
        """One SGLD commit; ``delay_k`` is the realized staleness tau_k."""
        return self._sampler.step(state, batch, delay_k)

    def run(self, state: SamplerState, batches, delays, *, collect: bool = True):
        return self._sampler.run(state, batches, delays, collect=collect)


def make_minibatch_grad(potential):
    """grad U from a potential object (autodiff through potential.value)."""

    def grad_fn(params, batch):
        return jax.grad(potential.value)(params, batch)

    return grad_fn

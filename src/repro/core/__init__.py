"""Core: the paper's contribution — delayed-gradient SGLD and its theory."""

from repro.core.delay import (  # noqa: F401
    RingBuffer,
    StalenessError,
    check_staleness_fits,
    init_ring,
    push,
    read_consistent,
    read_inconsistent,
    ring_depths,
    sample_coordinate_delays,
    validate_staleness,
)
from repro.core.delay_model import (  # noqa: F401
    BATCH_POLICIES,
    DelayTrace,
    FaultPlan,
    WorkerModel,
    constant_delays,
    simulate_async,
    simulate_sync,
    speedup_vs_sync,
    truncate_to_evals,
)
from repro.core.potentials import PolyRegression, Quadratic, RICA  # noqa: F401
from repro.core.schedules import clip_to_theory, constant, poly_decay, wsd  # noqa: F401
from repro.core.sgld import SGLDConfig, SGLDSampler, SGLDState  # noqa: F401
from repro.core.theory import (  # noqa: F401
    ProblemConstants,
    gamma_eps_kl,
    gamma_eps_w2,
    gamma_terms,
    n_eps_kl,
    n_eps_w2,
)

"""Step-size schedules: constant, polynomial decay, warmup, WSD.

WSD (warmup-stable-decay) is included because the assigned ``minicpm-2b``
architecture is defined by it [arXiv:2404.06395]; all schedules compose with
the Corollary 2.1 ceiling (``clip_to_theory``).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(value: float) -> Schedule:
    return lambda step: jnp.full_like(jnp.asarray(step, jnp.float32), value)


def poly_decay(gamma0: float, alpha: float = 0.5, t0: float = 1.0) -> Schedule:
    """gamma_k = gamma0 / (t0 + k)^alpha — the classic SGLD decreasing schedule."""
    return lambda step: gamma0 / (t0 + jnp.asarray(step, jnp.float32)) ** alpha


def linear_warmup(base: Schedule, warmup_steps: int) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        scale = jnp.minimum(1.0, (step + 1.0) / max(warmup_steps, 1))
        return scale * base(step)

    return sched


def wsd(peak: float, warmup_steps: int, stable_steps: int, decay_steps: int,
        final_frac: float = 0.1) -> Schedule:
    """Warmup-Stable-Decay (MiniCPM)."""

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * (step + 1.0) / max(warmup_steps, 1)
        in_decay = jnp.clip((step - warmup_steps - stable_steps) / max(decay_steps, 1), 0.0, 1.0)
        decay = peak * (1.0 - (1.0 - final_frac) * in_decay)
        return jnp.where(step < warmup_steps, warm, decay)

    return sched


def clip_to_theory(base: Schedule, gamma_max: float) -> Schedule:
    """Enforce the Corollary 2.1 ceiling on any schedule."""
    return lambda step: jnp.minimum(base(step), gamma_max)

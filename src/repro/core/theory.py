"""Corollary 2.1 — theory-prescribed step sizes and iteration counts.

These are the paper's explicit constants; the tau-sweep benchmark checks that
running SGLD at (gamma_eps, n_eps) actually lands inside the epsilon ball,
and that the tau-dependence of n_eps follows the predicted polynomial growth.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ProblemConstants:
    m: float      # strong convexity
    L: float      # gradient Lipschitz
    d: int        # dimension
    G: float      # E||grad U|| bound (Assumption 2.2)
    sigma: float  # temperature
    tau: int      # max delay
    w2sq_0: float = 1.0  # W2^2(mu_0, pi) initial distance estimate


def gamma_terms(c: ProblemConstants, eps: float) -> dict[str, float]:
    """The six step-size ceilings of Corollary 2.1."""
    m, L, d, G, sigma, tau = c.m, c.L, c.d, c.G, c.sigma, c.tau
    g1 = eps / (L * d + L**2 * tau**2 * sigma)
    g2 = math.sqrt(eps) / ((L + L**2 + tau**2 * L**2) * G**2)
    g3 = math.sqrt(eps) * m / (L * max(tau, 1) * G)
    g4 = eps ** (2.0 / 3.0) / (
        2 * sigma / (1.65 * L + math.sqrt(sigma) * math.sqrt(m))
        + 1.65 * (L / m)
        + tau * L * math.sqrt(sigma) / m
    )
    g5 = L**2 / (L**2 + L**4)
    g6 = 1.0 / 12.0
    return {"g1": g1, "g2": g2, "g3": g3, "g4": g4, "g5": g5, "g6": g6}


def gamma_eps_kl(c: ProblemConstants, eps: float) -> float:
    """Step size guaranteeing KL(nu_n | pi) <= eps."""
    return min(gamma_terms(c, eps).values()) / 4.0


def n_eps_kl(c: ProblemConstants, eps: float) -> int:
    g = gamma_eps_kl(c, eps)
    return 2 * max(math.ceil(c.w2sq_0 / (g * eps)), c.tau)


def gamma_eps_w2(c: ProblemConstants, eps: float) -> float:
    """Step size guaranteeing W2^2(mu_0 R^n, pi) <= eps."""
    return c.m * min(gamma_terms(c, eps).values()) / 8.0


def n_eps_w2(c: ProblemConstants, eps: float) -> int:
    g = gamma_eps_w2(c, eps)
    n = 2 * max(
        math.ceil(math.log(4.0 * c.w2sq_0 / eps) / (g * c.m)),
        math.ceil(math.log(max(c.tau, 2))),
    )
    return n


def inconsistent_read_bias(c: ProblemConstants, gamma: float) -> float:
    """Gradient inaccuracy bias used in the Cor. 2.1 proof (via [3] Thm 4):

    ||grad U(X_k) - grad U(X_hat_k)|| <= L tau (gamma G + sqrt(gamma sigma)).
    """
    return c.L * c.tau * (gamma * c.G + math.sqrt(gamma * c.sigma))

"""Potentials U for the paper's experiments and for theory validation.

The SGLD target is the Gibbs measure pi(x) ∝ exp(-U(x)/sigma) (eq. (1)-(2)
of the paper with temperature sigma).  Each potential exposes:

  - ``value(params, batch)``     full/minibatch potential
  - ``grad(params, batch)``      stochastic gradient (autodiff)
  - ``sample_batch(key, n)``     draw a data minibatch
  - strong-convexity / Lipschitz constants ``m``, ``L`` where defined
    (quadratic and regression; RICA is non-convex — the paper runs it
    anyway, outside the theory, and so do we).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Quadratic potential — closed-form stationary distribution, used by tests
# and the tau-sweep theory benchmark.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Quadratic:
    """U(x) = 1/2 (x - x*)^T A (x - x*), A diagonal SPD.

    Langevin dX = -∇U dt + sqrt(2 sigma) dB has stationary N(x*, sigma A^-1).
    Stochastic gradients add N(0, grad_noise^2 I).
    """

    x_star: jnp.ndarray
    diag: jnp.ndarray
    grad_noise: float = 0.0

    @property
    def d(self) -> int:
        return int(self.x_star.shape[0])

    @property
    def m(self) -> float:
        return float(jnp.min(self.diag))

    @property
    def L(self) -> float:
        return float(jnp.max(self.diag))

    def value(self, x: jnp.ndarray, batch=None) -> jnp.ndarray:
        r = x - self.x_star
        return 0.5 * jnp.sum(self.diag * r * r)

    def grad(self, x: jnp.ndarray, batch=None, *, key=None) -> jnp.ndarray:
        g = self.diag * (x - self.x_star)
        if self.grad_noise > 0.0 and key is not None:
            g = g + self.grad_noise * jax.random.normal(key, g.shape)
        return g

    def sample_batch(self, key, n: int):
        return None

    def stationary_cov(self, sigma: float) -> jnp.ndarray:
        return sigma / self.diag

    @staticmethod
    def make(key, d: int, m: float = 0.5, L: float = 2.0, grad_noise: float = 0.0) -> "Quadratic":
        k1, k2 = jax.random.split(key)
        x_star = jax.random.normal(k1, (d,))
        if d == 1:
            diag = jnp.full((1,), m)
        else:
            diag = jnp.concatenate([
                jnp.array([m, L]),
                jax.random.uniform(k2, (d - 2,), minval=m, maxval=L),
            ]) if d >= 2 else jnp.full((d,), m)
        return Quadratic(x_star=x_star, diag=diag, grad_noise=grad_noise)


# ---------------------------------------------------------------------------
# Polynomial regression — paper §3.2.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PolyRegression:
    """Bayesian linear regression on phi(z) = [z, z^2, z^3, z^4] (+ bias).

    The paper: "a single linear layer with 4 input features and an output
    feature implementing a 4th degree polynomial regression", observation
    noise nu ~ N(0, nu_std^2), essentially infinite data (generated on the
    fly from the true polynomial).

    U(w) = N/(2 nu^2) E_batch[(w·phi + b - y)^2] + prior_prec/2 ||w||^2
    taken per-example (N=1 scaling) so that m, L are batch-independent.
    """

    true_coef: jnp.ndarray          # (4,)
    true_bias: float
    nu_std: float = 0.1
    prior_prec: float = 1.0
    z_scale: float = 1.0

    @property
    def d(self) -> int:
        return 5

    def features(self, z: jnp.ndarray) -> jnp.ndarray:
        return jnp.stack([z, z**2, z**3, z**4], axis=-1)

    def predict(self, w: jnp.ndarray, phi: jnp.ndarray) -> jnp.ndarray:
        """Model forward on feature rows: ``phi @ coef + bias`` — the single
        spelling of the w = [coef, bias] layout, shared by the training
        potential and the serving path."""
        return phi @ w[:4] + w[4]

    def sample_batch(self, key, n: int):
        kz, ke = jax.random.split(key)
        z = self.z_scale * jax.random.uniform(kz, (n,), minval=-1.0, maxval=1.0)
        phi = self.features(z)
        y = phi @ self.true_coef + self.true_bias + self.nu_std * jax.random.normal(ke, (n,))
        return phi, y

    def value(self, w: jnp.ndarray, batch) -> jnp.ndarray:
        phi, y = batch
        pred = self.predict(w, phi)
        fit = 0.5 / (self.nu_std**2) * jnp.mean((pred - y) ** 2)
        return fit + 0.5 * self.prior_prec * jnp.sum(w * w)

    def grad(self, w: jnp.ndarray, batch, *, key=None) -> jnp.ndarray:
        return jax.grad(self.value)(w, batch)

    def posterior_moments(self, num: int = 200_000, seed: int = 0, sigma: float = 1.0):
        """Gaussian posterior N(mu, sigma * Sigma) for the *per-example* U.

        U(w) = 1/(2 nu^2) E[(w·psi - y)^2] + prior/2 ||w||^2 with
        psi = [phi, 1]; quadratic in w with Hessian
        A = E[psi psi^T]/nu^2 + prior*I, so pi ∝ exp(-U/sigma) is
        N(A^-1 b, sigma A^-1).
        """
        rng = np.random.default_rng(seed)
        z = self.z_scale * rng.uniform(-1.0, 1.0, num)
        psi = np.stack([z, z**2, z**3, z**4, np.ones_like(z)], axis=-1)
        y = (
            psi[:, :4] @ np.asarray(self.true_coef)
            + self.true_bias
            + self.nu_std * rng.normal(size=num)
        )
        A = (psi.T @ psi) / num / self.nu_std**2 + self.prior_prec * np.eye(5)
        b = (psi.T @ y) / num / self.nu_std**2
        mu = np.linalg.solve(A, b)
        cov = sigma * np.linalg.inv(A)
        return jnp.asarray(mu), jnp.asarray(cov), jnp.asarray(A)

    def constants(self) -> tuple[float, float]:
        """(m, L) of the per-example expected potential."""
        _, _, A = self.posterior_moments(num=100_000)
        ev = np.linalg.eigvalsh(np.asarray(A))
        return float(ev[0]), float(ev[-1])

    @staticmethod
    def make(key, nu_std: float = 0.1) -> "PolyRegression":
        k1, k2 = jax.random.split(key)
        coef = jax.random.normal(k1, (4,))
        bias = float(jax.random.normal(k2, ()))
        return PolyRegression(true_coef=coef, true_bias=bias, nu_std=nu_std)


# ---------------------------------------------------------------------------
# Reconstruction ICA — paper §3.3 (non-convex; outside the theory, as in the
# paper).  min_W  lambda ||W x||_1 + 1/2 ||W^T W x - x||^2.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RICA:
    """RICA on image patches.  W has shape (num_features, patch_dim)."""

    patch_dim: int
    num_features: int
    lam: float = 0.4
    _spectrum: np.ndarray = field(default=None, repr=False, compare=False)

    @property
    def d(self) -> int:
        return self.num_features * self.patch_dim

    def init_params(self, key) -> jnp.ndarray:
        w = jax.random.normal(key, (self.num_features, self.patch_dim))
        return w / jnp.linalg.norm(w, axis=1, keepdims=True)

    def sample_batch(self, key, n: int) -> jnp.ndarray:
        """Synthetic natural-image-statistics patches: 1/f spectrum.

        Offline stand-in for CIFAR-10 (no dataset downloads in this
        container) — documented in DESIGN.md §2.
        """
        side = int(math.isqrt(self.patch_dim))
        assert side * side == self.patch_dim, "patch_dim must be a square"
        freq = jnp.fft.fftfreq(side)
        f2 = freq[:, None] ** 2 + freq[None, :] ** 2
        amp = jnp.where(f2 > 0, 1.0 / jnp.sqrt(f2), 0.0)
        phase = jax.random.uniform(key, (n, side, side), minval=0, maxval=2 * jnp.pi)
        spec = amp[None] * jnp.exp(1j * phase)
        img = jnp.real(jnp.fft.ifft2(spec))
        img = img - jnp.mean(img, axis=(1, 2), keepdims=True)
        img = img / (jnp.std(img, axis=(1, 2), keepdims=True) + 1e-8)
        return img.reshape(n, self.patch_dim)

    def value(self, w: jnp.ndarray, batch: jnp.ndarray) -> jnp.ndarray:
        x = batch  # (n, patch_dim)
        wx = x @ w.T  # (n, num_features)
        recon = wx @ w  # (n, patch_dim)
        sparse = self.lam * jnp.mean(jnp.sum(jnp.abs(wx), axis=-1))
        fit = 0.5 * jnp.mean(jnp.sum((recon - x) ** 2, axis=-1))
        return sparse + fit

    def grad(self, w: jnp.ndarray, batch, *, key=None) -> jnp.ndarray:
        return jax.grad(self.value)(w, batch)


def neg_log_posterior_potential(loss_fn, prior_prec: float = 0.0):
    """Wrap an arbitrary model loss into a potential U for SGLD on pytrees."""

    def u(params, batch):
        val = loss_fn(params, batch)
        if prior_prec > 0.0:
            sq = sum(jnp.sum(p * p) for p in jax.tree_util.tree_leaves(params))
            val = val + 0.5 * prior_prec * sq
        return val

    return u

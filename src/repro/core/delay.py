"""Parameter-history ring buffer: the TPU-native stand-in for racy shared memory.

The paper's asynchronous processors read the parameter vector out of shared
memory while other processors write to it.  On SPMD hardware we reproduce the
*information pattern* deterministically: every committed iterate is pushed
into a ring buffer holding the last ``tau + 1`` snapshots (a stacked leading
axis on every pytree leaf), and stale reads index into it.

Two read models, matching the paper:

- **consistent** (W-Con, Assumption 2.1): the whole vector comes from one
  snapshot ``X_{k - tau_k}``.
- **inconsistent** (W-Icon, Assumption 2.3): each *coordinate* ``i`` comes
  from its own snapshot ``[X_{s_i}]_i`` with ``s_i`` in ``[k - tau_k, k]``.

All functions are jit/grad-safe and shard transparently: the history carries
the same sharding as the parameters on all non-leading axes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import tree_broadcast_leading, tree_keys

PyTree = Any


@jax.tree_util.register_dataclass
@dataclass
class RingBuffer:
    """History of the last ``depth`` parameter snapshots.

    Attributes:
      history: pytree; each leaf has shape ``(depth, *leaf_shape)``.
      head: int32 scalar — slot holding the most recent snapshot.
      depth: static python int, ``tau + 1``.
    """

    history: PyTree
    head: jax.Array
    depth: int = field(metadata=dict(static=True))


def init_ring(params: PyTree, tau: int) -> RingBuffer:
    """Fill every slot with the initial parameters (delay-0 warm start)."""
    depth = int(tau) + 1
    return RingBuffer(history=tree_broadcast_leading(params, depth),
                      head=jnp.int32(0), depth=depth)


class StalenessError(ValueError):
    """A delay schedule demands staler reads than the iterate ring can serve."""


def ring_depths(tree: PyTree) -> list[int]:
    """Depths of every :class:`RingBuffer` inside ``tree`` (e.g. a sampler
    state's transform-chain state) — lets drivers validate that a delay
    schedule fits the history before ``read_consistent`` silently clamps."""
    nodes = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: isinstance(x, RingBuffer))[0]
    return [r.depth for r in nodes if isinstance(r, RingBuffer)]


def check_staleness_fits(max_delay: int, depth: int,
                         context: str = "schedule") -> None:
    """Raise :class:`StalenessError` unless a ring of ``depth`` snapshots can
    serve reads ``max_delay`` commits stale (``read_consistent`` clamps
    silently — running anyway would sample a different, less stale process)."""
    if max_delay >= depth:
        raise StalenessError(
            f"{context} max staleness {max_delay} does not fit the "
            f"iterate ring (depth {depth}, max readable staleness "
            f"{depth - 1}); read_consistent would silently clamp — "
            f"build the sampler with tau >= {max_delay}")


def validate_staleness(max_delay: int, tree: PyTree,
                       context: str = "schedule") -> None:
    """:func:`check_staleness_fits` against every ring inside ``tree``."""
    for depth in ring_depths(tree):
        check_staleness_fits(max_delay, depth, context)


def push(ring: RingBuffer, params: PyTree) -> RingBuffer:
    """Commit a new snapshot into the next slot."""
    new_head = (ring.head + 1) % ring.depth
    history = jax.tree_util.tree_map(
        lambda h, x: jax.lax.dynamic_update_index_in_dim(h, x.astype(h.dtype), new_head, 0),
        ring.history,
        params,
    )
    return RingBuffer(history=history, head=new_head, depth=ring.depth)


def read_consistent(ring: RingBuffer, delay: jax.Array) -> PyTree:
    """W-Con: the snapshot committed ``delay`` updates ago (clamped to depth-1)."""
    delay = jnp.clip(delay, 0, ring.depth - 1)
    slot = (ring.head - delay) % ring.depth
    return jax.tree_util.tree_map(
        lambda h: jax.lax.dynamic_index_in_dim(h, slot, axis=0, keepdims=False),
        ring.history,
    )


def sample_coordinate_delays(key: jax.Array, ring: RingBuffer, max_delay: jax.Array) -> PyTree:
    """Per-coordinate delays ``s_i ~ U{0..max_delay}`` for the W-Icon read.

    Returns a pytree of int32 leaves shaped like the parameters.
    """
    max_delay = jnp.clip(max_delay, 0, ring.depth - 1)
    keytree = tree_keys(key, ring.history)
    return jax.tree_util.tree_map(
        lambda k, h: jax.random.randint(k, h.shape[1:], 0, max_delay + 1, dtype=jnp.int32),
        keytree,
        ring.history,
    )


def read_inconsistent(ring: RingBuffer, delays: PyTree) -> PyTree:
    """W-Icon: gather ``x_hat[i] = history[(head - s_i) % depth, i]`` per coordinate.

    Pure-jnp reference path (``take_along_axis``).  The Pallas kernel
    ``repro.kernels.delay_gather`` implements the same contract for the TPU
    hot path; both are cross-validated in tests.
    """

    def gather(h, s):
        slot = (ring.head - s) % ring.depth  # same shape as one snapshot
        flat_h = h.reshape(ring.depth, -1)
        flat_slot = slot.reshape(1, -1)
        out = jnp.take_along_axis(flat_h, flat_slot, axis=0)
        return out.reshape(h.shape[1:])

    return jax.tree_util.tree_map(gather, ring.history, delays)

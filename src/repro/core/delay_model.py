"""Event-driven virtual-worker simulator: realistic, *seeded* delay processes.

The paper's delays come from OS/NUMA/MPS scheduling races (it had to average
three runs per figure).  We replace the physical race with an event-driven
simulation of ``P`` workers, each drawing per-step compute times from a
heterogeneous distribution.  A worker reads the model at commit-version
``v_read``, computes for a sampled duration, then commits; its realized
staleness is ``tau_k = v_now - v_read`` — exactly the paper's consistent-read
model.  The simulator also yields commit wall-clock times, which drive the
speedup figures (paper Figs 1b/2b/3b) without real hardware.

Pure numpy on the host; outputs are fed to the jitted sampler as arrays.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np


@dataclass
class DelayTrace:
    """Realized asynchronous schedule."""

    delays: np.ndarray        # (num_commits,) int32 staleness tau_k per commit
    commit_times: np.ndarray  # (num_commits,) float64 simulated wall clock
    worker_ids: np.ndarray    # (num_commits,) which worker committed
    num_workers: int

    @property
    def max_delay(self) -> int:
        return int(self.delays.max(initial=0))

    @property
    def mean_delay(self) -> float:
        return float(self.delays.mean()) if self.delays.size else 0.0


@dataclass
class WorkerModel:
    """Per-step compute-time distribution for the virtual workers.

    ``heterogeneity`` scales a fixed per-worker speed multiplier (NUMA socket
    imbalance); ``cv`` is the per-step lognormal coefficient of variation
    (OS jitter).
    """

    num_workers: int
    mean_step_time: float = 1.0
    cv: float = 0.3
    heterogeneity: float = 0.2
    update_cost: float = 0.05  # serialized commit (lock / memory write) time
    seed: int = 0
    _speeds: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._speeds = 1.0 + self.heterogeneity * rng.uniform(-1, 1, self.num_workers)

    def sample_step_time(self, rng: np.random.Generator, worker: int) -> float:
        mu = self.mean_step_time * self._speeds[worker]
        sigma = np.sqrt(np.log1p(self.cv**2))
        return float(mu * rng.lognormal(-0.5 * sigma**2, sigma))


def simulate_async(model: WorkerModel, num_commits: int, seed: int = 0) -> DelayTrace:
    """Asynchronous execution: every worker free-runs; commits serialize."""
    rng = np.random.default_rng(seed)
    heap: list[tuple[float, int, int]] = []  # (finish_time, worker, read_version)
    for w in range(model.num_workers):
        heapq.heappush(heap, (model.sample_step_time(rng, w), w, 0))

    delays = np.empty(num_commits, dtype=np.int32)
    times = np.empty(num_commits, dtype=np.float64)
    workers = np.empty(num_commits, dtype=np.int32)
    version = 0
    for k in range(num_commits):
        t, w, v_read = heapq.heappop(heap)
        t += model.update_cost  # serialized write
        delays[k] = version - v_read
        times[k] = t
        workers[k] = w
        version += 1
        heapq.heappush(heap, (t + model.sample_step_time(rng, w), w, version))
    return DelayTrace(delays=delays, commit_times=times, worker_ids=workers,
                      num_workers=model.num_workers)


def simulate_sync(model: WorkerModel, num_rounds: int, seed: int = 0) -> DelayTrace:
    """Synchronous (barrier) execution: one summed update per round.

    Round time = max over workers' draws (barrier) + one serialized update.
    Delay is 0 by construction.
    """
    rng = np.random.default_rng(seed)
    times = np.empty(num_rounds, dtype=np.float64)
    t = 0.0
    for k in range(num_rounds):
        t += max(model.sample_step_time(rng, w) for w in range(model.num_workers))
        t += model.update_cost
        times[k] = t
    return DelayTrace(
        delays=np.zeros(num_rounds, dtype=np.int32),
        commit_times=times,
        worker_ids=np.zeros(num_rounds, dtype=np.int32),
        num_workers=model.num_workers,
    )


def constant_delays(tau: int, num_commits: int) -> DelayTrace:
    """Worst-case fixed staleness (theory experiments)."""
    d = np.full(num_commits, tau, dtype=np.int32)
    d[: tau + 1] = np.arange(min(tau + 1, num_commits))  # warm-up: can't be staler than k
    return DelayTrace(
        delays=d,
        commit_times=np.arange(1, num_commits + 1, dtype=np.float64),
        worker_ids=np.zeros(num_commits, dtype=np.int32),
        num_workers=1,
    )


def speedup_vs_sync(async_trace: DelayTrace, sync_trace: DelayTrace) -> float:
    """Wall-clock speedup at equal gradient-evaluation counts.

    Sync evaluates P gradients per round; async evaluates 1 per commit.
    Compare time to consume the same number of gradient evaluations.
    """
    p = async_trace.num_workers
    n_async = len(async_trace.commit_times)
    n_rounds = max(1, n_async // p)
    if len(sync_trace.commit_times) < n_rounds:
        raise ValueError("sync trace too short")
    return float(sync_trace.commit_times[n_rounds - 1] / async_trace.commit_times[n_async - 1])

"""Event-driven virtual-worker simulator: realistic, *seeded* delay processes.

The paper's delays come from OS/NUMA/MPS scheduling races (it had to average
three runs per figure).  We replace the physical race with an event-driven
simulation of ``P`` workers, each drawing per-step compute times from a
heterogeneous distribution.  A worker reads the model at commit-version
``v_read``, computes for a sampled duration, then commits; its realized
staleness is ``tau_k = v_now - v_read`` — exactly the paper's consistent-read
model.  The simulator also yields commit wall-clock times, which drive the
speedup figures (paper Figs 1b/2b/3b) without real hardware.

Pure numpy on the host; outputs are fed to the jitted sampler as arrays.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.utils import bucket_size

#: the batch-size policy vocabulary (shared with
#: :class:`repro.cluster.ClusterEngine`).  :meth:`WorkerModel.batch_sizes`
#: draws ``"fixed"`` and ``"inverse-speed"``; ``"explicit"`` sizes bypass
#: the worker model and are passed straight to the executor.
BATCH_POLICIES = ("fixed", "inverse-speed", "explicit")

#: salt folded into the fault RNG seed so the chaos draws come from a stream
#: *disjoint* from the step-time draws — a :class:`FaultPlan` with zero rates
#: leaves the realized zero-fault trace bitwise identical.
_FAULT_SEED_SALT = 0xFA17

# event states on the simulator heap (4-tuple entries under a FaultPlan)
_EV_RUN = 0      # worker computing normally
_EV_STALLED = 1  # worker paused mid-step (stall already drawn; commits next)
_EV_REJOIN = 2   # worker coming back from a crash; re-reads fresh params


@dataclass(frozen=True)
class FaultPlan:
    """Per-commit fault process for :func:`simulate_async` chaos schedules.

    All draws come from a dedicated RNG stream (seeded with
    ``(seed, _FAULT_SEED_SALT)``), so attaching a plan with zero rates —
    or no plan at all — reproduces today's traces bitwise.

    - ``crash_rate``: probability a commit is lost mid-write.  The slot is
      still burned (version counter advances, preserving the all-commit
      numbering the executor's endogenous-staleness contract relies on) but
      the update is marked dead in :attr:`DelayTrace.alive`; the worker goes
      down for an exponential ``mean_downtime`` (in units of
      ``mean_step_time``) and *re-reads fresh params* when it rejoins.
    - ``pause_rate``: probability a worker is preempted just before its
      commit, stalling an exponential ``mean_pause`` before the (now even
      staler) gradient lands.  The commit itself survives.
    """

    crash_rate: float = 0.0
    mean_downtime: float = 2.0
    pause_rate: float = 0.0
    mean_pause: float = 1.0

    def __post_init__(self):
        for name in ("crash_rate", "pause_rate"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"FaultPlan.{name} must be in [0, 1), got {v}")
        for name in ("mean_downtime", "mean_pause"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"FaultPlan.{name} must be >= 0")

    @property
    def active(self) -> bool:
        """Whether this plan can realize any fault at all."""
        return self.crash_rate > 0.0 or self.pause_rate > 0.0


@dataclass
class DelayTrace:
    """Realized asynchronous schedule.

    ``batch_sizes`` (optional) is the per-commit minibatch size the committing
    worker averaged its gradient over — ``None`` means the legacy fixed-shape
    contract where every commit consumes one engine-defined minibatch.

    ``alive`` (optional) marks commits that actually landed: ``False`` slots
    are crashed workers' in-flight commits, which the executor turns into
    masked no-ops.  ``None`` means every commit landed (the zero-fault
    contract — note ``None``, not an all-True array, so fault-free plumbing
    stays bitwise identical to a trace that never saw a :class:`FaultPlan`).
    """

    delays: np.ndarray        # (num_commits,) int32 staleness tau_k per commit
    commit_times: np.ndarray  # (num_commits,) float64 simulated wall clock
    worker_ids: np.ndarray    # (num_commits,) which worker committed
    num_workers: int
    batch_sizes: np.ndarray | None = None  # (num_commits,) int32 per commit
    alive: np.ndarray | None = None        # (num_commits,) bool, False = lost

    @property
    def max_delay(self) -> int:
        return int(self.delays.max(initial=0))

    @property
    def num_lost(self) -> int:
        """Commits lost to crashes (0 for a fault-free trace)."""
        return 0 if self.alive is None else int((~self.alive).sum())

    @property
    def mean_delay(self) -> float:
        return float(self.delays.mean()) if self.delays.size else 0.0

    @property
    def total_grad_evals(self) -> int:
        """Total gradient evaluations = sum of per-commit batch sizes (one
        per commit under the legacy fixed-shape contract)."""
        if self.batch_sizes is None:
            return int(self.delays.shape[0])
        return int(self.batch_sizes.sum())


@dataclass
class WorkerModel:
    """Per-step compute-time distribution for the virtual workers.

    ``heterogeneity`` scales a fixed per-worker speed multiplier (NUMA socket
    imbalance); ``cv`` is the per-step lognormal coefficient of variation
    (OS jitter).
    """

    num_workers: int
    mean_step_time: float = 1.0
    cv: float = 0.3
    heterogeneity: float = 0.2
    update_cost: float = 0.05  # serialized commit (lock / memory write) time
    seed: int = 0
    faults: FaultPlan | None = None  # chaos process; None = fault-free
    _speeds: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._speeds = 1.0 + self.heterogeneity * rng.uniform(-1, 1, self.num_workers)

    def sample_step_time(self, rng: np.random.Generator, worker: int) -> float:
        mu = self.mean_step_time * self._speeds[worker]
        sigma = np.sqrt(np.log1p(self.cv**2))
        return float(mu * rng.lognormal(-0.5 * sigma**2, sigma))

    def batch_sizes(self, batch_policy: str = "fixed", *, base_batch: int = 1,
                    buckets=None) -> np.ndarray:
        """Per-worker minibatch size under ``batch_policy``.

        - ``fixed``: every worker consumes exactly ``base_batch`` per commit
          (the legacy contract — sizes are *not* bucket-snapped, so the
          realized schedule is unchanged).
        - ``inverse-speed``: a worker's batch scales with its per-step time
          relative to the fastest worker (Chen et al.'s staleness/variance
          trade: slow workers amortize their inevitable staleness over more
          data, fast workers commit fresh low-latency gradients), snapped up
          the bucket ladder so mixed sizes compile one trace per rung.
        """
        if batch_policy == "fixed":
            return np.full(self.num_workers, base_batch, np.int32)
        if batch_policy == "inverse-speed":
            rel = self._speeds / self._speeds.min()  # slowest -> largest
            raw = np.maximum(1, np.round(base_batch * rel)).astype(np.int64)
            return np.array([bucket_size(int(b), buckets) for b in raw],
                            np.int32)
        raise ValueError(
            f"unknown batch policy {batch_policy!r} for a WorkerModel "
            f"(choose from {BATCH_POLICIES[:2]}; 'explicit' sizes are passed "
            "straight to the executor)")


def simulate_async(model: WorkerModel, num_commits: int, seed: int = 0, *,
                   batch_policy: str = "fixed", base_batch: int = 1,
                   buckets=None) -> DelayTrace:
    """Asynchronous execution: every worker free-runs; commits serialize.

    ``batch_policy`` couples each worker's per-commit batch size to its
    drawn compute times: a commit over ``b`` examples takes ``b/base_batch``
    times the worker's sampled per-``base_batch`` step time, so larger
    batches make a worker commit less often but average more data — the
    realized staleness *and* the realized batch sizes come out of one
    event-driven simulation.  With the default fixed policy the time scale
    factor is exactly 1.0 and the realized trace is unchanged.
    """
    sizes = model.batch_sizes(batch_policy, base_batch=base_batch,
                              buckets=buckets)
    scale = sizes.astype(np.float64) / float(base_batch)
    rng = np.random.default_rng(seed)
    if model.faults is not None and model.faults.active:
        return _simulate_chaos(model, num_commits, seed, rng, sizes, scale)
    heap: list[tuple[float, int, int]] = []  # (finish_time, worker, read_version)
    for w in range(model.num_workers):
        heapq.heappush(heap, (model.sample_step_time(rng, w) * scale[w], w, 0))

    delays = np.empty(num_commits, dtype=np.int32)
    times = np.empty(num_commits, dtype=np.float64)
    workers = np.empty(num_commits, dtype=np.int32)
    version = 0
    for k in range(num_commits):
        t, w, v_read = heapq.heappop(heap)
        t += model.update_cost  # serialized write
        delays[k] = version - v_read
        times[k] = t
        workers[k] = w
        version += 1
        heapq.heappush(heap,
                       (t + model.sample_step_time(rng, w) * scale[w], w,
                        version))
    return DelayTrace(delays=delays, commit_times=times, worker_ids=workers,
                      num_workers=model.num_workers,
                      batch_sizes=sizes[workers])


def _simulate_chaos(model: WorkerModel, num_commits: int, seed: int,
                    rng: np.random.Generator, sizes: np.ndarray,
                    scale: np.ndarray) -> DelayTrace:
    """The fault-injected event loop behind :func:`simulate_async`.

    Same event-driven core, plus crash/pause/rejoin events drawn from a
    *separate* RNG stream.  A crashed commit still burns a version slot (so
    ``read_versions`` keep the all-commit numbering the executor derives
    staleness against) but is marked dead in ``alive``; the crashed worker
    rejoins after an exponential downtime and re-reads the then-current
    version — exactly the elastic join/leave semantics the ROADMAP asks for.
    """
    plan = model.faults
    rng_f = np.random.default_rng((seed, _FAULT_SEED_SALT))
    # (finish_time, worker, read_version, event_state)
    heap: list[tuple[float, int, int, int]] = []
    for w in range(model.num_workers):
        heapq.heappush(heap,
                       (model.sample_step_time(rng, w) * scale[w], w, 0,
                        _EV_RUN))

    delays = np.empty(num_commits, dtype=np.int32)
    times = np.empty(num_commits, dtype=np.float64)
    workers = np.empty(num_commits, dtype=np.int32)
    alive = np.ones(num_commits, dtype=bool)
    version = 0
    k = 0
    while k < num_commits:
        t, w, v_read, ev = heapq.heappop(heap)
        if ev == _EV_REJOIN:
            # back from the dead: fresh read of the current version
            heapq.heappush(heap,
                           (t + model.sample_step_time(rng, w) * scale[w], w,
                            version, _EV_RUN))
            continue
        if ev == _EV_RUN and rng_f.random() < plan.pause_rate:
            # preempted just before the commit; the gradient only gets staler
            stall = rng_f.exponential(plan.mean_pause * model.mean_step_time)
            heapq.heappush(heap, (t + stall, w, v_read, _EV_STALLED))
            continue
        crashed = rng_f.random() < plan.crash_rate
        t += model.update_cost  # serialized write (attempted either way)
        delays[k] = version - v_read
        times[k] = t
        workers[k] = w
        alive[k] = not crashed
        version += 1
        k += 1
        if crashed:
            down = rng_f.exponential(plan.mean_downtime * model.mean_step_time)
            heapq.heappush(heap, (t + down, w, -1, _EV_REJOIN))
        else:
            heapq.heappush(heap,
                           (t + model.sample_step_time(rng, w) * scale[w], w,
                            version, _EV_RUN))
    return DelayTrace(delays=delays, commit_times=times, worker_ids=workers,
                      num_workers=model.num_workers,
                      batch_sizes=sizes[workers], alive=alive)


def simulate_sync(model: WorkerModel, num_rounds: int, seed: int = 0) -> DelayTrace:
    """Synchronous (barrier) execution: one summed update per round.

    Round time = max over workers' draws (barrier) + one serialized update.
    Delay is 0 by construction.
    """
    rng = np.random.default_rng(seed)
    times = np.empty(num_rounds, dtype=np.float64)
    t = 0.0
    for k in range(num_rounds):
        t += max(model.sample_step_time(rng, w) for w in range(model.num_workers))
        t += model.update_cost
        times[k] = t
    return DelayTrace(
        delays=np.zeros(num_rounds, dtype=np.int32),
        commit_times=times,
        worker_ids=np.zeros(num_rounds, dtype=np.int32),
        num_workers=model.num_workers,
    )


def constant_delays(tau: int, num_commits: int) -> DelayTrace:
    """Worst-case fixed staleness (theory experiments)."""
    d = np.full(num_commits, tau, dtype=np.int32)
    d[: tau + 1] = np.arange(min(tau + 1, num_commits))  # warm-up: can't be staler than k
    return DelayTrace(
        delays=d,
        commit_times=np.arange(1, num_commits + 1, dtype=np.float64),
        worker_ids=np.zeros(num_commits, dtype=np.int32),
        num_workers=1,
    )


def truncate_to_evals(trace: DelayTrace, evals: int) -> DelayTrace:
    """Clip a trace at a gradient-evaluation budget: keep the shortest commit
    prefix whose summed batch sizes reach ``evals`` (commit count, for a
    legacy trace without sizes).  The equal-compute axis for comparing batch
    policies: heterogeneous and fixed schedules truncated to one budget have
    consumed the same number of per-example gradients."""
    sizes = (np.ones(len(trace.delays), np.int64) if trace.batch_sizes is None
             else trace.batch_sizes.astype(np.int64))
    total = np.cumsum(sizes)
    if total.size == 0 or total[-1] < evals:
        raise ValueError(f"trace holds {int(total[-1]) if total.size else 0} "
                         f"grad evals, need {evals} — simulate more commits")
    k = int(np.searchsorted(total, evals)) + 1
    return DelayTrace(
        delays=trace.delays[:k], commit_times=trace.commit_times[:k],
        worker_ids=trace.worker_ids[:k], num_workers=trace.num_workers,
        batch_sizes=None if trace.batch_sizes is None
        else trace.batch_sizes[:k],
        alive=None if trace.alive is None else trace.alive[:k])


def speedup_vs_sync(async_trace: DelayTrace, sync_trace: DelayTrace) -> float:
    """Wall-clock speedup at equal gradient-evaluation counts.

    Sync evaluates P gradients per round; async evaluates 1 per commit.
    Compare time to consume the same number of gradient evaluations.
    """
    p = async_trace.num_workers
    n_async = len(async_trace.commit_times)
    n_rounds = max(1, n_async // p)
    if len(sync_trace.commit_times) < n_rounds:
        raise ValueError("sync trace too short")
    return float(sync_trace.commit_times[n_rounds - 1] / async_trace.commit_times[n_async - 1])

"""Process-global metrics registry: counters, gauges, fixed-bucket histograms.

The quantities the paper's claims live and die by — per-token decode
latency, per-commit staleness, W2 snapshots, cumulative gradient
evaluations, cache-bank utilization — are recorded here by the engines as
they run, cheaply enough to stay on in production serving loops (a counter
``inc`` is one float add under a slot attribute; a histogram ``observe`` is
one ``bisect`` plus two adds).  Buckets are **fixed at construction**, so a
histogram never reallocates on the hot path and snapshots from different
processes are mergeable bucket-by-bucket.

Two export formats:

- :meth:`Registry.snapshot` → a JSON-ready dict;
  :meth:`Registry.write_snapshot` / :meth:`Registry.append_jsonl` persist it
  (the benchmarks write one snapshot next to each ``BENCH_*.json``, and
  ``scripts/check_bench.py`` prints non-gating deltas against the committed
  baseline snapshot);
- :meth:`Registry.prometheus` → Prometheus text exposition (counters,
  gauges, and cumulative ``_bucket`` histograms), so a scrape endpoint is a
  file write away.

Engines use the process-global :func:`registry`; tests construct private
:class:`Registry` instances (or read deltas of the global one — every value
is monotone or last-write).
"""

from __future__ import annotations

import bisect
import json
import math
import re
import threading
from typing import Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "registry",
           "LATENCY_MS_BUCKETS", "STALENESS_BUCKETS"]

#: default rungs for millisecond-latency histograms (log-ish ladder)
LATENCY_MS_BUCKETS = (0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                      500.0, 1000.0, 2500.0)
#: default rungs for per-commit staleness (powers of two; tau=0 is its own
#: bucket so the synchronous baseline is visible at a glance)
STALENESS_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128)


class Counter:
    """Monotone float counter."""

    __slots__ = ("name", "help", "_value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        self.name = name
        self.help = help
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0 — counters only go up)."""
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        self._value += n

    @property
    def value(self) -> float:
        """Current running total."""
        return self._value

    def to_dict(self) -> dict:
        """JSON-ready ``{"type", "value"}`` form for snapshots."""
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "help", "_value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        self.name = name
        self.help = help
        self._value = math.nan

    def set(self, v: float) -> None:
        """Overwrite the gauge with ``v`` (last write wins)."""
        self._value = float(v)

    @property
    def value(self) -> float:
        """Last value set (NaN before the first ``set``)."""
        return self._value

    def to_dict(self) -> dict:
        """JSON-ready ``{"type", "value"}`` form for snapshots."""
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Fixed-bucket histogram: ``bounds`` are inclusive upper edges, with an
    implicit +inf overflow bucket; ``counts[i]`` holds observations ``<=
    bounds[i]`` and ``> bounds[i-1]``."""

    __slots__ = ("name", "help", "bounds", "counts", "total", "sum")
    kind = "histogram"

    def __init__(self, name: str, bounds: Sequence[float],
                 help: str = ""):  # noqa: A002
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name} needs ascending bucket "
                             f"bounds, got {bounds!r}")
        self.name = name
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # + overflow
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        """Record one observation into its bucket (and total/sum)."""
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v

    def observe_many(self, values) -> None:
        """Bulk observe (host arrays from a schedule or a latency list) —
        one pass, no per-element Python dispatch for the common case."""
        for v in values:
            v = float(v)
            self.counts[bisect.bisect_left(self.bounds, v)] += 1
            self.total += 1
            self.sum += v

    @property
    def mean(self) -> float:
        """Exact mean of all observations (NaN when empty)."""
        return self.sum / self.total if self.total else math.nan

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the ``q``-quantile (a
        conservative estimate — exact values are not retained)."""
        if not self.total:
            return math.nan
        rank = q * self.total
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return (self.bounds[i] if i < len(self.bounds)
                        else math.inf)
        return math.inf

    def to_dict(self) -> dict:
        """JSON-ready bucket layout: bounds, counts, count, sum."""
        return {"type": "histogram", "bounds": list(self.bounds),
                "counts": list(self.counts), "count": self.total,
                "sum": self.sum}


_PROM_SAN = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    n = _PROM_SAN.sub("_", name)
    return n if not n[:1].isdigit() else f"_{n}"


class Registry:
    """Name → metric map with idempotent, type-checked constructors.

    ``counter``/``gauge``/``histogram`` return the existing metric when the
    name is already registered (so call sites need no module-level
    plumbing) and raise if the registered kind differs — a name means one
    thing process-wide.
    """

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, make):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = make()
            elif m.kind != kind:
                raise TypeError(f"metric {name!r} is a {m.kind}, "
                                f"not a {kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:  # noqa: A002
        """Get-or-create the :class:`Counter` registered under ``name``."""
        return self._get(name, "counter", lambda: Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:  # noqa: A002
        """Get-or-create the :class:`Gauge` registered under ``name``."""
        return self._get(name, "gauge", lambda: Gauge(name, help))

    def histogram(self, name: str, bounds: Optional[Sequence[float]] = None,
                  help: str = "") -> Histogram:  # noqa: A002
        """Get-or-create the :class:`Histogram` under ``name``; ``bounds``
        default to the latency-ms buckets and only apply on creation."""
        return self._get(name, "histogram",
                         lambda: Histogram(name, bounds or LATENCY_MS_BUCKETS,
                                           help))

    def get(self, name: str):
        """The metric registered under ``name``, or None."""
        return self._metrics.get(name)

    def names(self) -> list:
        """Sorted list of every registered metric name."""
        with self._lock:
            return sorted(self._metrics)

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready ``{name: metric dict}`` (NaN gauges are omitted —
        ``json`` would emit invalid ``NaN`` literals)."""
        out = {}
        with self._lock:
            for name in sorted(self._metrics):
                d = self._metrics[name].to_dict()
                if d["type"] == "gauge" and math.isnan(d["value"]):
                    continue
                out[name] = d
        return out

    def write_snapshot(self, path) -> dict:
        """Dump :meth:`snapshot` to ``path`` as pretty JSON; returns it."""
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        return snap

    def append_jsonl(self, path, **extra) -> None:
        """Append one ``{**extra, "metrics": snapshot}`` JSON line — the
        trend-trail format (nightly CI appends one line per run)."""
        with open(path, "a") as f:
            json.dump({**extra, "metrics": self.snapshot()}, f,
                      sort_keys=True)
            f.write("\n")

    def prometheus(self) -> str:
        """Prometheus text exposition of the whole registry."""
        lines = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                pname = _prom_name(name)
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} {m.kind}")
                if m.kind == "histogram":
                    acc = 0
                    for bound, c in zip(m.bounds, m.counts):
                        acc += c
                        lines.append(
                            f'{pname}_bucket{{le="{bound:g}"}} {acc}')
                    lines.append(f'{pname}_bucket{{le="+Inf"}} {m.total}')
                    lines.append(f"{pname}_sum {m.sum:g}")
                    lines.append(f"{pname}_count {m.total}")
                elif not (m.kind == "gauge" and math.isnan(m.value)):
                    lines.append(f"{pname} {m.value:g}")
        return "\n".join(lines) + "\n"


_GLOBAL = Registry()


def registry() -> Registry:
    """The process-global registry every engine publishes into."""
    return _GLOBAL

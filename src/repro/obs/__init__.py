"""repro.obs — observability for the async sampler, serve, and decode paths.

The paper's claim is about *wall-clock* behavior under asynchrony, so time
has to be a first-class, exportable quantity — not a benchmark total.  Three
layers, all host-side by construction (safe on compiled paths):

- :mod:`repro.obs.trace` — a low-overhead span tracer (``span("decode.
  generate", **attrs)``, engine hooks at chunk boundaries, parent-linked
  per-thread trees, disabled-by-default null path);
- :mod:`repro.obs.metrics` — a process-global registry of counters, gauges,
  and fixed-bucket histograms (per-token latency, per-commit staleness, W2,
  grad evals, bank utilization) with JSON snapshot and Prometheus text
  exposition;
- :mod:`repro.obs.timeline` — Chrome-trace-event export of cluster commit
  schedules and decode request streams, openable directly in Perfetto /
  ``chrome://tracing`` (``scripts/obstool.py`` summarizes them).

The runtime invariants bus (:mod:`repro.analysis.instrument`) feeds this
layer: XLA compile wall-time lands in the registry, and the benchmarks
write one metrics snapshot + timeline next to each ``BENCH_*.json``.
"""

from repro.obs import metrics, timeline, trace  # noqa: F401
from repro.obs.metrics import Registry, registry  # noqa: F401
from repro.obs.timeline import (  # noqa: F401
    cluster_timeline,
    decode_timeline,
    paged_timeline,
    to_chrome_trace,
    write_chrome_trace,
)
from repro.obs.trace import Span, Tracer, span, trace_hook, tracer  # noqa: F401

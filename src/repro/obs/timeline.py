"""Chrome-trace-event export: spans and schedules as Perfetto timelines.

Everything here emits the JSON object format Perfetto and
``chrome://tracing`` open directly — ``{"traceEvents": [...]}`` with
complete (``"ph": "X"``) duration events in microseconds and ``"M"``
metadata records naming the process/thread rows.  Three producers:

- :func:`to_chrome_trace` — any list of :class:`repro.obs.trace.Span`
  records (or their dicts), one timeline row per originating thread;
- :func:`cluster_timeline` — a :class:`ClusterEngine` run's per-worker
  commit schedule: one process per chain, one row per worker, one span per
  commit stretching from that worker's previous commit to this one on the
  *simulated* wall clock, annotated with the commit index, read version,
  **staleness**, and batch size.  This is the paper's Figure-1 execution
  diagram, reconstructed from the same ``WorkerSchedule`` arrays the
  executor scans — no extra event collection;
- :func:`paged_timeline` — a :class:`PagedDecodeEngine` stream: one row per
  serving *slot* carrying each request's queue wait (submit → admission),
  its prefill (``paged.admit``), and its residency (``paged.request``,
  annotated with new-token count and eviction count), plus a scheduler row
  of ``paged.decode_chunk`` spans showing how many slots each fused step
  chunk advanced.  Continuous batching is visible at a glance: slot rows
  stay dense while the waiting queue drains, and an evicted request shows
  up twice on (possibly) different slot rows;
- :func:`decode_timeline` — a :class:`DecodeEngine` request stream traced by
  :mod:`repro.obs.trace`: per request, one ``decode.generate`` span (the
  host-measured truth) plus **amortized** prefill/per-token child slices on
  the request's bucket-rung row.  The whole generation is one fused
  ``lax.scan`` on device — the host cannot observe token boundaries without
  breaking the one-dispatch contract — so each token slice is the request
  duration split position-proportionally (prefill weighs ``t_rung`` cached
  positions, each token one) and carries ``"amortized": true``.

Times: span input is seconds on the :func:`repro.obs.trace.now` clock;
schedule input is simulated seconds; both scale to integer-friendly
microseconds in the output.
"""

from __future__ import annotations

import json
from collections import defaultdict
from typing import Optional, Sequence

from repro.obs.trace import iter_spans

__all__ = ["cluster_timeline", "decode_timeline", "paged_timeline",
           "to_chrome_trace", "write_chrome_trace"]

_US = 1e6


def _meta(pid: int, name: str, tid: Optional[int] = None) -> dict:
    ev = {"ph": "M", "pid": pid, "ts": 0,
          "name": "process_name" if tid is None else "thread_name",
          "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def _event(name: str, t0_s: float, t1_s: float, pid: int, tid: int,
           args: dict, cat: str = "repro") -> dict:
    return {"ph": "X", "name": name, "cat": cat, "pid": pid, "tid": tid,
            "ts": round(t0_s * _US, 3),
            "dur": round(max(t1_s - t0_s, 0.0) * _US, 3), "args": args}


def to_chrome_trace(spans, *, pid: int = 0,
                    process_name: str = "repro") -> dict:
    """Spans (objects or dicts) → a Chrome-trace JSON object, one timeline
    row per originating thread, span attributes as ``args`` (parent links
    ride along as ``args["span_id"]/["parent_id"]``)."""
    events = [_meta(pid, process_name)]
    tids: dict = {}
    for sp in iter_spans(spans):
        tid = tids.setdefault(sp["tid"], len(tids))
        args = dict(sp["attrs"])
        args["span_id"] = sp["id"]
        if sp["parent"] is not None:
            args["parent_id"] = sp["parent"]
        events.append(_event(sp["name"], sp["t0"], sp["t1"], pid, tid, args))
    for raw, tid in tids.items():
        events.append(_meta(pid, f"thread {raw}", tid))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def cluster_timeline(schedules, *, max_chains: Optional[int] = 8,
                     time_scale: float = 1.0) -> dict:
    """Per-worker commit spans of a :class:`ClusterEngine` schedule.

    ``schedules`` is one ``WorkerSchedule`` or a per-chain sequence (as
    passed to :meth:`ClusterEngine.run`); chains beyond ``max_chains`` are
    dropped (``None`` keeps all) so a 64-chain benchmark exports a readable
    file.  Commit ``k`` by worker ``w`` renders as a span on chain-process
    ``c``'s worker-``w`` row ending at ``commit_times[k]`` and starting at
    ``w``'s previous commit (or 0) — the worker's compute+commit interval —
    with ``staleness``/``read_version``/``batch_size`` in ``args``.
    ``time_scale`` multiplies simulated time units into seconds.
    """
    if hasattr(schedules, "read_versions"):
        schedules = [schedules]
    schedules = list(schedules)
    if max_chains is not None:
        schedules = schedules[:max_chains]
    events = []
    for c, sched in enumerate(schedules):
        events.append(_meta(c, f"chain {c}"))
        delays = sched.delays
        sizes = sched.batch_sizes
        last_by_worker: dict = {}
        for k in range(len(sched)):
            w = int(sched.worker_ids[k])
            t1 = float(sched.commit_times[k]) * time_scale
            t0 = last_by_worker.get(w, 0.0)
            last_by_worker[w] = t1
            args = {"commit": k, "worker": w,
                    "staleness": int(delays[k]),
                    "read_version": int(sched.read_versions[k])}
            if sizes is not None:
                args["batch_size"] = int(sizes[k])
            alive = getattr(sched, "alive", None)
            lost = alive is not None and not bool(alive[k])
            if lost:
                args["lost"] = True  # crashed mid-commit: masked no-op
            events.append(_event("commit (lost)" if lost else "commit",
                                 t0, t1, c, w, args, cat="cluster"))
        for w in sorted(last_by_worker):
            events.append(_meta(c, f"worker {w}", w))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def decode_timeline(spans, *, pid: int = 0) -> dict:
    """``decode.generate`` spans → per-rung rows with amortized
    prefill/per-token slices (see module docstring for why token boundaries
    are amortized rather than measured)."""
    events = [_meta(pid, "decode")]
    rung_tid: dict = {}
    for sp in iter_spans(spans):
        if sp["name"] != "decode.generate":
            continue
        attrs = sp["attrs"]
        rung = (attrs.get("b_rung", 0), attrs.get("t_rung", 0))
        tid = rung_tid.setdefault(rung, len(rung_tid))
        args = dict(attrs)
        args["span_id"] = sp["id"]
        events.append(_event("decode.generate", sp["t0"], sp["t1"], pid,
                             tid, args, cat="decode"))
        new_tokens = int(attrs.get("new_tokens", 0))
        if new_tokens < 1:
            continue
        t_rung = max(int(attrs.get("t_rung", 1)), 1)
        total = sp["t1"] - sp["t0"]
        # position-proportional amortization: prefill processes t_rung
        # cached positions in one pass, each decode step one position
        unit = total / (t_rung + new_tokens)
        t = sp["t0"]
        slices = [("decode.prefill", t_rung * unit, {"positions": t_rung})]
        slices += [("decode.token", unit, {"i": i})
                   for i in range(new_tokens)]
        for name, dur, extra in slices:
            events.append(_event(name, t, t + dur, pid, tid,
                                 {**extra, "amortized": True,
                                  "b_rung": attrs.get("b_rung"),
                                  "t_rung": attrs.get("t_rung"),
                                  "request_span": sp["id"]},
                                 cat="decode"))
            t += dur
    for (b, t_), tid in rung_tid.items():
        events.append(_meta(pid, f"rung B{b}xT{t_}", tid))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def paged_timeline(spans, *, pid: int = 0) -> dict:
    """``paged.*`` spans → a per-slot continuous-batching timeline.

    One thread row per serving slot, plus a ``scheduler`` row.  Per
    request: a ``paged.wait`` slice (submission → first prefill start, on
    the slot that first admitted it), each ``paged.admit`` prefill, and the
    full ``paged.request`` residency (submission → finish) with
    ``new_tokens`` / ``evictions`` in ``args``.  ``paged.decode_chunk``
    spans land on the scheduler row, showing how many slots each fused
    step chunk advanced.
    """
    events = [_meta(pid, "paged")]
    slots: set = set()
    admits: dict = defaultdict(list)  # request_id -> [admit span dicts]
    chunks, requests = [], []
    for sp in iter_spans(spans):
        if sp["name"] == "paged.admit":
            admits[sp["attrs"].get("request_id")].append(sp)
        elif sp["name"] == "paged.request":
            requests.append(sp)
        elif sp["name"] == "paged.decode_chunk":
            chunks.append(sp)
    for rid, sps in admits.items():
        sps.sort(key=lambda sp: sp["t0"])
        for sp in sps:
            s = int(sp["attrs"]["slot"])
            slots.add(s)
            events.append(_event("paged.admit", sp["t0"], sp["t1"], pid, s,
                                 dict(sp["attrs"]), cat="paged"))
    for sp in requests:
        attrs = dict(sp["attrs"])
        s = int(attrs["slot"])
        slots.add(s)
        first = admits.get(attrs.get("request_id"))
        if first:  # queue wait: submission until the first prefill starts
            events.append(_event(
                "paged.wait", sp["t0"], first[0]["t0"], pid,
                int(first[0]["attrs"]["slot"]),
                {"request_id": attrs.get("request_id")}, cat="paged"))
        events.append(_event("paged.request", sp["t0"], sp["t1"], pid, s,
                             attrs, cat="paged"))
    sched = (max(slots) + 1) if slots else 0
    for sp in chunks:
        events.append(_event("paged.decode_chunk", sp["t0"], sp["t1"], pid,
                             sched, dict(sp["attrs"]), cat="paged"))
    for s in sorted(slots):
        events.append(_meta(pid, f"slot {s}", s))
    if chunks:
        events.append(_meta(pid, "scheduler", sched))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, trace_or_spans) -> dict:
    """Write a timeline JSON; bare span lists go through
    :func:`to_chrome_trace` first.  Returns the written object."""
    trace = trace_or_spans
    if not isinstance(trace, dict):
        trace = to_chrome_trace(trace)
    if "traceEvents" not in trace:
        raise ValueError("not a Chrome trace object (missing traceEvents)")
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace


def validate_chrome_trace(trace: dict) -> list:
    """Schema problems (empty list = valid Chrome-trace-event JSON): the
    checks ``tests/test_obs.py`` pins the benchmark artifacts with."""
    problems = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "M", "i", "B", "E"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev:
            problems.append(f"event {i}: missing name/pid")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or ts < 0:
                problems.append(f"event {i}: bad ts {ts!r}")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
            if "tid" not in ev:
                problems.append(f"event {i}: X event without tid")
    return problems


def _iter_complete(trace: dict, name: Optional[str] = None,
                   cat: Optional[str] = None):
    for ev in trace.get("traceEvents", ()):
        if ev.get("ph") != "X":
            continue
        if name is not None and ev.get("name") != name:
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        yield ev


def summarize(trace: dict) -> dict:
    """Aggregate a timeline for ``scripts/obstool.py``: per-(pid, tid) busy
    time and makespan (the critical path is the busiest row of the longest
    process), staleness histogram over commit spans, and tokens/sec by
    decode rung."""
    busy: dict = defaultdict(float)
    end: dict = defaultdict(float)
    names: dict = {}
    for ev in trace.get("traceEvents", ()):
        if ev.get("ph") == "M":
            key = (ev["pid"], ev.get("tid"))
            names[key] = ev.get("args", {}).get("name", "")
    staleness: dict = defaultdict(int)
    rung: dict = defaultdict(lambda: [0, 0.0])  # tid -> [tokens, secs]
    for ev in _iter_complete(trace):
        key = (ev["pid"], ev["tid"])
        busy[key] += ev["dur"] / _US
        end[key] = max(end[key], (ev["ts"] + ev["dur"]) / _US)
        args = ev.get("args", {})
        if "staleness" in args:
            staleness[int(args["staleness"])] += 1
        if ev["name"] == "decode.token":
            r = rung[key]
            r[0] += 1
            r[1] += ev["dur"] / _US
    makespan = max(end.values(), default=0.0)
    rows = [{"pid": pid, "tid": tid,
             "label": (f"{names.get((pid, None), pid)}/"
                       f"{names.get((pid, tid), tid)}"),
             "busy_s": round(b, 6), "end_s": round(end[(pid, tid)], 6),
             "utilization": round(b / makespan, 4) if makespan else 0.0}
            for (pid, tid), b in sorted(busy.items(),
                                        key=lambda kv: -kv[1])]
    tokens_by_rung = {
        f"{names.get((pid, tid), tid)}": {
            "tokens": n, "tokens_per_s": round(n / secs, 2) if secs else None}
        for (pid, tid), (n, secs) in rung.items()}
    return {"makespan_s": round(makespan, 6), "rows": rows,
            "critical": rows[0] if rows else None,
            "staleness_hist": dict(sorted(staleness.items())),
            "tokens_by_rung": tokens_by_rung}


def _spans_or_trace(payload) -> dict:
    """``obstool`` input adapter: a Chrome trace object passes through, a
    bare span-dump list converts."""
    if isinstance(payload, dict) and "traceEvents" in payload:
        return payload
    return to_chrome_trace(payload)

"""Low-overhead host-side span tracer for the serving and training hot paths.

A span is one wall-clock interval with a name, attributes, and a parent —
``with span("decode.generate", b_rung=8): ...`` records where the time went
without touching the compiled program.  Everything here is **host-side by
construction**: spans open and close around jitted dispatches and inside
engine hooks (chunk boundaries), never inside traced code, so enabling the
tracer cannot introduce a host sync, a retrace, or a pad allocation into a
measured stream (lint rule JL004 and the
:func:`~repro.analysis.instrument.instrument` stream flags stay clean —
asserted in ``tests/test_obs.py``).

Cost discipline: the global tracer starts **disabled**, and a disabled
``span()`` returns a shared no-op context — two attribute loads and a
branch, no allocation — so engines leave their span sites on permanently.
Enabled spans cost one clock read on entry and one on exit plus a list
append; parents are linked through a per-thread stack, so concurrent
serving threads get independent span trees over one shared buffer.

Timestamps are seconds on a process-local monotonic clock
(``perf_counter`` minus the module-import epoch); the Chrome-trace exporter
(:mod:`repro.obs.timeline`) converts them to the microsecond ``ts`` Perfetto
expects.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Callable, Iterator, Optional

__all__ = ["Span", "Tracer", "disable", "enable", "now", "span", "tracer",
           "trace_hook"]

_EPOCH = time.perf_counter()


def now() -> float:
    """Seconds since the tracer epoch (process-local monotonic clock)."""
    return time.perf_counter() - _EPOCH


class Span:
    """One recorded interval: ``[t0, t1]`` seconds since the tracer epoch,
    parent-linked into this thread's span tree."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1", "attrs", "tid")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t0: float, attrs: dict, tid: int):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1 = t0
        self.attrs = attrs
        self.tid = tid

    def set(self, **attrs) -> "Span":
        """Attach attributes after entry (e.g. results known only on exit)."""
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        """Wall seconds from entry to exit (0 while still open)."""
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        """JSON-ready form: name, ids, timestamps, thread, attrs."""
        return {"name": self.name, "id": self.span_id,
                "parent": self.parent_id, "t0": self.t0, "t1": self.t1,
                "tid": self.tid, "attrs": dict(self.attrs)}


class _NullSpan:
    """The shared span handed out while tracing is disabled."""

    __slots__ = ()

    def set(self, **_attrs) -> "_NullSpan":
        return self


class _NullCtx:
    __slots__ = ()

    def __enter__(self) -> _NullSpan:
        return _NULL_SPAN

    def __exit__(self, *_exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()
_NULL_CTX = _NullCtx()


class _SpanCtx:
    """Context manager for one live span (hand-rolled: no generator frame
    per call on the hot path)."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        stack = self._tracer._stack()
        if stack:
            self._span.parent_id = stack[-1].span_id
        stack.append(self._span)
        self._span.t0 = now()
        return self._span

    def __exit__(self, *_exc) -> bool:
        sp = self._span
        sp.t1 = now()
        self._tracer._stack().pop()
        with self._tracer._lock:
            self._tracer._spans.append(sp)
        return False


class Tracer:
    """A span buffer plus per-thread parent stacks.

    One process-global instance (:func:`tracer`) serves the engines; tests
    construct private ones.  ``record()`` backfills a span from timestamps
    measured elsewhere (an engine hook timing the chunk that just ran) —
    it participates in parent linking but not in the live stack.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._spans: list[Span] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._ids = itertools.count(1)

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def enable(self) -> "Tracer":
        """Start recording spans; returns self for chaining."""
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        """Stop recording (``span()`` hands out no-op spans); returns self."""
        self.enabled = False
        return self

    def span(self, name: str, **attrs):
        """Context manager recording one span around its body."""
        if not self.enabled:
            return _NULL_CTX
        sp = Span(name, next(self._ids), None, 0.0, attrs,
                  threading.get_ident())
        return _SpanCtx(self, sp)

    def record(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Backfill a completed span from caller-measured timestamps
        (seconds on the :func:`now` clock).  No-op while disabled."""
        if not self.enabled:
            return
        sp = Span(name, next(self._ids), None, t0, attrs,
                  threading.get_ident())
        sp.t1 = t1
        stack = self._stack()
        if stack:
            sp.parent_id = stack[-1].span_id
        with self._lock:
            self._spans.append(sp)

    @property
    def spans(self) -> list:
        """Snapshot copy of every recorded span, in completion order."""
        with self._lock:
            return list(self._spans)

    def drain(self) -> list:
        """All recorded spans, clearing the buffer."""
        with self._lock:
            out, self._spans = self._spans, []
        return out

    def clear(self) -> None:
        """Drop every recorded span without returning them."""
        with self._lock:
            self._spans.clear()

    def to_dicts(self) -> list:
        """:meth:`Span.to_dict` over :attr:`spans` (JSON-ready list)."""
        return [sp.to_dict() for sp in self.spans]


_GLOBAL = Tracer()


def tracer() -> Tracer:
    """The process-global tracer every engine reports through."""
    return _GLOBAL


def span(name: str, **attrs):
    """``with span("serve.request", rung=8) as sp:`` on the global tracer."""
    if not _GLOBAL.enabled:
        return _NULL_CTX
    return _GLOBAL.span(name, **attrs)


def enable() -> Tracer:
    """Turn on the process-global tracer; returns it."""
    return _GLOBAL.enable()


def disable() -> Tracer:
    """Turn off the process-global tracer; returns it."""
    return _GLOBAL.disable()


def trace_hook(name: str = "engine.chunk",
               to: Optional[Tracer] = None) -> Callable:
    """An :class:`~repro.train.engine.Engine`-style hook emitting one span
    per chunk boundary.

    Hooks run between jitted chunks, so each span covers the host interval
    from the previous boundary (or hook creation) to this one — dispatch,
    device wait, and sibling hooks included.  Attributes carry the commit
    range.  This is the sanctioned way to see chunk timing without touching
    the scan itself.
    """
    target = to if to is not None else _GLOBAL
    prev = [now(), 0]  # [boundary time, step at that boundary]

    def hook(step_end: int, _state, _aux) -> None:
        t = now()
        target.record(name, prev[0], t, start=prev[1], end=step_end)
        prev[0], prev[1] = t, step_end

    return hook


def iter_spans(spans) -> Iterator[dict]:
    """Normalize ``Span`` objects / dicts into dicts (shared by the timeline
    exporter and ``scripts/obstool.py``)."""
    for sp in spans:
        yield sp.to_dict() if isinstance(sp, Span) else sp

"""Paper §3.3: Reconstruction ICA under async SGLD — the GPU/MPS (M2)
experiment.  Figures 5-8 / 11-12 / 16-17: objective vs iteration, distance
to the SGLD optimum, speedup at P in {2, 4, 8}.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers
from repro.core import (
    RICA,
    WorkerModel,
    simulate_async,
    simulate_sync,
    speedup_vs_sync,
)


@dataclass
class RicaCurve:
    iters: np.ndarray
    objective: np.ndarray
    dist_to_opt: np.ndarray
    times: np.ndarray
    speedup: float = 1.0


def run_rica_experiment(P: int = 4, nu: float = 0.01, steps: int = 800,
                        gamma: float = 2e-3, batch: int = 512,
                        patch_dim: int = 64, num_features: int = 48,
                        tau_cap: int = 8, seed: int = 0,
                        modes=("sync", "consistent", "inconsistent")):
    """nu is the injected-noise std (paper's nu_i): sigma = nu^2 / (2 gamma)."""
    rica = RICA(patch_dim=patch_dim, num_features=num_features)
    sigma = nu**2 / (2.0 * gamma)
    w0 = rica.init_params(jax.random.PRNGKey(seed))
    # GPU/MPS-like worker model: low heterogeneity, high update cost
    wm = WorkerModel(num_workers=P, cv=0.15, heterogeneity=0.05,
                     update_cost=0.15, seed=seed)
    tr_sync = simulate_sync(wm, max(steps // P, 1), seed=seed)
    tr_async = simulate_async(wm, steps, seed=seed)

    # reference optimum: plain SGD long run (the paper's "optimal of SGLD")
    def grad(p, key):
        return rica.grad(p, rica.sample_batch(key, batch))

    opt_sampler = samplers.sgld("sync", grad, gamma=gamma, sigma=0.0)
    opt_state = opt_sampler.init(w0, jax.random.PRNGKey(seed + 9))
    keys_opt = jax.random.split(jax.random.PRNGKey(seed + 10), 2 * steps)
    opt_state, _ = jax.jit(lambda s: opt_sampler.run(
        s, keys_opt, jnp.zeros((2 * steps,), jnp.int32),
        collect=False))(opt_state)
    w_ref = opt_state.params

    eval_key = jax.random.PRNGKey(seed + 11)
    eval_batch = rica.sample_batch(eval_key, 1024)

    results = {}
    for mode in modes:
        is_sync = mode == "sync"
        n_commits = max(steps // P, 1) if is_sync else steps
        eff_batch = batch * P if is_sync else batch

        def grad_m(p, key, _b=eff_batch):
            return rica.grad(p, rica.sample_batch(key, _b))

        sampler = samplers.sgld(mode, grad_m, gamma=gamma, sigma=sigma,
                                tau=tau_cap if not is_sync else 0)
        state = sampler.init(w0, jax.random.PRNGKey(seed + 1))
        keys = jax.random.split(jax.random.PRNGKey(seed + 2), n_commits)
        if is_sync:
            delays = jnp.zeros((n_commits,), jnp.int32)
            times = tr_sync.commit_times[:n_commits]
        else:
            delays = jnp.asarray(np.minimum(tr_async.delays[:n_commits],
                                            tau_cap))
            times = tr_async.commit_times[:n_commits]
        state, traj = jax.jit(lambda s: sampler.run(s, keys, delays))(state)

        ev = max(5, n_commits // 30)
        idx = np.arange(0, n_commits, ev)
        objs = jax.jit(jax.vmap(lambda w: rica.value(w, eval_batch)))(
            traj[jnp.asarray(idx)])
        dists = jax.vmap(lambda w: jnp.linalg.norm(w - w_ref))(
            traj[jnp.asarray(idx)])
        results[mode] = RicaCurve(
            iters=idx + 1, objective=np.asarray(objs),
            dist_to_opt=np.asarray(dists), times=times[idx],
            speedup=1.0 if is_sync else speedup_vs_sync(tr_async, tr_sync))
    return results

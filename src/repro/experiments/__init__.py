from repro.experiments.regression import run_regression_experiment  # noqa: F401
from repro.experiments.rica import run_rica_experiment  # noqa: F401

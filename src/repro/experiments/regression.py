"""Paper §3.2: polynomial-regression posterior sampling, Sync vs W-Con vs
W-Icon, with the event-driven delay/wall-clock model standing in for the
paper's NUMA box (M1).  Produces the data behind Figures 1-4 / 9-15.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers
from repro.core import (
    PolyRegression,
    WorkerModel,
    simulate_async,
    simulate_sync,
    speedup_vs_sync,
)
from repro.metrics import w2_to_gaussian

MODES = ("sync", "consistent", "inconsistent")  # paper: Sync, W-Con, W-Icon


@dataclass
class Curve:
    iters: np.ndarray
    w2: np.ndarray
    times: np.ndarray
    traj2d: np.ndarray      # first two coordinates of the trajectory
    speedup: float = 1.0


def _w2_curve(traj, mu, cov, eval_every=100, window=400):
    idx, out = [], []
    for k in range(window, traj.shape[0], eval_every):
        samp = jnp.asarray(traj[k - window:k])
        out.append(float(w2_to_gaussian(samp, mu, cov)))
        idx.append(k)
    return np.asarray(idx), np.asarray(out)


def run_regression_experiment(P: int = 18, nu: float = 0.1,
                              steps: int = 6000, gamma: float = 2e-4,
                              sigma: float = 1e-3, batch: int = 256,
                              tau_cap: int = 16, seed: int = 0,
                              modes=MODES) -> dict[str, Curve]:
    """Returns one Curve per update scheme.

    Sync consumes P gradients per commit (paper's summed update) so at equal
    gradient-evaluation budget it performs steps//P commits; its wall clock
    comes from the barrier model, async from the free-running model.
    """
    reg = PolyRegression.make(jax.random.PRNGKey(seed), nu_std=nu)
    mu, cov, _ = reg.posterior_moments(sigma=sigma)
    wm = WorkerModel(num_workers=P, seed=seed)
    results: dict[str, Curve] = {}

    tr_sync = simulate_sync(wm, max(steps // P, 1), seed=seed)
    tr_async = simulate_async(wm, steps, seed=seed)

    for mode in modes:
        is_sync = mode == "sync"
        n_commits = max(steps // P, 1) if is_sync else steps
        eff_batch = batch * P if is_sync else batch

        def grad(p, key):
            return jax.grad(reg.value)(p, reg.sample_batch(key, eff_batch))

        sampler = samplers.sgld(mode, grad, gamma=gamma, sigma=sigma,
                                tau=tau_cap if not is_sync else 0)
        state = sampler.init(mu + 1.0, jax.random.PRNGKey(seed + 1))
        keys = jax.random.split(jax.random.PRNGKey(seed + 2), n_commits)
        if is_sync:
            delays = jnp.zeros((n_commits,), jnp.int32)
            times = tr_sync.commit_times[:n_commits]
        else:
            delays = jnp.asarray(np.minimum(tr_async.delays[:n_commits],
                                            tau_cap))
            times = tr_async.commit_times[:n_commits]
        state, traj = jax.jit(lambda s: sampler.run(s, keys, delays))(state)
        traj = np.asarray(traj)
        ev = max(10, n_commits // 40)
        win = max(50, min(400, n_commits // 4))
        idx, w2 = _w2_curve(traj, mu, cov, eval_every=ev, window=win)
        results[mode] = Curve(iters=idx, w2=w2, times=times[idx - 1],
                              traj2d=traj[:, :2])

    # relative speedup at equal gradient evaluations (paper subfigure b)
    sp = speedup_vs_sync(tr_async, tr_sync)
    for mode in modes:
        results[mode].speedup = 1.0 if mode == "sync" else sp
    return results


def posterior_for(nu: float, sigma: float, seed: int = 0):
    reg = PolyRegression.make(jax.random.PRNGKey(seed), nu_std=nu)
    mu, cov, _ = reg.posterior_moments(sigma=sigma)
    return reg, mu, cov

"""Synthetic data: token streams and frontend-embedding stubs.

``make_batch`` returns real arrays (smoke tests / examples);
``make_specs`` returns ShapeDtypeStruct stand-ins for the dry-run (the
"weak-type-correct, shardable, no device allocation" pattern).

Frontend stubs (the one allowed stub): VLM batches carry precomputed patch
embeddings, audio batches carry precomputed frame embeddings, both of width
``FRONTEND_DIM`` — standing in for InternViT / EnCodec outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import FRONTEND_DIM


def _text_len(cfg, shape) -> int:
    n_front = cfg.num_frontend_tokens if cfg.frontend else 0
    return shape.seq_len - n_front


def token_stream(key, vocab_size: int, batch: int, length: int) -> jnp.ndarray:
    """Markov-ish synthetic tokens (not uniform — gives a learnable signal)."""
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (batch, length), 0, vocab_size, dtype=jnp.int32)
    # repeat-previous structure: ~50% of positions copy position-1
    rep = jax.random.bernoulli(k2, 0.5, (batch, length))
    shifted = jnp.roll(base, 1, axis=1)
    return jnp.where(rep, shifted, base)


def make_batch(cfg, shape, key, kind: str | None = None):
    """Real arrays for a (arch, shape) pair. kind defaults to shape.kind."""
    kind = kind or shape.kind
    B = shape.global_batch
    kf, kt = jax.random.split(key)

    if kind in ("train", "prefill"):
        s_text = _text_len(cfg, shape)
        extra = 1 if kind == "train" else 0
        batch = {"tokens": token_stream(kt, cfg.vocab_size, B, s_text + extra)}
        if cfg.frontend:
            batch["frontend"] = jax.random.normal(
                kf, (B, cfg.num_frontend_tokens, FRONTEND_DIM), jnp.float32)
        return batch

    if kind == "decode":
        return {"tokens": jax.random.randint(kt, (B, 1), 0, cfg.vocab_size,
                                             dtype=jnp.int32),
                "cur_pos": jnp.int32(shape.seq_len - 1)}
    raise ValueError(kind)


def make_specs(cfg, shape, kind: str | None = None):
    """ShapeDtypeStruct stand-ins (dry-run; no allocation)."""
    kind = kind or shape.kind
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct

    if kind in ("train", "prefill"):
        s_text = _text_len(cfg, shape)
        extra = 1 if kind == "train" else 0
        batch = {"tokens": sds((B, s_text + extra), jnp.int32)}
        if cfg.frontend:
            batch["frontend"] = sds((B, cfg.num_frontend_tokens, FRONTEND_DIM),
                                    jnp.float32)
        return batch

    if kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32),
                "cur_pos": sds((), jnp.int32)}
    raise ValueError(kind)

"""Host-side data pipeline: double-buffered prefetch + device placement.

The dry-run shapes never allocate, but the real training loop wants batches
produced off the critical path: ``Prefetcher`` generates the next batch on a
background thread while the current step runs, and (when a mesh is given)
places it with the batch sharding the step expects.  :func:`ar1_stream`
generates the dependent (non-i.i.d.) minibatch sequence used by the
Chau-et-al.-shaped benchmark scenario.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def ar1_stream(key: jax.Array, *, steps: int, batch: int, d: int,
               rho: float = 0.9, mean: float = 0.0, scale: float = 1.0,
               dtype=jnp.float32) -> jax.Array:
    """Generate a dependent AR(1) minibatch sequence (Chau et al.-shaped).

    SGLD convergence results usually assume i.i.d. minibatches; Chau,
    Moulines & Rásonyi analyse SGLD when the data arrive as a *dependent*
    stream instead.  This produces the simplest such stream: each of the
    ``batch * d`` example coordinates follows an independent stationary
    AR(1) chain across steps,

        e_{t+1} = mean + rho * (e_t - mean) + scale * sqrt(1 - rho^2) * xi_t,

    with ``e_0`` drawn from the stationary marginal ``N(mean, scale^2)``.
    The innovation scaling keeps the *marginal* of every step equal to that
    of an i.i.d. ``N(mean, scale^2)`` stream, so swapping this in for an
    i.i.d. stream changes only the temporal dependence — the stationary
    target of a data-noise-driven scenario is unchanged.

    Args:
        key: PRNG key; the stream is a pure function of it (bit-for-bit
            reproducible from the seed — pinned by ``tests/test_zoo.py``).
        steps: number of minibatches in the sequence (the scan length).
        batch: examples per minibatch.
        d: feature dimension of each example.
        rho: AR(1) autocorrelation in ``[0, 1)``; ``rho=0`` recovers an
            i.i.d. stream.
        mean / scale: stationary marginal moments.
        dtype: element dtype of the returned stream.

    Returns:
        ``(steps, batch, d)`` array of minibatches, ready to feed to
        ``Sampler.run`` / ``Engine`` as the per-step batch axis.
    """
    if not 0.0 <= rho < 1.0:
        raise ValueError(f"rho must be in [0, 1), got {rho}")
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    k0, k_noise = jax.random.split(key)
    e0 = mean + scale * jax.random.normal(k0, (batch, d), dtype)
    innovations = jax.random.normal(k_noise, (steps - 1, batch, d), dtype)
    innov_scale = jnp.asarray(scale * (1.0 - rho ** 2) ** 0.5, dtype)

    def step(prev, xi):
        nxt = mean + rho * (prev - mean) + innov_scale * xi
        return nxt, nxt

    _, tail = jax.lax.scan(step, e0, innovations)
    return jnp.concatenate([e0[None], tail], axis=0)


class Prefetcher:
    """Wrap a batch-generating callable into a prefetching iterator.

    batch_fn(key) -> pytree;  keys are split from ``key`` per step.
    """

    def __init__(self, batch_fn: Callable[[jax.Array], PyTree], key: jax.Array,
                 mesh=None, batch_axes=("data",), depth: int = 2):
        self.batch_fn = batch_fn
        self.key = key
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes)
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _place(self, batch: PyTree) -> PyTree:
        if self.mesh is None:
            return batch

        def put(x):
            spec = P(self.batch_axes if self.batch_axes else None,
                     *([None] * (x.ndim - 1))) if x.ndim else P()
            return jax.device_put(x, NamedSharding(self.mesh, spec))

        return jax.tree_util.tree_map(put, batch)

    def _worker(self):
        key = self.key
        while not self.stop.is_set():
            key, sub = jax.random.split(key)
            batch = self._place(self.batch_fn(sub))
            while not self.stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[PyTree]:
        return self

    def __next__(self) -> PyTree:
        return self.q.get()

    def close(self):
        self.stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2.0)

"""Host-side data pipeline: double-buffered prefetch + device placement.

The dry-run shapes never allocate, but the real training loop wants batches
produced off the critical path: ``Prefetcher`` generates the next batch on a
background thread while the current step runs, and (when a mesh is given)
places it with the batch sharding the step expects.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


class Prefetcher:
    """Wrap a batch-generating callable into a prefetching iterator.

    batch_fn(key) -> pytree;  keys are split from ``key`` per step.
    """

    def __init__(self, batch_fn: Callable[[jax.Array], PyTree], key: jax.Array,
                 mesh=None, batch_axes=("data",), depth: int = 2):
        self.batch_fn = batch_fn
        self.key = key
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes)
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _place(self, batch: PyTree) -> PyTree:
        if self.mesh is None:
            return batch

        def put(x):
            spec = P(self.batch_axes if self.batch_axes else None,
                     *([None] * (x.ndim - 1))) if x.ndim else P()
            return jax.device_put(x, NamedSharding(self.mesh, spec))

        return jax.tree_util.tree_map(put, batch)

    def _worker(self):
        key = self.key
        while not self.stop.is_set():
            key, sub = jax.random.split(key)
            batch = self._place(self.batch_fn(sub))
            while not self.stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[PyTree]:
        return self

    def __next__(self) -> PyTree:
        return self.q.get()

    def close(self):
        self.stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2.0)

from repro.data.pipeline import Prefetcher, ar1_stream  # noqa: F401
from repro.data.synthetic import make_batch, make_specs, token_stream  # noqa: F401

from repro.checkpoint.io import (  # noqa: F401
    checkpoint_step,
    restore_checkpoint,
    restore_ensemble,
    save_checkpoint,
)

from repro.checkpoint.io import restore_checkpoint, save_checkpoint  # noqa: F401

from repro.checkpoint.io import (  # noqa: F401
    CorruptCheckpointError,
    checkpoint_step,
    restore_checkpoint,
    restore_ensemble,
    save_checkpoint,
)

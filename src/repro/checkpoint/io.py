"""Checkpointing: flat-path npz save/restore for arbitrary pytrees.

Ring-buffer aware: the SGLD delay history is part of the sampler state and
round-trips like any other leaf.  Writes are atomic (tmp + rename).
"""

from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "##"


def _flatten_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def visit(kp, leaf):
        path = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[path] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save_checkpoint(path: str, tree: PyTree, step: int | None = None) -> None:
    flat = _flatten_paths(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def restore_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (dtypes preserved from disk)."""
    with np.load(path) as data:
        arrays = {k: data[k] for k in data.files if k != "__step__"}

    leaves_with_paths = []

    def visit(kp, leaf):
        p = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        leaves_with_paths.append(p)

    jax.tree_util.tree_map_with_path(visit, like)
    treedef = jax.tree_util.tree_structure(like)
    missing = [p for p in leaves_with_paths if p not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(arrays[p]) for p in leaves_with_paths])


def checkpoint_step(path: str) -> int | None:
    with np.load(path) as data:
        if "__step__" in data.files:
            return int(data["__step__"])
    return None

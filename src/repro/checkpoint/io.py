"""Checkpointing: flat-path npz save/restore for arbitrary pytrees.

Ring-buffer aware: the SGLD delay history is part of the sampler state and
round-trips like any other leaf.  Writes are atomic (tmp + rename), and
every leaf carries a CRC32 in the manifest: a truncated or bit-flipped
file raises a loud :class:`CorruptCheckpointError` naming the damaged leaf
instead of a cryptic numpy failure deep in a restore.
"""

from __future__ import annotations

import os
import tempfile
import zipfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "##"

# np.savez writes bfloat16 (an ml_dtypes extension type) as opaque void
# bytes that numpy reloads as |V2 and jax rejects; bf16 leaves — transformer
# banks — are stored viewed as uint16 plus a manifest of their paths.
_BF16 = np.dtype(jnp.bfloat16)
_BF16_KEY = "__bf16__"
# per-leaf integrity manifest: parallel arrays of flat paths and the CRC32
# of each leaf's stored bytes (computed on the uint16 view for bf16 leaves)
_CRC_PATHS_KEY = "__crc_paths__"
_CRC_VALS_KEY = "__crc_vals__"
_META_KEYS = ("__step__", _BF16_KEY, _CRC_PATHS_KEY, _CRC_VALS_KEY)


class CorruptCheckpointError(RuntimeError):
    """A checkpoint file is unreadable or fails its integrity manifest
    (truncated write, bit flip, damaged zip member)."""


def _flatten_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def visit(kp, leaf):
        path = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[path] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def save_checkpoint(path: str, tree: PyTree, step: int | None = None) -> None:
    flat = _flatten_paths(tree)
    bf16_paths = [p for p, a in flat.items() if a.dtype == _BF16]
    for p in bf16_paths:
        flat[p] = flat[p].view(np.uint16)
    if bf16_paths:
        flat[_BF16_KEY] = np.asarray(bf16_paths)
    crc_paths = sorted(flat)  # leaf paths only — meta keys join below
    flat[_CRC_PATHS_KEY] = np.asarray(crc_paths)
    flat[_CRC_VALS_KEY] = np.asarray([_crc(flat[p]) for p in crc_paths],
                                     np.uint32)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def _read_arrays(path: str) -> dict[str, np.ndarray]:
    """Load every member of an npz, failing loudly on damage.

    numpy reads members lazily through ``zipfile``, so truncation or bit
    flips surface as a zoo of low-level errors mid-iteration; normalize all
    of them (and a CRC-manifest mismatch) to :class:`CorruptCheckpointError`.
    """
    try:
        with np.load(path) as data:
            arrays = {k: data[k] for k in data.files}
    except (zipfile.BadZipFile, zlib.error, ValueError, KeyError, EOFError,
            OSError) as e:
        raise CorruptCheckpointError(f"{path}: unreadable checkpoint "
                                     f"({type(e).__name__}: {e})") from e
    if _CRC_PATHS_KEY in arrays:  # legacy checkpoints carry no manifest
        vals = arrays[_CRC_VALS_KEY]
        for p, want in zip(arrays[_CRC_PATHS_KEY].tolist(), vals.tolist()):
            if p not in arrays:
                raise CorruptCheckpointError(
                    f"{path}: leaf {p!r} in the CRC manifest is missing")
            if _crc(arrays[p]) != int(want):
                raise CorruptCheckpointError(
                    f"{path}: leaf {p!r} fails its CRC32 — the file was "
                    "truncated or bit-flipped since it was written")
    return arrays


def restore_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (dtypes preserved from disk).

    Raises :class:`CorruptCheckpointError` when the file is truncated,
    bit-flipped, or otherwise fails its per-leaf CRC manifest."""
    data = _read_arrays(path)
    bf16 = (set(data[_BF16_KEY].tolist())
            if _BF16_KEY in data else set())
    arrays = {k: (v.view(_BF16) if k in bf16 else v)
              for k, v in data.items() if k not in _META_KEYS}

    leaves_with_paths = []

    def visit(kp, _leaf):
        p = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        leaves_with_paths.append(p)

    jax.tree_util.tree_map_with_path(visit, like)
    treedef = jax.tree_util.tree_structure(like)
    missing = [p for p in leaves_with_paths if p not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(arrays[p]) for p in leaves_with_paths])


def restore_ensemble(path: str, like: PyTree, *,
                     num_chains: int | None = None) -> PyTree:
    """Restore chain-stacked ("ensemble layout") params for serving.

    ``like`` is the *single-chain* params structure; the shapes on disk
    decide the layout.  An ensemble checkpoint — every leaf carrying one
    extra leading axis of a common chain count (what
    :meth:`~repro.cluster.executor.ClusterEngine.save_ensemble` writes) —
    restores as-is; a single-model checkpoint is broadcast to
    ``num_chains`` identical chains (required then).  Mixed or mismatched
    layouts fail loudly, as does a damaged file
    (:class:`CorruptCheckpointError`).
    """
    from repro.utils import tree_broadcast_leading

    tree = restore_checkpoint(path, like)
    got = jax.tree_util.tree_leaves(tree)
    want = [tuple(jnp.shape(x)) for x in jax.tree_util.tree_leaves(like)]
    if all(g.shape == w for g, w in zip(got, want)):
        if num_chains is None:
            raise ValueError(
                f"{path} holds a single-model checkpoint; pass num_chains= "
                "to broadcast it into a chain bank")
        return tree_broadcast_leading(tree, num_chains)
    stacked = [g.ndim > 0 and g.shape[1:] == w for g, w in zip(got, want)]
    chain_counts = {g.shape[0] for g, s in zip(got, stacked) if s}
    if not all(stacked) or len(chain_counts) != 1:
        raise ValueError(
            f"{path} is neither a single-model nor a chain-stacked "
            f"checkpoint for the given `like` structure")
    c = chain_counts.pop()
    if num_chains is not None and num_chains != c:
        raise ValueError(f"{path} holds {c} chains, asked for {num_chains}")
    return tree


def checkpoint_step(path: str) -> int | None:
    data = _read_arrays(path)
    if "__step__" in data:
        return int(data["__step__"])
    return None

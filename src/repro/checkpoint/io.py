"""Checkpointing: flat-path npz save/restore for arbitrary pytrees.

Ring-buffer aware: the SGLD delay history is part of the sampler state and
round-trips like any other leaf.  Writes are atomic (tmp + rename).
"""

from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "##"

# np.savez writes bfloat16 (an ml_dtypes extension type) as opaque void
# bytes that numpy reloads as |V2 and jax rejects; bf16 leaves — transformer
# banks — are stored viewed as uint16 plus a manifest of their paths.
_BF16 = np.dtype(jnp.bfloat16)
_BF16_KEY = "__bf16__"


def _flatten_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def visit(kp, leaf):
        path = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[path] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save_checkpoint(path: str, tree: PyTree, step: int | None = None) -> None:
    flat = _flatten_paths(tree)
    bf16_paths = [p for p, a in flat.items() if a.dtype == _BF16]
    for p in bf16_paths:
        flat[p] = flat[p].view(np.uint16)
    if bf16_paths:
        flat[_BF16_KEY] = np.asarray(bf16_paths)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def restore_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (dtypes preserved from disk)."""
    with np.load(path) as data:
        bf16 = (set(data[_BF16_KEY].tolist())
                if _BF16_KEY in data.files else set())
        arrays = {k: (data[k].view(_BF16) if k in bf16 else data[k])
                  for k in data.files if k not in ("__step__", _BF16_KEY)}

    leaves_with_paths = []

    def visit(kp, _leaf):
        p = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        leaves_with_paths.append(p)

    jax.tree_util.tree_map_with_path(visit, like)
    treedef = jax.tree_util.tree_structure(like)
    missing = [p for p in leaves_with_paths if p not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(arrays[p]) for p in leaves_with_paths])


def restore_ensemble(path: str, like: PyTree, *,
                     num_chains: int | None = None) -> PyTree:
    """Restore chain-stacked ("ensemble layout") params for serving.

    ``like`` is the *single-chain* params structure; the shapes on disk
    decide the layout.  An ensemble checkpoint — every leaf carrying one
    extra leading axis of a common chain count (what
    :meth:`~repro.cluster.executor.ClusterEngine.save_ensemble` writes) —
    restores as-is; a single-model checkpoint is broadcast to
    ``num_chains`` identical chains (required then).  Mixed or mismatched
    layouts fail loudly.
    """
    from repro.utils import tree_broadcast_leading

    tree = restore_checkpoint(path, like)
    got = jax.tree_util.tree_leaves(tree)
    want = [tuple(jnp.shape(x)) for x in jax.tree_util.tree_leaves(like)]
    if all(g.shape == w for g, w in zip(got, want)):
        if num_chains is None:
            raise ValueError(
                f"{path} holds a single-model checkpoint; pass num_chains= "
                "to broadcast it into a chain bank")
        return tree_broadcast_leading(tree, num_chains)
    stacked = [g.ndim > 0 and g.shape[1:] == w for g, w in zip(got, want)]
    chain_counts = {g.shape[0] for g, s in zip(got, stacked) if s}
    if not all(stacked) or len(chain_counts) != 1:
        raise ValueError(
            f"{path} is neither a single-model nor a chain-stacked "
            f"checkpoint for the given `like` structure")
    c = chain_counts.pop()
    if num_chains is not None and num_chains != c:
        raise ValueError(f"{path} holds {c} chains, asked for {num_chains}")
    return tree


def checkpoint_step(path: str) -> int | None:
    with np.load(path) as data:
        if "__step__" in data.files:
            return int(data["__step__"])
    return None

"""Composable sampler-transform API for the delayed-gradient sampler zoo.

Optax-style ``(init, update)`` primitives — :func:`delay_read`,
:func:`gradients`, :func:`svrg_gradients`, :func:`stale_correction`,
:func:`langevin_noise`, :func:`apply_sgld_update`, :func:`sghmc_update`,
:func:`fused_update`, :func:`pipeline_overlap` — a :func:`chain`
combinator, :class:`DelayPolicy` implementations, and the :func:`sgld` /
:func:`svrg` / :func:`sghmc` presets reproducing the paper's four read
models across the zoo.  The unified training driver over these samplers is
:class:`repro.train.engine.Engine`; the equation-to-transform map lives in
``docs/THEORY.md`` and the transform catalog in ``docs/SAMPLERS.md``.
"""

from repro.samplers.base import Sampler, SamplerState  # noqa: F401
from repro.samplers.policies import (  # noqa: F401
    ConstantDelay,
    DelayPolicy,
    PerCoordinateDelay,
    TraceDelay,
)
from repro.samplers.presets import (  # noqa: F401
    MODES,
    from_config,
    sghmc,
    sgld,
    svrg,
)
from repro.samplers.transform import (  # noqa: F401
    SamplerTransform,
    StepContext,
    chain,
    stateless,
)
from repro.samplers.transforms import (  # noqa: F401
    MaskedBatch,
    SVRGState,
    apply_sgld_update,
    batch_mask,
    batch_scaled_gamma,
    delay_read,
    fused_update,
    gradients,
    langevin_noise,
    masked_gradients,
    masked_mean,
    noise_like,
    pipeline_overlap,
    sghmc_update,
    sgld_apply,
    stale_correction,
    svrg_gradients,
)

"""Composable sampler-transform API for delayed-gradient SGLD.

Optax-style ``(init, update)`` primitives — :func:`delay_read`,
:func:`gradients`, :func:`langevin_noise`, :func:`apply_sgld_update`,
:func:`fused_update`, :func:`pipeline_overlap` — a :func:`chain`
combinator, :class:`DelayPolicy` implementations, and the :func:`sgld`
presets reproducing the paper's four read models.  The unified training
driver over these samplers is :class:`repro.train.engine.Engine`.
"""

from repro.samplers.base import Sampler, SamplerState  # noqa: F401
from repro.samplers.policies import (  # noqa: F401
    ConstantDelay,
    DelayPolicy,
    PerCoordinateDelay,
    TraceDelay,
)
from repro.samplers.presets import MODES, from_config, sgld  # noqa: F401
from repro.samplers.transform import (  # noqa: F401
    SamplerTransform,
    StepContext,
    chain,
    stateless,
)
from repro.samplers.transforms import (  # noqa: F401
    MaskedBatch,
    apply_sgld_update,
    batch_mask,
    batch_scaled_gamma,
    delay_read,
    fused_update,
    gradients,
    langevin_noise,
    masked_gradients,
    masked_mean,
    noise_like,
    pipeline_overlap,
    sgld_apply,
)

"""The sampler driver: threads PRNG keys, the step counter, and the chained
transform state through one commit, and offers a jit-friendly scan runner.

The driver is deliberately thin — every modelling decision (stale reads,
noise, fusion, overlap) lives in the transform chain, so new read models
compose without touching this file.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.samplers.transform import SamplerTransform, StepContext

if TYPE_CHECKING:  # repro.core.schedules.Schedule; kept lazy to avoid a cycle
    Schedule = Callable[[jnp.ndarray], jnp.ndarray]

PyTree = Any


class SamplerState(NamedTuple):
    """Carry for the scan: iterate, commit counter, PRNG key, chain state."""

    params: PyTree
    step: jax.Array          # int32
    key: jax.Array           # PRNG key
    inner: Any               # tuple of per-transform states (from chain)


@dataclass(frozen=True)
class Sampler:
    """A transform chain + a gamma schedule, driven one commit at a time."""

    transform: SamplerTransform
    gamma: float | Schedule = 1e-2

    def gamma_at(self, step: jnp.ndarray) -> jnp.ndarray:
        """Step size at commit ``step``: the schedule evaluated there, or
        the constant ``gamma`` as a float32 scalar."""
        if callable(self.gamma):
            return self.gamma(step)
        return jnp.asarray(self.gamma, jnp.float32)

    # -- init ---------------------------------------------------------------
    def init(self, params: PyTree, key: jax.Array) -> SamplerState:
        """Fresh state at ``params``: step 0, the carried chain ``key``,
        and every transform's ``init`` state in ``inner`` (chain order)."""
        return SamplerState(params=params, step=jnp.int32(0), key=key,
                            inner=self.transform.init(params))

    # -- one commit ----------------------------------------------------------
    def step(self, state: SamplerState, batch: Any = None,
             delay: jax.Array | int = 0,
             keys: tuple[jax.Array, jax.Array] | None = None
             ) -> tuple[SamplerState, Any]:
        """Run the chain once; ``delay`` is the realized staleness tau_k.
        Returns ``(new_state, aux)`` with aux from the gradients stage.

        By default the per-step ``(noise, coordinate-delay)`` keys are split
        off the carried chain key, which ties a commit's noise to its global
        position in the commit sequence.  Passing explicit ``keys`` hands
        that derivation to the caller (e.g. per-worker attribution keyed on
        ``(worker_id, worker-local slot)``); the carried key is then left
        untouched so the caller's derivation stays the only source of
        randomness.
        """
        if keys is not None:
            key, (k_noise, k_delay) = state.key, keys
        else:
            key, k_noise, k_delay = jax.random.split(state.key, 3)
        ctx = StepContext(
            params=state.params,
            x_hat=state.params,
            grads=None,
            noise=None,
            aux=None,
            gamma=self.gamma_at(state.step),
            key_noise=k_noise,
            key_delay=k_delay,
            step=state.step,
            delay=jnp.asarray(delay, jnp.int32),
            batch=batch,
        )
        ctx, inner = self.transform.update(ctx, state.inner)
        return SamplerState(ctx.params, state.step + 1, key, inner), ctx.aux

    # -- a jit-compiled multi-step runner -------------------------------------
    def run(self, state: SamplerState, batches, delays=None, *,
            collect: bool = True):
        """lax.scan over pre-generated (batches, delays); returns final state
        and (optionally) the iterate trajectory stacked on axis 0."""
        if delays is None:
            n = jax.tree_util.tree_leaves(batches)[0].shape[0]
            delays = jnp.zeros((n,), jnp.int32)

        def body(s, inp):
            batch, d = inp
            s, _ = self.step(s, batch, d)
            out = s.params if collect else None
            return s, out

        return jax.lax.scan(body, state, (batches, jnp.asarray(delays, jnp.int32)))

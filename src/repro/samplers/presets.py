"""One-line presets: the paper's four read models as transform chains.

    sampler = samplers.sgld("consistent", grad_fn, gamma=1e-2, sigma=0.5, tau=4)

is exactly

    Sampler(chain(delay_read(TraceDelay(tau)),
                  gradients(grad_fn),
                  langevin_noise(sigma),
                  apply_sgld_update()),
            gamma=gamma)

and reproduces the legacy ``SGLDSampler`` trajectories bit-for-bit.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.samplers.base import Sampler
from repro.samplers.policies import DelayPolicy, PerCoordinateDelay, TraceDelay
from repro.samplers.transform import SamplerTransform, chain
from repro.samplers.transforms import (
    GradFn,
    apply_sgld_update,
    batch_scaled_gamma,
    delay_read,
    fused_update,
    gradients,
    langevin_noise,
    masked_gradients,
    pipeline_overlap,
)

MODES = ("sync", "consistent", "inconsistent", "pipeline")


def sgld(mode: str, grad_fn: GradFn, *, gamma=1e-2, sigma: float = 1.0,
         tau: int = 0, has_aux: bool = False, delay_policy: DelayPolicy | None = None,
         fused: bool = False, interpret: bool = True,
         noise_dtype=jnp.float32, base_batch: int | None = None) -> Sampler:
    """The paper's SGLD in any of its four read models.

    - ``sync``         X_hat = X_k (barrier baseline; tau = 0).
    - ``consistent``   X_hat = X_{k - tau_k} whole-vector stale read (W-Con).
    - ``inconsistent`` [X_hat]_i = [X_{s_i}]_i per-coordinate read (W-Icon).
    - ``pipeline``     previous step's gradient (tau = 1 W-Con on gradients)
                       whose all-reduce overlaps the next step's compute.

    ``fused=True`` commits through the Pallas fused kernel (noise generated
    in VMEM); ``delay_policy`` overrides the mode's default policy.

    ``base_batch`` switches the chain to the heterogeneous-minibatch
    contract: ``grad_fn(params, example)`` becomes a *per-example* oracle
    evaluated through :func:`~repro.samplers.transforms.masked_gradients`
    over the executor's bucket-padded :class:`MaskedBatch` views, and the
    step size is linearly rescaled by ``size / base_batch``
    (:func:`~repro.samplers.transforms.batch_scaled_gamma`).
    """
    if mode not in MODES:
        raise ValueError(f"unknown SGLD mode {mode!r}")
    if mode in ("consistent", "inconsistent") and delay_policy is None and tau < 1:
        raise ValueError(f"mode {mode!r} needs tau >= 1")

    parts: list[SamplerTransform] = []
    if mode in ("consistent", "inconsistent"):
        if delay_policy is None:
            delay_policy = (PerCoordinateDelay(tau, fused=fused, interpret=interpret)
                            if mode == "inconsistent" else TraceDelay(tau))
        parts.append(delay_read(delay_policy))
    if base_batch is None:
        parts.append(gradients(grad_fn, has_aux=has_aux))
    else:
        parts.append(batch_scaled_gamma(base_batch))
        parts.append(masked_gradients(grad_fn, has_aux=has_aux))
    if mode == "pipeline":
        parts.append(pipeline_overlap())
    if fused:
        parts.append(fused_update(sigma, interpret=interpret))
    else:
        parts.append(langevin_noise(sigma, noise_dtype=noise_dtype))
        parts.append(apply_sgld_update())
    return Sampler(transform=chain(*parts), gamma=gamma)


def from_config(cfg, grad_fn: GradFn, has_aux: bool = False, *,
                fused: bool = False, interpret: bool = True) -> Sampler:
    """Build the preset matching a legacy ``SGLDConfig`` (duck-typed)."""
    return sgld(cfg.mode, grad_fn, gamma=cfg.gamma, sigma=cfg.sigma,
                tau=cfg.tau, has_aux=has_aux, fused=fused, interpret=interpret,
                noise_dtype=getattr(cfg, "noise_dtype", jnp.float32))

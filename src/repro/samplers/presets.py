"""One-line presets: the sampler zoo as transform chains.

    sampler = samplers.sgld("consistent", grad_fn, gamma=1e-2, sigma=0.5, tau=4)

is exactly

    Sampler(chain(delay_read(TraceDelay(tau)),
                  gradients(grad_fn),
                  langevin_noise(sigma),
                  apply_sgld_update()),
            gamma=gamma)

and reproduces the legacy ``SGLDSampler`` trajectories bit-for-bit.  The
zoo variants reuse the same skeleton: :func:`svrg` swaps the gradient stage
for the control-variate :func:`~repro.samplers.transforms.svrg_gradients`
oracle, :func:`sghmc` swaps the commit pair for the momentum
:func:`~repro.samplers.transforms.sghmc_update`, and every preset takes
``stale_strength`` / ``stale_gamma_scale`` to splice the Chen-et-al.
:func:`~repro.samplers.transforms.stale_correction` in after the gradient
stage.  The equation-to-transform map lives in ``docs/THEORY.md``.
"""

from __future__ import annotations

from typing import Any, Callable

import jax.numpy as jnp

from repro.samplers.base import Sampler
from repro.samplers.policies import DelayPolicy, PerCoordinateDelay, TraceDelay
from repro.samplers.transform import SamplerTransform, chain
from repro.samplers.transforms import (
    GradFn,
    apply_sgld_update,
    batch_scaled_gamma,
    delay_read,
    fused_update,
    gradients,
    langevin_noise,
    masked_gradients,
    pipeline_overlap,
    sghmc_update,
    stale_correction,
    svrg_gradients,
)

MODES = ("sync", "consistent", "inconsistent", "pipeline")


def _front_parts(mode: str, *, tau: int, delay_policy: DelayPolicy | None,
                 fused: bool, interpret: bool) -> list[SamplerTransform]:
    """The read-model head shared by every preset: validates ``mode`` /
    ``tau`` and returns the (possibly empty) ``delay_read`` stage."""
    if mode not in MODES:
        raise ValueError(f"unknown sampler mode {mode!r}")
    if mode in ("consistent", "inconsistent") and delay_policy is None \
            and tau < 1:
        raise ValueError(f"mode {mode!r} needs tau >= 1")
    parts: list[SamplerTransform] = []
    if mode in ("consistent", "inconsistent"):
        if delay_policy is None:
            delay_policy = (PerCoordinateDelay(tau, fused=fused,
                                               interpret=interpret)
                            if mode == "inconsistent" else TraceDelay(tau))
        parts.append(delay_read(delay_policy))
    return parts


def _stale_parts(stale_strength: float | None,
                 stale_gamma_scale: float) -> list[SamplerTransform]:
    """The optional Chen-et-al. correction stage (after the gradients)."""
    if stale_strength is None and stale_gamma_scale == 0.0:
        return []
    return [stale_correction(strength=(stale_strength or 0.0),
                             gamma_scale=stale_gamma_scale)]


def sgld(mode: str, grad_fn: GradFn, *, gamma=1e-2, sigma: float = 1.0,
         tau: int = 0, has_aux: bool = False, delay_policy: DelayPolicy | None = None,
         fused: bool = False, interpret: bool = True,
         noise_dtype=jnp.float32, base_batch: int | None = None,
         stale_strength: float | None = None,
         stale_gamma_scale: float = 0.0) -> Sampler:
    """The paper's SGLD in any of its four read models.

    - ``sync``         X_hat = X_k (barrier baseline; tau = 0).
    - ``consistent``   X_hat = X_{k - tau_k} whole-vector stale read (W-Con).
    - ``inconsistent`` [X_hat]_i = [X_{s_i}]_i per-coordinate read (W-Icon).
    - ``pipeline``     previous step's gradient (tau = 1 W-Con on gradients)
                       whose all-reduce overlaps the next step's compute.

    ``fused=True`` commits through the Pallas fused kernel (noise generated
    in VMEM); ``delay_policy`` overrides the mode's default policy.

    ``base_batch`` switches the chain to the heterogeneous-minibatch
    contract: ``grad_fn(params, example)`` becomes a *per-example* oracle
    evaluated through :func:`~repro.samplers.transforms.masked_gradients`
    over the executor's bucket-padded :class:`MaskedBatch` views, and the
    step size is linearly rescaled by ``size / base_batch``
    (:func:`~repro.samplers.transforms.batch_scaled_gamma`).

    ``stale_strength`` / ``stale_gamma_scale`` splice the Chen-et-al.
    :func:`~repro.samplers.transforms.stale_correction` in after the
    gradient stage (a bitwise no-op on commits with staleness 0).
    """
    parts = _front_parts(mode, tau=tau, delay_policy=delay_policy,
                         fused=fused, interpret=interpret)
    if base_batch is None:
        parts.append(gradients(grad_fn, has_aux=has_aux))
    else:
        parts.append(batch_scaled_gamma(base_batch))
        parts.append(masked_gradients(grad_fn, has_aux=has_aux))
    parts.extend(_stale_parts(stale_strength, stale_gamma_scale))
    if mode == "pipeline":
        parts.append(pipeline_overlap())
    if fused:
        parts.append(fused_update(sigma, interpret=interpret))
    else:
        parts.append(langevin_noise(sigma, noise_dtype=noise_dtype))
        parts.append(apply_sgld_update())
    return Sampler(transform=chain(*parts), gamma=gamma)


def svrg(mode: str, grad_fn: GradFn, full_grad_fn: Callable[[Any], Any], *,
         anchor_every: int = 64, gamma=1e-2, sigma: float = 1.0,
         tau: int = 0, has_aux: bool = False,
         delay_policy: DelayPolicy | None = None, interpret: bool = True,
         noise_dtype=jnp.float32, base_batch: int | None = None,
         stale_strength: float | None = None,
         stale_gamma_scale: float = 0.0) -> Sampler:
    """SVRG-Langevin under any read model: :func:`sgld` with the gradient
    stage swapped for :func:`~repro.samplers.transforms.svrg_gradients`.

    ``full_grad_fn(params)`` evaluates the full-data gradient at the anchor
    (refreshed every ``anchor_every`` commits inside the scanned carry);
    ``grad_fn`` keeps the surrounding batch contract — a minibatch oracle by
    default, a *per-example* oracle under ``base_batch`` (the masked
    heterogeneous path, with the same linear ``gamma ∝ b`` scaling as
    :func:`sgld`).  ``stale_strength`` / ``stale_gamma_scale`` compose the
    Chen-et-al. correction after the variance-reduced oracle.
    """
    parts = _front_parts(mode, tau=tau, delay_policy=delay_policy,
                         fused=False, interpret=interpret)
    if base_batch is not None:
        parts.append(batch_scaled_gamma(base_batch))
    parts.append(svrg_gradients(grad_fn, full_grad_fn,
                                anchor_every=anchor_every, has_aux=has_aux))
    parts.extend(_stale_parts(stale_strength, stale_gamma_scale))
    if mode == "pipeline":
        parts.append(pipeline_overlap())
    parts.append(langevin_noise(sigma, noise_dtype=noise_dtype))
    parts.append(apply_sgld_update())
    return Sampler(transform=chain(*parts), gamma=gamma)


def sghmc(mode: str, grad_fn: GradFn, *, gamma=1e-2, sigma: float = 1.0,
          friction: float = 1.0, precond: Any = None, tau: int = 0,
          has_aux: bool = False, delay_policy: DelayPolicy | None = None,
          interpret: bool = True, noise_dtype=jnp.float32,
          base_batch: int | None = None,
          stale_strength: float | None = None,
          stale_gamma_scale: float = 0.0) -> Sampler:
    """Stochastic-gradient HMC under any read model: :func:`sgld` with the
    ``langevin_noise + apply_sgld_update`` pair swapped for the momentum
    commit :func:`~repro.samplers.transforms.sghmc_update`.

    ``friction`` is the underdamped drag ``a`` and ``precond`` an optional
    diagonal inverse-mass preconditioner (scalar or params-shaped pytree) —
    the momentum/preconditioned variant motivated by the faster
    non-log-concave SGLD-family rates of Zou, Xu & Gu.  The momentum buffer
    lives in the sampler state (scanned carry), so it survives chunking and
    checkpoint round-trips.  All the delayed-read, masked-batch, and
    stale-correction machinery composes exactly as in :func:`sgld`.
    """
    parts = _front_parts(mode, tau=tau, delay_policy=delay_policy,
                         fused=False, interpret=interpret)
    if base_batch is None:
        parts.append(gradients(grad_fn, has_aux=has_aux))
    else:
        parts.append(batch_scaled_gamma(base_batch))
        parts.append(masked_gradients(grad_fn, has_aux=has_aux))
    parts.extend(_stale_parts(stale_strength, stale_gamma_scale))
    if mode == "pipeline":
        parts.append(pipeline_overlap())
    parts.append(sghmc_update(sigma, friction=friction, precond=precond,
                              noise_dtype=noise_dtype))
    return Sampler(transform=chain(*parts), gamma=gamma)


def from_config(cfg, grad_fn: GradFn, has_aux: bool = False, *,
                fused: bool = False, interpret: bool = True) -> Sampler:
    """Build the preset matching a legacy ``SGLDConfig`` (duck-typed)."""
    return sgld(cfg.mode, grad_fn, gamma=cfg.gamma, sigma=cfg.sigma,
                tau=cfg.tau, has_aux=has_aux, fused=fused, interpret=interpret,
                noise_dtype=getattr(cfg, "noise_dtype", jnp.float32))

"""Delay policies: how a commit chooses the stale read point ``X_hat_k``.

A :class:`DelayPolicy` replaces the old loose ``delay_k`` argument (and the
``ring`` special-case inside ``SGLDState``): ``delay_read(policy)`` owns the
iterate ring buffer and delegates the read to the policy.

- :class:`ConstantDelay` — worst-case fixed staleness ``tau`` (theory
  experiments), with the can't-be-staler-than-``k`` warm-up built in.
- :class:`TraceDelay` — consistent (W-Con, Assumption 2.1) whole-vector read
  at the realized staleness fed per step (e.g. from a
  :class:`~repro.core.delay_model.DelayTrace`).
- :class:`PerCoordinateDelay` — inconsistent (W-Icon, Assumption 2.3)
  per-coordinate read ``[X_hat]_i = [X_{s_i}]_i`` with
  ``s_i ~ U{0..tau_k}``; set ``fused=True`` to gather through the Pallas
  ``delay_gather`` kernel instead of the jnp reference path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.core.delay import (
    RingBuffer,
    read_consistent,
    read_inconsistent,
    sample_coordinate_delays,
)
from repro.kernels.ops import fused_delay_gather
from repro.samplers.transform import StepContext

PyTree = Any


@runtime_checkable
class DelayPolicy(Protocol):
    """Chooses the read point for one commit from the iterate history.

    ``tau`` is the static maximum staleness (ring depth is ``tau + 1``);
    ``read`` maps the per-step context + ring to the pytree ``X_hat_k``.
    """

    tau: int

    def read(self, ctx: StepContext, ring: RingBuffer) -> PyTree:
        ...


@dataclass(frozen=True)
class ConstantDelay:
    """W-Con read at fixed staleness ``tau`` (clamped to the commit count)."""

    tau: int

    def read(self, ctx: StepContext, ring: RingBuffer) -> PyTree:
        """Whole-vector read ``X_{k - min(k, tau)}`` from the ring."""
        return read_consistent(ring, jnp.minimum(ctx.step, self.tau))


@dataclass(frozen=True)
class TraceDelay:
    """W-Con read at the realized per-commit staleness ``ctx.delay``."""

    tau: int

    def read(self, ctx: StepContext, ring: RingBuffer) -> PyTree:
        """Whole-vector read ``X_{k - ctx.delay}`` from the ring."""
        return read_consistent(ring, ctx.delay)


@dataclass(frozen=True)
class PerCoordinateDelay:
    """W-Icon read: each coordinate from its own snapshot in ``[k-tau_k, k]``."""

    tau: int
    fused: bool = False
    interpret: bool = True

    def read(self, ctx: StepContext, ring: RingBuffer) -> PyTree:
        """Per-coordinate read: sample each coordinate's staleness in
        ``[0, ctx.delay]`` from ``ctx.key_delay`` and gather it from the
        ring (through the Pallas ``delay_gather`` kernel when ``fused``)."""
        delays = sample_coordinate_delays(ctx.key_delay, ring, ctx.delay)
        if self.fused:
            return fused_delay_gather(ring.history, delays, ring.head,
                                      ring.depth, interpret=self.interpret)
        return read_inconsistent(ring, delays)

"""The five sampler-transform primitives behind the paper's read models.

Raw leafwise math (``noise_like`` / ``sgld_apply``) lives here too — it is
the single source of truth shared by the transforms, the legacy
``SGLDSampler`` shim, and the launch-stack step builders, which is what
makes the new presets bit-compatible with the old sampler.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import delay as delay_lib
from repro.kernels.ops import fused_langevin_update
from repro.samplers.transform import SamplerTransform, StepContext, stateless
from repro.utils import tree_keys, tree_zeros_like

if TYPE_CHECKING:  # annotation-only; a runtime import would cycle via core
    from repro.samplers.policies import DelayPolicy

PyTree = Any
GradFn = Callable[..., PyTree]  # grad_fn(params, batch) -> grads | (grads, aux)


class MaskedBatch(NamedTuple):
    """A bucket-padded minibatch view: ``data`` leaves carry a leading
    bucket axis of ``B >= size`` examples, of which only the first ``size``
    are real.  The executor pads every commit's window up a shape-bucket
    ladder so a heterogeneous batch schedule compiles one trace per rung —
    the same discipline :class:`~repro.cluster.serve.ServeEngine` applies to
    query batches — and :func:`masked_gradients` averages over exactly the
    real examples, so padding rows never touch the math."""

    data: Any        # pytree; leaves (B, ...) bucket-padded examples
    size: jax.Array  # () int32 count of real examples (<= B)


def batch_mask(batch: MaskedBatch) -> jax.Array:
    """(B,) float32 indicator of the real examples in a padded view."""
    b = jax.tree_util.tree_leaves(batch.data)[0].shape[0]
    return (jnp.arange(b) < batch.size).astype(jnp.float32)


def masked_mean(values: PyTree, size: jax.Array) -> PyTree:
    """Mean of the first ``size`` rows of every ``(B, ...)`` leaf — the
    single reduction behind the masked gradient oracle (bitwise equal to
    ``jnp.mean`` when ``size == B``, since the mask multiplies by 1.0)."""

    def reduce(v):
        mask = (jnp.arange(v.shape[0]) < size).astype(v.dtype)
        mask = mask.reshape((-1,) + (1,) * (v.ndim - 1))
        return jnp.sum(v * mask, axis=0) / size.astype(v.dtype)

    return jax.tree_util.tree_map(reduce, values)


# ---------------------------------------------------------------------------
# raw leafwise math (shared with the legacy shim and launch/steps.py)
# ---------------------------------------------------------------------------
def noise_like(key: jax.Array, params: PyTree, scale: jnp.ndarray, dtype) -> PyTree:
    """sqrt(2 sigma gamma) * G_k, one independent key per leaf, shard-local."""
    keytree = tree_keys(key, params)
    return jax.tree_util.tree_map(
        lambda k, p: (scale * jax.random.normal(k, jnp.shape(p), dtype)).astype(p.dtype),
        keytree,
        params,
    )


def sgld_apply(params: PyTree, grads: PyTree, gamma: jnp.ndarray, noise: PyTree) -> PyTree:
    """x - gamma*g + noise, leafwise (the fused Pallas path is ``fused_update``)."""
    return jax.tree_util.tree_map(
        lambda p, g, n: (p - gamma.astype(p.dtype) * g.astype(p.dtype) + n).astype(p.dtype),
        params,
        grads,
        noise,
    )


def _key_bits(key: jax.Array) -> jax.Array:
    """(2,) uint32 view of a PRNG key (raw or typed) for the Pallas RNG."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.astype(jnp.uint32)


# ---------------------------------------------------------------------------
# transform primitives
# ---------------------------------------------------------------------------
def gradients(grad_fn: GradFn, has_aux: bool = False) -> SamplerTransform:
    """Evaluate the gradient oracle at the (possibly stale) read point."""

    def update(ctx: StepContext) -> StepContext:
        out = grad_fn(ctx.x_hat, ctx.batch)
        grads, aux = out if has_aux else (out, None)
        return ctx._replace(grads=grads, aux=aux)

    return stateless(update)


def masked_gradients(grad_fn: GradFn, has_aux: bool = False) -> SamplerTransform:
    """Evaluate a *per-example* gradient oracle over a :class:`MaskedBatch`.

    ``grad_fn(params, example)`` is vmapped over the padded bucket axis and
    reduced with :func:`masked_mean`, so the committed gradient averages
    exactly the ``size`` real examples regardless of how far the bucket
    ladder padded the view — mixed batch sizes change the mask contents,
    never the trace.  With ``has_aux`` the per-example aux is masked-mean
    reduced the same way.
    """

    def update(ctx: StepContext) -> StepContext:
        mb = ctx.batch
        if not isinstance(mb, MaskedBatch):
            raise TypeError("masked_gradients needs a MaskedBatch (did you "
                            "mean gradients(), or forget batch_policy=?)")
        out = jax.vmap(lambda e: grad_fn(ctx.x_hat, e))(mb.data)
        per_grads, per_aux = out if has_aux else (out, None)
        grads = masked_mean(per_grads, mb.size)
        aux = masked_mean(per_aux, mb.size) if has_aux else None
        return ctx._replace(grads=grads, aux=aux)

    return stateless(update)


def batch_scaled_gamma(base_batch: int) -> SamplerTransform:
    """Linear step-size scaling for heterogeneous batches: a commit that
    averaged ``b`` examples advances the Langevin discretization with
    ``gamma_k * b / base_batch`` (and the injected noise, which reads
    ``ctx.gamma`` downstream, scales accordingly) — so one large-batch
    commit covers the same integrator time as ``b/base_batch`` base-size
    commits, at lower gradient variance.  A no-op scale of exactly 1.0 when
    ``b == base_batch``, keeping the fixed policy bit-compatible."""

    def update(ctx: StepContext) -> StepContext:
        mb = ctx.batch
        if not isinstance(mb, MaskedBatch):
            raise TypeError("batch_scaled_gamma needs a MaskedBatch upstream")
        scale = mb.size.astype(jnp.float32) / jnp.float32(base_batch)
        return ctx._replace(gamma=ctx.gamma * scale)

    return stateless(update)


def langevin_noise(sigma: float, schedule=None, noise_dtype=jnp.float32) -> SamplerTransform:
    """Draw the injected noise ``sqrt(2 sigma gamma_k) G_k`` into ``ctx.noise``.

    ``schedule`` optionally overrides the driver's ``gamma_k`` for the noise
    scale only (e.g. to anneal temperature independently of the step size).
    """

    def update(ctx: StepContext) -> StepContext:
        gamma = schedule(ctx.step) if schedule is not None else ctx.gamma
        scale = jnp.sqrt(2.0 * sigma * gamma)
        return ctx._replace(noise=noise_like(ctx.key_noise, ctx.params, scale,
                                             noise_dtype))

    return stateless(update)


def apply_sgld_update() -> SamplerTransform:
    """Commit ``X_{k+1} = X_k - gamma_k grad + noise`` (unfused reference path)."""

    def update(ctx: StepContext) -> StepContext:
        if ctx.grads is None:
            raise ValueError("apply_sgld_update needs a gradients() stage first")
        noise = ctx.noise if ctx.noise is not None else tree_zeros_like(ctx.params)
        return ctx._replace(params=sgld_apply(ctx.params, ctx.grads, ctx.gamma, noise))

    return stateless(update)


def fused_update(sigma: float, *, interpret: bool = True) -> SamplerTransform:
    """Commit through the Pallas fused kernel: noise is generated *in VMEM*
    (counter-based threefry seeded from this step's noise key) and the
    update is one read of (x, g) + one write of x' — replacing the
    ``langevin_noise() + apply_sgld_update()`` pair in the hot path."""

    def update(ctx: StepContext) -> StepContext:
        if ctx.grads is None:
            raise ValueError("fused_update needs a gradients() stage first")
        scale = jnp.sqrt(2.0 * sigma * ctx.gamma)
        params = fused_langevin_update(ctx.params, ctx.grads,
                                       _key_bits(ctx.key_noise), ctx.gamma,
                                       scale, interpret=interpret)
        return ctx._replace(params=params)

    return stateless(update)


def _oracle_grads(grad_fn: GradFn, params: PyTree, batch: Any,
                  has_aux: bool):
    """Evaluate ``grad_fn`` at ``params`` under either batch contract:
    a plain batch calls the oracle once; a :class:`MaskedBatch` vmaps the
    *per-example* oracle over the padded bucket axis and masked-mean
    reduces, exactly as :func:`masked_gradients` does.  Returns
    ``(grads, aux)`` (aux ``None`` without ``has_aux``)."""
    if isinstance(batch, MaskedBatch):
        out = jax.vmap(lambda e: grad_fn(params, e))(batch.data)
        per_grads, per_aux = out if has_aux else (out, None)
        grads = masked_mean(per_grads, batch.size)
        aux = masked_mean(per_aux, batch.size) if has_aux else None
        return grads, aux
    out = grad_fn(params, batch)
    return out if has_aux else (out, None)


class SVRGState(NamedTuple):
    """Carry of :func:`svrg_gradients`: the control-variate anchor.

    ``anchor`` is the snapshot :math:`\\tilde X` the correction is centered
    on (same pytree structure as the params) and ``anchor_grad`` the full
    gradient :math:`\\mu = \\nabla U(\\tilde X)` evaluated at it.  Both live
    in the sampler's scanned carry, so an anchor refresh is a ``lax.cond``
    inside the jitted chunk — epochs never retrace.
    """

    anchor: PyTree       # pytree like params
    anchor_grad: PyTree  # pytree like params


def svrg_gradients(grad_fn: GradFn, full_grad_fn: Callable[[PyTree], PyTree],
                   *, anchor_every: int, has_aux: bool = False
                   ) -> SamplerTransform:
    """SVRG-Langevin gradient oracle: minibatch gradient with a
    control-variate correction against a periodically refreshed full-data
    anchor (Dubey et al.; stale-gradient variance analysis in Chen et al.).

    The committed gradient is

    ``g_k = grad_fn(x_hat_k, B_k) - grad_fn(anchor, B_k) + full_grad_fn(anchor)``

    — unbiased for the full gradient at the read point ``x_hat_k``, with the
    minibatch variance shrinking as the iterate approaches the anchor.  The
    anchor ``(params, full gradient)`` pair is transform state, i.e. part of
    the scanned carry: every ``anchor_every`` commits a ``lax.cond`` branch
    re-anchors at the *current* iterate and pays one full-gradient
    evaluation, so refreshes happen inside the jitted scan and never
    retrace, regardless of how the driver chunks the step loop.

    ``grad_fn`` follows the surrounding batch contract: called directly on a
    plain batch, vmapped per example and masked-mean reduced on a
    :class:`MaskedBatch` (the heterogeneous bucket-padded executor path).
    ``full_grad_fn(params)`` must close over the full dataset and return a
    gradient pytree.  ``aux`` (under ``has_aux``) comes from the read-point
    minibatch term only.
    """
    if anchor_every < 1:
        raise ValueError(f"anchor_every must be >= 1, got {anchor_every}")

    def init(params):
        # the zero anchor_grad is never read: step 0 satisfies
        # step % anchor_every == 0, so the first commit re-anchors first.
        # the anchor is a fresh copy — aliasing the live params buffer
        # would make the engines' donated carry donate it twice.
        return SVRGState(anchor=jax.tree_util.tree_map(jnp.array, params),
                         anchor_grad=tree_zeros_like(params))

    def update(ctx: StepContext, state: SVRGState):
        def refresh(_):
            return SVRGState(anchor=ctx.params,
                             anchor_grad=full_grad_fn(ctx.params))

        state = jax.lax.cond(ctx.step % anchor_every == 0, refresh,
                             lambda s: s, state)
        grads, aux = _oracle_grads(grad_fn, ctx.x_hat, ctx.batch, has_aux)
        anchor_grads, _ = _oracle_grads(grad_fn, state.anchor, ctx.batch,
                                        has_aux)
        corrected = jax.tree_util.tree_map(
            lambda g, ga, mu: g - ga + mu.astype(g.dtype),
            grads, anchor_grads, state.anchor_grad)
        return ctx._replace(grads=corrected, aux=aux), state

    return SamplerTransform(init, update)


def stale_correction(strength: float = 1.0,
                     gamma_scale: float = 0.0) -> SamplerTransform:
    """Stale-gradient compensation for delayed reads (Chen et al.,
    *Stochastic Gradient MCMC with Stale Gradients*).

    Chen et al. show the bias and MSE of stale-gradient SG-MCMC grow with
    the staleness ``tau_k`` while the estimation variance does not, and that
    staleness-aware step-size selection recovers the fresh-gradient
    convergence rate.  This transform applies both halves, reading the
    *endogenous* staleness the executor derives from its
    :class:`~repro.cluster.schedule.WorkerSchedule`
    (``version - read_version``, surfaced as ``ctx.delay``):

    - **gradient term** — a first-order Taylor compensation of the stale
      gradient toward the fresh read point, with the Hessian approximated
      by the diagonal empirical Fisher (outer product of the gradient with
      itself): ``g <- g + strength * g * g * (X_k - X_hat_k)``;
    - **step-size term** — ``gamma <- gamma / (1 + gamma_scale * tau_k)``,
      the staleness-aware schedule shrink (``gamma_scale=0`` disables it).

    Both terms are selected per commit on ``tau_k > 0``, so a fresh read
    (``tau_k = 0``) commits **bitwise-identically** to the uncorrected
    chain (pinned in ``tests/test_zoo.py``).  Compose it directly after the
    gradient stage; it is contract-agnostic (plain or masked batches) since
    it only rewrites ``ctx.grads`` / ``ctx.gamma``.
    """

    def update(ctx: StepContext) -> StepContext:
        if ctx.grads is None:
            raise ValueError("stale_correction needs a gradients() stage "
                             "first")
        is_stale = ctx.delay > 0
        corrected = jax.tree_util.tree_map(
            lambda g, x, xh: jnp.where(
                is_stale,
                g + jnp.asarray(strength, g.dtype) * g * g
                * (x - xh).astype(g.dtype),
                g),
            ctx.grads, ctx.params, ctx.x_hat)
        gamma = ctx.gamma / (1.0 + jnp.asarray(gamma_scale, jnp.float32)
                             * jnp.where(is_stale,
                                         ctx.delay.astype(jnp.float32), 0.0))
        return ctx._replace(grads=corrected, gamma=gamma)

    return stateless(update)


def sghmc_update(sigma: float, *, friction: float = 1.0,
                 precond: Any = None,
                 noise_dtype=jnp.float32) -> SamplerTransform:
    """Commit one SGHMC step: momentum buffer + friction + injected noise
    (the non-log-concave workhorse motivated by Zou, Xu & Gu's faster
    SGLD-family rates; momentum state rides the sampler carry and
    checkpoint-round-trips with it).

    The underdamped Langevin SDE ``dX = V dt``, ``dV = -grad U dt
    - a V dt + sqrt(2 a sigma) dW`` discretized Euler-style at step size
    ``gamma_k`` (Chen, Fox & Guestrin 2014):

    ``V_{k+1} = (1 - gamma_k a) V_k - gamma_k P grad + sqrt(2 a sigma
    gamma_k) sqrt(P) G_k``;  ``X_{k+1} = X_k + gamma_k V_{k+1}``

    where ``a = friction`` and ``P = precond`` is an optional diagonal
    (inverse-mass) preconditioner — a scalar or a pytree shaped like the
    params (the practical variant that drops the ``Gamma`` correction
    term).  Replaces the ``langevin_noise() + apply_sgld_update()`` pair;
    the gradient is whatever the upstream stages left in ``ctx.grads``, so
    it composes with :func:`delay_read`, :func:`svrg_gradients`, and
    :func:`stale_correction` unchanged.
    """
    if friction <= 0.0:
        raise ValueError(f"friction must be > 0, got {friction}")

    def init(params):
        return tree_zeros_like(params)  # momentum buffer V_0 = 0

    def precond_tree(params):
        """Normalize ``precond`` to one diagonal factor per leaf.  A None
        is the identity, a scalar broadcasts to every leaf, and a
        params-shaped pytree is taken leafwise (scalars are detected by
        value, not treedef — a bare float has the same single-leaf treedef
        as single-array params)."""
        if precond is None:
            return jax.tree_util.tree_map(
                lambda p: jnp.asarray(1.0, p.dtype), params)
        if (not isinstance(precond, (list, tuple, dict))
                and jnp.ndim(precond) == 0):
            return jax.tree_util.tree_map(
                lambda p: jnp.asarray(precond, p.dtype), params)
        return jax.tree_util.tree_map(
            lambda p, f: jnp.asarray(f, p.dtype), params, precond)

    def update(ctx: StepContext, momentum):
        if ctx.grads is None:
            raise ValueError("sghmc_update needs a gradients() stage first")
        scale = jnp.sqrt(2.0 * friction * sigma * ctx.gamma)
        noise = noise_like(ctx.key_noise, ctx.params, scale, noise_dtype)

        def step_v(v, g, n, p):
            decay = (1.0 - ctx.gamma * friction).astype(v.dtype)
            return (decay * v
                    - ctx.gamma.astype(v.dtype) * p.astype(v.dtype)
                    * g.astype(v.dtype)
                    + jnp.sqrt(p).astype(v.dtype) * n.astype(v.dtype))

        momentum = jax.tree_util.tree_map(step_v, momentum, ctx.grads,
                                          noise, precond_tree(ctx.params))
        params = jax.tree_util.tree_map(
            lambda x, v: (x + ctx.gamma.astype(x.dtype)
                          * v.astype(x.dtype)).astype(x.dtype),
            ctx.params, momentum)
        return ctx._replace(params=params, noise=noise), momentum

    return SamplerTransform(init, update)


def pipeline_overlap() -> SamplerTransform:
    """Swap this step's gradient for the previous one (tau=1 on the gradient
    sequence).  The fresh gradient's all-reduce has no consumer this step,
    so XLA overlaps it with the next step's compute."""

    def init(params):
        return tree_zeros_like(params)

    def update(ctx: StepContext, pending):
        if ctx.grads is None:
            raise ValueError("pipeline_overlap needs a gradients() stage first")
        return ctx._replace(grads=pending), ctx.grads

    return SamplerTransform(init, update)


def delay_read(policy: DelayPolicy) -> SamplerTransform:
    """Maintain the iterate ring buffer and set the stale read point.

    The last commit is pushed at the *start* of the step (value-identical to
    pushing at the end of the previous step, and it keeps the ring state
    local to this transform instead of special-cased in the driver state).
    """

    def init(params):
        return delay_lib.init_ring(params, policy.tau)

    def update(ctx: StepContext, ring):
        ring = delay_lib.push(ring, ctx.params)
        return ctx._replace(x_hat=policy.read(ctx, ring)), ring

    return SamplerTransform(init, update)

"""The five sampler-transform primitives behind the paper's read models.

Raw leafwise math (``noise_like`` / ``sgld_apply``) lives here too — it is
the single source of truth shared by the transforms, the legacy
``SGLDSampler`` shim, and the launch-stack step builders, which is what
makes the new presets bit-compatible with the old sampler.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import delay as delay_lib
from repro.kernels.ops import fused_langevin_update
from repro.samplers.transform import SamplerTransform, StepContext, stateless
from repro.utils import tree_keys, tree_zeros_like

if TYPE_CHECKING:  # annotation-only; a runtime import would cycle via core
    from repro.samplers.policies import DelayPolicy

PyTree = Any
GradFn = Callable[..., PyTree]  # grad_fn(params, batch) -> grads | (grads, aux)


class MaskedBatch(NamedTuple):
    """A bucket-padded minibatch view: ``data`` leaves carry a leading
    bucket axis of ``B >= size`` examples, of which only the first ``size``
    are real.  The executor pads every commit's window up a shape-bucket
    ladder so a heterogeneous batch schedule compiles one trace per rung —
    the same discipline :class:`~repro.cluster.serve.ServeEngine` applies to
    query batches — and :func:`masked_gradients` averages over exactly the
    real examples, so padding rows never touch the math."""

    data: Any        # pytree; leaves (B, ...) bucket-padded examples
    size: jax.Array  # () int32 count of real examples (<= B)


def batch_mask(batch: MaskedBatch) -> jax.Array:
    """(B,) float32 indicator of the real examples in a padded view."""
    b = jax.tree_util.tree_leaves(batch.data)[0].shape[0]
    return (jnp.arange(b) < batch.size).astype(jnp.float32)


def masked_mean(values: PyTree, size: jax.Array) -> PyTree:
    """Mean of the first ``size`` rows of every ``(B, ...)`` leaf — the
    single reduction behind the masked gradient oracle (bitwise equal to
    ``jnp.mean`` when ``size == B``, since the mask multiplies by 1.0)."""

    def reduce(v):
        mask = (jnp.arange(v.shape[0]) < size).astype(v.dtype)
        mask = mask.reshape((-1,) + (1,) * (v.ndim - 1))
        return jnp.sum(v * mask, axis=0) / size.astype(v.dtype)

    return jax.tree_util.tree_map(reduce, values)


# ---------------------------------------------------------------------------
# raw leafwise math (shared with the legacy shim and launch/steps.py)
# ---------------------------------------------------------------------------
def noise_like(key: jax.Array, params: PyTree, scale: jnp.ndarray, dtype) -> PyTree:
    """sqrt(2 sigma gamma) * G_k, one independent key per leaf, shard-local."""
    keytree = tree_keys(key, params)
    return jax.tree_util.tree_map(
        lambda k, p: (scale * jax.random.normal(k, jnp.shape(p), dtype)).astype(p.dtype),
        keytree,
        params,
    )


def sgld_apply(params: PyTree, grads: PyTree, gamma: jnp.ndarray, noise: PyTree) -> PyTree:
    """x - gamma*g + noise, leafwise (the fused Pallas path is ``fused_update``)."""
    return jax.tree_util.tree_map(
        lambda p, g, n: (p - gamma.astype(p.dtype) * g.astype(p.dtype) + n).astype(p.dtype),
        params,
        grads,
        noise,
    )


def _key_bits(key: jax.Array) -> jax.Array:
    """(2,) uint32 view of a PRNG key (raw or typed) for the Pallas RNG."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.astype(jnp.uint32)


# ---------------------------------------------------------------------------
# transform primitives
# ---------------------------------------------------------------------------
def gradients(grad_fn: GradFn, has_aux: bool = False) -> SamplerTransform:
    """Evaluate the gradient oracle at the (possibly stale) read point."""

    def update(ctx: StepContext) -> StepContext:
        out = grad_fn(ctx.x_hat, ctx.batch)
        grads, aux = out if has_aux else (out, None)
        return ctx._replace(grads=grads, aux=aux)

    return stateless(update)


def masked_gradients(grad_fn: GradFn, has_aux: bool = False) -> SamplerTransform:
    """Evaluate a *per-example* gradient oracle over a :class:`MaskedBatch`.

    ``grad_fn(params, example)`` is vmapped over the padded bucket axis and
    reduced with :func:`masked_mean`, so the committed gradient averages
    exactly the ``size`` real examples regardless of how far the bucket
    ladder padded the view — mixed batch sizes change the mask contents,
    never the trace.  With ``has_aux`` the per-example aux is masked-mean
    reduced the same way.
    """

    def update(ctx: StepContext) -> StepContext:
        mb = ctx.batch
        if not isinstance(mb, MaskedBatch):
            raise TypeError("masked_gradients needs a MaskedBatch (did you "
                            "mean gradients(), or forget batch_policy=?)")
        out = jax.vmap(lambda e: grad_fn(ctx.x_hat, e))(mb.data)
        per_grads, per_aux = out if has_aux else (out, None)
        grads = masked_mean(per_grads, mb.size)
        aux = masked_mean(per_aux, mb.size) if has_aux else None
        return ctx._replace(grads=grads, aux=aux)

    return stateless(update)


def batch_scaled_gamma(base_batch: int) -> SamplerTransform:
    """Linear step-size scaling for heterogeneous batches: a commit that
    averaged ``b`` examples advances the Langevin discretization with
    ``gamma_k * b / base_batch`` (and the injected noise, which reads
    ``ctx.gamma`` downstream, scales accordingly) — so one large-batch
    commit covers the same integrator time as ``b/base_batch`` base-size
    commits, at lower gradient variance.  A no-op scale of exactly 1.0 when
    ``b == base_batch``, keeping the fixed policy bit-compatible."""

    def update(ctx: StepContext) -> StepContext:
        mb = ctx.batch
        if not isinstance(mb, MaskedBatch):
            raise TypeError("batch_scaled_gamma needs a MaskedBatch upstream")
        scale = mb.size.astype(jnp.float32) / jnp.float32(base_batch)
        return ctx._replace(gamma=ctx.gamma * scale)

    return stateless(update)


def langevin_noise(sigma: float, schedule=None, noise_dtype=jnp.float32) -> SamplerTransform:
    """Draw the injected noise ``sqrt(2 sigma gamma_k) G_k`` into ``ctx.noise``.

    ``schedule`` optionally overrides the driver's ``gamma_k`` for the noise
    scale only (e.g. to anneal temperature independently of the step size).
    """

    def update(ctx: StepContext) -> StepContext:
        gamma = schedule(ctx.step) if schedule is not None else ctx.gamma
        scale = jnp.sqrt(2.0 * sigma * gamma)
        return ctx._replace(noise=noise_like(ctx.key_noise, ctx.params, scale,
                                             noise_dtype))

    return stateless(update)


def apply_sgld_update() -> SamplerTransform:
    """Commit ``X_{k+1} = X_k - gamma_k grad + noise`` (unfused reference path)."""

    def update(ctx: StepContext) -> StepContext:
        if ctx.grads is None:
            raise ValueError("apply_sgld_update needs a gradients() stage first")
        noise = ctx.noise if ctx.noise is not None else tree_zeros_like(ctx.params)
        return ctx._replace(params=sgld_apply(ctx.params, ctx.grads, ctx.gamma, noise))

    return stateless(update)


def fused_update(sigma: float, *, interpret: bool = True) -> SamplerTransform:
    """Commit through the Pallas fused kernel: noise is generated *in VMEM*
    (counter-based threefry seeded from this step's noise key) and the
    update is one read of (x, g) + one write of x' — replacing the
    ``langevin_noise() + apply_sgld_update()`` pair in the hot path."""

    def update(ctx: StepContext) -> StepContext:
        if ctx.grads is None:
            raise ValueError("fused_update needs a gradients() stage first")
        scale = jnp.sqrt(2.0 * sigma * ctx.gamma)
        params = fused_langevin_update(ctx.params, ctx.grads,
                                       _key_bits(ctx.key_noise), ctx.gamma,
                                       scale, interpret=interpret)
        return ctx._replace(params=params)

    return stateless(update)


def pipeline_overlap() -> SamplerTransform:
    """Swap this step's gradient for the previous one (tau=1 on the gradient
    sequence).  The fresh gradient's all-reduce has no consumer this step,
    so XLA overlaps it with the next step's compute."""

    def init(params):
        return tree_zeros_like(params)

    def update(ctx: StepContext, pending):
        if ctx.grads is None:
            raise ValueError("pipeline_overlap needs a gradients() stage first")
        return ctx._replace(grads=pending), ctx.grads

    return SamplerTransform(init, update)


def delay_read(policy: DelayPolicy) -> SamplerTransform:
    """Maintain the iterate ring buffer and set the stale read point.

    The last commit is pushed at the *start* of the step (value-identical to
    pushing at the end of the previous step, and it keeps the ring state
    local to this transform instead of special-cased in the driver state).
    """

    def init(params):
        return delay_lib.init_ring(params, policy.tau)

    def update(ctx: StepContext, ring):
        ring = delay_lib.push(ring, ctx.params)
        return ctx._replace(x_hat=policy.read(ctx, ring)), ring

    return SamplerTransform(init, update)

"""The composable sampler-transform protocol: optax-style ``(init, update)``.

A :class:`SamplerTransform` is a pure pair of functions threaded by the
:class:`~repro.samplers.base.Sampler` driver:

- ``init(params) -> state`` builds the transform's own state pytree
  (a ring buffer of iterates, a pending gradient, or ``()``).
- ``update(ctx, state) -> (ctx, state)`` reads and rewrites fields of the
  per-step :class:`StepContext` — the read point ``x_hat``, the gradient,
  the Langevin noise, or the committed ``params`` — and advances its state.

``chain(*transforms)`` composes transforms left-to-right into one
transform whose state is the tuple of member states, exactly like
``optax.chain``.  The paper's four read models are one-line chains over
five primitives (see :mod:`repro.samplers.presets`).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax

PyTree = Any


class StepContext(NamedTuple):
    """Everything one SGLD commit can read or rewrite.

    Built fresh by the driver each step; transforms communicate through it
    instead of through positional plumbing (the old ``delay_k`` argument).
    """

    params: PyTree               # current iterate X_k (rewritten by apply stages)
    x_hat: PyTree                # gradient read point (rewritten by delay_read)
    grads: Optional[PyTree]      # set by the gradients stage
    noise: Optional[PyTree]      # set by langevin_noise
    aux: Any                     # metrics surfaced by the gradients stage
    gamma: jax.Array             # step size gamma_k (schedule-evaluated)
    key_noise: jax.Array         # per-step PRNG key for Langevin noise
    key_delay: jax.Array         # per-step PRNG key for coordinate delays
    step: jax.Array              # int32 commit counter k
    delay: jax.Array             # int32 realized staleness tau_k for this commit
    batch: Any                   # opaque payload handed to the gradient oracle


InitFn = Callable[[PyTree], Any]
UpdateFn = Callable[[StepContext, Any], tuple[StepContext, Any]]


class SamplerTransform(NamedTuple):
    """An optax-style (init, update) pair over :class:`StepContext`."""

    init: InitFn
    update: UpdateFn


def stateless(update_ctx: Callable[[StepContext], StepContext]) -> SamplerTransform:
    """Lift a pure ``ctx -> ctx`` function into a stateless transform."""

    def init(params):
        del params
        return ()

    def update(ctx, state):
        return update_ctx(ctx), state

    return SamplerTransform(init, update)


def chain(*transforms: SamplerTransform) -> SamplerTransform:
    """Compose transforms left-to-right; state is the tuple of member states."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(ctx, state):
        new_state = []
        for t, s in zip(transforms, state):
            ctx, s = t.update(ctx, s)
            new_state.append(s)
        return ctx, tuple(new_state)

    return SamplerTransform(init, update)

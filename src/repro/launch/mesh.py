"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  The dry-run entry point (dryrun.py) force-creates 512
host-platform placeholder devices *before* importing anything else.

Target hardware: TPU v5e, 16x16 = 256 chips per pod; 2 pods = 512 chips.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under dryrun.py "
            f"(it sets xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CI-scale sharding tests (8 forced host devices)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:n])


def batch_axes_for(mesh, global_batch: int):
    """Which mesh axes shard the batch: all 'data-like' axes whose product
    divides the batch (long_500k's B=1 falls back to replication)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and global_batch % size == 0:
        return axes
    return ()


def fsdp_axes_for(mesh):
    """Axes used for the 2-D (fsdp_tp) parameter sharding."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)

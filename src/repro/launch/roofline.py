"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per DESIGN/EXPERIMENTS:

    compute    = HLO_FLOPs_per_device                / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device                / HBM_bw_per_chip
    collective = collective_bytes_per_device         / ICI_bw_per_chip

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition after
SPMD).  Collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO
text and sum the result-buffer sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (per-device view).

Hardware constants (TPU v5e target): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (per-device collective bytes / this)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,1024]{1,0} all-gather(...)   or   (f32[8], f32[8]) all-reduce
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _buffer_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _line_collective(s: str):
    """(kind, bytes) if this HLO line is a collective op, else None."""
    for kind in _COLLECTIVES:
        # match ` = <shape> kind(` — -done lines don't match so async ops
        # are counted once (on -start)
        m = re.search(r"=\s+((?:\([^)]*\))|(?:\S+))\s+" + kind
                      + r"(?:-start)?\(", s)
        if m:
            return kind, _buffer_bytes(m.group(1))
    return None


_COMP_HEADER = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),.*?(?:condition=%?([\w.\-]+)).*?(?:body=%?([\w.\-]+))"
    r"|while\(.*?\),.*?(?:body=%?([\w.\-]+)).*?(?:condition=%?([\w.\-]+))")
_CALL_RE = re.compile(r"\scall\(.*?to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _parse_computations(hlo_text: str) -> tuple[dict[str, list[str]], str]:
    """name -> body lines; also returns the ENTRY computation name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HEADER.match(line)
        if m and "{" in line:
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_body: list[str]) -> int:
    """Trip count of a jax scan's while: the bound constant in its cond."""
    best = 1
    for line in cond_body:
        if "compare" in line or "constant" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Loop-aware per-device collective bytes from post-SPMD HLO.

    XLA's cost analysis counts while bodies once; jax lowers every lax.scan
    to a while whose trip count is a compile-time constant in the condition
    computation — we recurse through while/call edges multiplying by it.
    """
    comps, entry = _parse_computations(hlo_text)
    memo: dict[str, dict[str, float]] = {}

    def visit(name: str) -> dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {k: 0.0 for k in _COLLECTIVES}  # cycle guard
        out = {k: 0.0 for k in _COLLECTIVES}
        for line in comps.get(name, ()):
            col = _line_collective(line)
            if col:
                out[col[0]] += col[1]
            wm = _WHILE_RE.search(line)
            if wm:
                cond = wm.group(1) or wm.group(4)
                body = wm.group(2) or wm.group(3)
                trip = _trip_count(comps.get(cond, []))
                sub = visit(body)
                for k in out:
                    out[k] += trip * sub[k]
                continue
            cm = _CALL_RE.search(line)
            if cm:
                sub = visit(cm.group(1))
                for k in out:
                    out[k] += sub[k]
        memo[name] = out
        return out

    if entry is None:
        # fallback: flat scan, no loop awareness
        out = {k: 0.0 for k in _COLLECTIVES}
        for line in hlo_text.splitlines():
            col = _line_collective(line.strip())
            if col:
                out[col[0]] += col[1]
        return {k: int(v) for k, v in out.items()}
    return {k: int(v) for k, v in visit(entry).items()}


@dataclass
class Roofline:
    name: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_global: float
    hlo_flops_global: float
    useful_ratio: float

    def summary(self) -> str:
        return (f"{self.name}: compute {self.t_compute*1e3:.3f}ms, "
                f"memory {self.t_memory*1e3:.3f}ms, "
                f"collective {self.t_collective*1e3:.3f}ms "
                f"-> {self.dominant}-bound; useful={self.useful_ratio:.2f}")


def analyze(name: str, compiled, num_devices: int, model_flops_global: float,
            hlo_text: str | None = None, jaxpr_cost=None) -> Roofline:
    """jaxpr_cost: a launch.jaxpr_cost.Cost (per-device, loop-aware).  When
    given it supersedes XLA's cost_analysis, which undercounts loop bodies
    (see jaxpr_cost module docstring)."""
    if jaxpr_cost is not None:
        flops = float(jaxpr_cost.flops)
        byts = float(jaxpr_cost.bytes)
    else:
        cost = compiled.cost_analysis()
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    coll_total = float(sum(coll.values()))
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll_total / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                   key=lambda kv: kv[1])[0]
    hlo_global = flops * num_devices
    return Roofline(
        name=name,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll_total,
        collective_breakdown=coll,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dominant,
        model_flops_global=model_flops_global,
        hlo_flops_global=hlo_global,
        useful_ratio=(model_flops_global / hlo_global) if hlo_global else 0.0,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only (N = active
    params, D = global tokens processed by the step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens


def memory_report(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    rep = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            rep[k] = int(v)
    return rep

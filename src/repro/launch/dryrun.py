import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

The two lines above MUST stay first — jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mode pipeline]

Writes one JSON per combo under experiments/dryrun/ with memory analysis,
cost analysis, collective bytes and roofline terms (read by
benchmarks/roofline and EXPERIMENTS.md).
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_arch, get_shape, SHAPES  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.jaxpr_cost import step_cost  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    adapt_config,
    batch_specs,
    build_model,
    cache_spec_tree,
    make_decode_step,
    make_prefill_step,
    make_sgld_train_step,
    param_structs,
)
from repro.utils import use_mesh  # noqa: E402

OUTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "dryrun")


def lower_combo(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                mode: str = "sync", opts: tuple = (), micro: int = 0,
                verbose: bool = True):
    """Lower+compile one combination; returns result dict.

    opts/micro are the §Perf hillclimb switches; mode "sync" + empty opts is
    the paper-faithful baseline."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_devices = mesh.size
    shape = get_shape(shape_name)
    if micro:
        from dataclasses import replace as _replace
        shape = _replace(shape, num_microbatches=micro)
    cfg0 = get_arch(arch_id)
    model, cfg, baxes, faxes = build_model(cfg0, shape, mesh, opts)

    pstructs, pshard = param_structs(cfg, mesh, faxes)
    bstructs = batch_specs(cfg, shape, mesh, baxes)
    rep = NamedSharding(mesh, P())
    t0 = time.time()

    with use_mesh(mesh):
        if shape.kind == "train":
            step = make_sgld_train_step(model, shape, mode=mode)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep)
            if mode == "pipeline":
                args = (pstructs, pstructs, bstructs, key)
                lowered = jax.jit(
                    step, out_shardings=(pshard, pshard, rep)).lower(*args)
            else:
                args = (pstructs, bstructs, key)
                lowered = jax.jit(
                    step, out_shardings=(pshard, rep)).lower(*args)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            args = (pstructs, bstructs)
            lowered = jax.jit(step).lower(*args)
        else:  # decode
            step = make_decode_step(model)
            cstructs, cshard = cache_spec_tree(model, cfg, shape, mesh, baxes)
            bstructs_d = batch_specs(cfg, shape, mesh, baxes, kind="decode")
            args = (pstructs, cstructs, bstructs_d)
            lowered = jax.jit(step, out_shardings=(None, cshard)).lower(*args)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        acost = step_cost(step, *args, num_devices=num_devices)

    mem = rl.memory_report(compiled)
    mf = rl.model_flops(cfg, shape)
    hlo = compiled.as_text()
    roof = rl.analyze(f"{arch_id}/{shape_name}", compiled, num_devices, mf,
                      hlo_text=hlo, jaxpr_cost=acost)

    tag = mode + ("" if not opts else "+" + "+".join(opts)) \
        + (f"+micro{micro}" if micro else "")
    from repro.configs.base import ALIASES
    canon = ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "p")
    result = {
        "arch": canon,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": tag,
        "kind": shape.kind,
        "num_devices": num_devices,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "roofline": {
            "flops_per_device": roof.flops_per_device,
            "bytes_per_device": roof.bytes_per_device,
            "collective_bytes_per_device": roof.collective_bytes_per_device,
            "collective_breakdown": roof.collective_breakdown,
            "t_compute": roof.t_compute,
            "t_memory": roof.t_memory,
            "t_collective": roof.t_collective,
            "dominant": roof.dominant,
            "model_flops_global": roof.model_flops_global,
            "hlo_flops_global": roof.hlo_flops_global,
            "useful_ratio": roof.useful_ratio,
        },
    }
    if verbose:
        print(json.dumps({k: result[k] for k in
                          ("arch", "shape", "mesh", "mode", "compile_s")}),
              flush=True)
        print("  memory:", {k: f"{v/2**30:.2f}GiB" for k, v in mem.items()
                            if "size" in k}, flush=True)
        print(" ", roof.summary(), flush=True)
    return result


def save_result(result: dict, outdir: str = OUTDIR, suffix: str = ""):
    os.makedirs(outdir, exist_ok=True)
    fname = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
             f"__{result['mode']}{suffix}.json")
    with open(os.path.join(outdir, fname), "w") as f:
        json.dump(result, f, indent=2)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (see configs)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="sync", choices=["sync", "pipeline"])
    ap.add_argument("--opts", default="", help="comma list: attn_shard,window_slice")
    ap.add_argument("--micro", type=int, default=0,
                    help="override num_microbatches (train shapes)")
    ap.add_argument("--outdir", default=OUTDIR)
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            res = lower_combo(arch, shape, multi_pod=args.multi_pod,
                              mode=args.mode,
                              opts=tuple(o for o in args.opts.split(",") if o),
                              micro=args.micro)
            save_result(res, args.outdir)
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print(f"FAILED {len(failures)}/{len(combos)}:", failures)
        sys.exit(1)
    print(f"OK: {len(combos)} combinations lowered+compiled")


if __name__ == "__main__":
    main()

"""Loop-aware analytic cost model (FLOPs + HBM-traffic) from the jaxpr.

WHY: ``compiled.cost_analysis()`` counts each while-loop body ONCE — verified
in this container (a 10-iteration scan of a 512^3 matmul reports the flops of
a single matmul).  Every layer stack / microbatch / flash-attention chunk in
this framework is a static-length ``lax.scan``, so XLA's numbers undercount
by orders of magnitude.  This walker traverses the (grad-transformed) jaxpr
and multiplies by scan lengths — FLOPs are *exact* for dot/conv ops.

Traffic model (``bytes``): a perfectly-fused executor —
  - dot_general / conv: operands + result stream HBM once,
  - gather/scatter/dynamic-slice/top_k/sort/cumsum/RNG: in + out,
  - scan: xs/ys once in total + carry read+write per iteration,
  - elementwise chains: assumed fused into neighbors (not counted).
This is a *lower bound* on real traffic; EXPERIMENTS.md §Roofline discusses
the deviation.  Collective bytes come from the post-SPMD HLO text (see
roofline.collective_bytes_loop_aware) since GSPMD inserts them after jaxpr.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

TRAFFIC_PRIMS = {
    "cumsum", "sort", "top_k", "argsort",
    "threefry2x32", "random_bits", "random_seed", "random_wrap",
    "reduce_sum", "reduce_max", "reduce_min",
}


def _size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    matmul_flops: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.matmul_flops + o.matmul_flops)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k, self.matmul_flops * k)


def _dot_cost(eqn) -> Cost:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    k = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    b = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    m = int(np.prod([d for i, d in enumerate(lhs.shape) if i not in lc + lb])) or 1
    n = int(np.prod([d for i, d in enumerate(rhs.shape) if i not in rc + rb])) or 1
    fl = 2.0 * b * m * n * k
    by = _size_bytes(lhs) + _size_bytes(rhs) + _size_bytes(eqn.outvars[0].aval)
    return Cost(flops=fl, bytes=by, matmul_flops=fl)


def _conv_cost(eqn) -> Cost:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    kernel_elems = int(np.prod(rhs.shape))
    fl = 2.0 * int(np.prod(out.shape)) * kernel_elems / max(rhs.shape[-1], 1)
    by = sum(_size_bytes(v.aval) for v in eqn.invars) + _size_bytes(out)
    return Cost(flops=fl, bytes=by)


def _sub_jaxprs(eqn):
    """(closed_jaxpr, multiplier) pairs referenced by this eqn."""
    p = eqn.params
    name = eqn.primitive.name
    if name == "scan":
        return [(p["jaxpr"], int(p["length"]))]
    if name == "while":
        return [(p["body_jaxpr"], 1), (p["cond_jaxpr"], 1)]
    if name == "cond":
        return [(bj, 1) for bj in p["branches"]]
    out = []
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p and p[key] is not None:
            out.append((p[key], 1))
    return out


def jaxpr_cost(jaxpr, scale: float = 1.0) -> Cost:
    """jaxpr: ClosedJaxpr or Jaxpr.

    ``scale`` converts global (logical-shape) costs to per-device: ops
    outside shard_map are assumed evenly sharded (x 1/num_devices); inside a
    shard_map body shapes are already per-device (scale resets to 1).
    """
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    total = Cost()
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total = total + _dot_cost(eqn) * scale
        elif name == "conv_general_dilated":
            total = total + _conv_cost(eqn) * scale
        elif name == "dynamic_update_slice":
            # in-place (XLA aliases the buffer): traffic = read+write the slot
            total = total + Cost(bytes=2.0 * _size_bytes(eqn.invars[1].aval)) * scale
        elif name == "dynamic_slice":
            total = total + Cost(bytes=2.0 * _size_bytes(eqn.outvars[0].aval)) * scale
        elif name == "gather":
            by = (_size_bytes(eqn.outvars[0].aval)
                  + _size_bytes(eqn.invars[1].aval))
            total = total + Cost(bytes=2.0 * by) * scale
        elif name in ("scatter", "scatter-add", "scatter_add"):
            by = (2.0 * _size_bytes(eqn.invars[2].aval)
                  + _size_bytes(eqn.invars[1].aval))
            total = total + Cost(bytes=by) * scale
        elif name in TRAFFIC_PRIMS:
            by = (sum(_size_bytes(v.aval) for v in eqn.invars)
                  + sum(_size_bytes(v.aval) for v in eqn.outvars))
            total = total + Cost(bytes=by) * scale
        subs = _sub_jaxprs(eqn)
        if name == "scan":
            sub, length = subs[0]
            inner_cost = jaxpr_cost(sub, scale)
            n_carry = eqn.params["num_carry"]
            n_consts = eqn.params["num_consts"]
            # carries are buffer-aliased in place (body ops touching them are
            # already counted); xs/ys stream HBM once in total
            xs_bytes = sum(_size_bytes(v.aval)
                           for v in eqn.invars[n_consts + n_carry:])
            ys_bytes = sum(_size_bytes(v.aval) for v in eqn.outvars[n_carry:])
            total = total + inner_cost * length
            total = total + Cost(bytes=(xs_bytes + ys_bytes)) * scale
        elif name == "shard_map":
            for sub, mult in subs:
                total = total + jaxpr_cost(sub, 1.0) * mult
        else:
            for sub, mult in subs:
                total = total + jaxpr_cost(sub, scale) * mult
    return total


def step_cost(fn, *args, num_devices: int = 1) -> Cost:
    """Per-device cost of fn(*args) (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*args)
    scale = 1.0 / max(num_devices, 1)
    c = jaxpr_cost(closed, scale)
    io = sum(_size_bytes(v.aval) for v in closed.jaxpr.invars)
    io += sum(_size_bytes(v.aval) for v in closed.jaxpr.outvars)
    return c + Cost(bytes=float(io) * scale)

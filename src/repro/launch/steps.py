"""Step builders + sharding specs for the dry-run and the real launcher.

For each (arch, shape, mesh) this module constructs:
  - the jit-able step function (train / prefill / decode),
  - ShapeDtypeStruct input stand-ins with NamedShardings attached,
  - out_shardings trees,
so dryrun.py only has to ``.lower().compile()``.

SGLD modes exposed here:
  - ``sync``      paper-faithful Sync baseline (gradient all-reduce on the
                  critical path) — the §Perf *baseline*.
  - ``pipeline``  paper's tau=1 W-Con adapted to TPU: apply last step's
                  all-reduced gradient, overlap this step's all-reduce —
                  the beyond-paper optimized mode.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig, ShapeConfig
from repro.samplers.transforms import noise_like as langevin_noise
from repro.samplers.transforms import sgld_apply as apply_update
from repro.data import make_specs
from repro.launch.mesh import batch_axes_for, fsdp_axes_for
from repro.models.common import partition_tree
from repro.models.transformer import Model, init_params
from repro.train.loop import make_grad_fn

PyTree = Any

LONG_CONTEXT_WINDOW = 8192  # sliding window applied to attention archs @500k


def adapt_config(cfg: ArchConfig, shape: ShapeConfig,
                 opts: tuple = ()) -> ArchConfig:
    """Shape-dependent config tweaks (DESIGN.md §4) + §Perf opt switches.

    opts: subset of {"attn_shard", "window_slice"}."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",) \
            and cfg.sliding_window is None:
        cfg = replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    if "attn_shard" in opts:
        cfg = replace(cfg, opt_attn_head_shard=True)
    if "window_slice" in opts:
        cfg = replace(cfg, opt_window_slice=True)
    if "fsdp" in opts:
        assert cfg.num_experts == 0, "fsdp opt is for dense archs"
        cfg = replace(cfg, param_sharding="fsdp_full",
                      opt_attn_head_shard=False)
    if "unroll" in opts:
        cfg = replace(cfg, opt_unroll_layers=True)
    if "padvocab" in opts:
        # standard practice: pad vocab to a shardable multiple so the embed
        # table and the (B,S,V) logits shard over the model axis
        v = -(-cfg.vocab_size // 256) * 256
        cfg = replace(cfg, vocab_size=v)
    return cfg


def build_model(cfg: ArchConfig, shape: ShapeConfig, mesh, opts: tuple = ()):
    cfg = adapt_config(cfg, shape, opts)
    baxes = batch_axes_for(mesh, shape.global_batch) if mesh is not None else ()
    if cfg.param_sharding == "fsdp_full" and mesh is not None:
        allax = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        if shape.global_batch % mesh.size == 0:
            baxes = allax  # batch over every axis: zero TP collectives
    faxes = fsdp_axes_for(mesh) if mesh is not None else ("data",)
    model = Model(cfg, mesh=mesh, batch_axes=baxes or (), fsdp_axes=faxes)
    return model, cfg, baxes, faxes


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def sanitize_spec(spec: P, shape: tuple, mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (explicit jit
    in/out shardings require exact divisibility; e.g. 25 heads on a 16-way
    axis, or a 32001 vocab)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts = parts[: len(shape)]
    for i, p in enumerate(parts):
        if p is None:
            continue
        axes = p if isinstance(p, tuple) else (p,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if shape[i] % size != 0:
            parts[i] = None
    return P(*parts)


def sanitized_named(mesh, spec_tree, shape_tree):
    specs = jax.tree_util.tree_map(
        lambda sp, s: sanitize_spec(sp, s.shape, mesh), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))
    return named(mesh, specs)


def param_structs(cfg, mesh, fsdp_axes):
    """abstract params + NamedSharding tree (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    specs = partition_tree(shapes, cfg.param_sharding, fsdp_axes, cfg=cfg,
                           model_size=mesh.shape.get("model"))
    shardings = sanitized_named(mesh, specs, shapes)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return structs, shardings


def batch_specs(cfg, shape, mesh, batch_axes, kind=None):
    """input ShapeDtypeStructs with batch sharded over the data-like axes."""
    specs = make_specs(cfg, shape, kind)
    b = P(batch_axes) if batch_axes else P(None)

    def shard_of(_path_leaf_name, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*( (batch_axes if batch_axes else None),
                                        *([None] * (leaf.ndim - 1)))))

    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                    sharding=shard_of(k, v))
            for k, v in specs.items()}


def cache_spec_tree(model: Model, cfg, shape, mesh, batch_axes):
    """Decode-cache ShapeDtypeStructs with shardings."""
    shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 prefill_len=shape.seq_len - 1))
    bd = batch_axes if batch_axes else None

    def trunc(nd, *parts):
        parts = tuple(parts)[:nd]
        parts = parts + (None,) * (nd - len(parts))
        return P(*parts)

    def spec_for(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        nd = leaf.ndim
        stacked = isinstance(cfg.block_pattern, tuple) and len(cfg.block_pattern) == 1
        lead = (None,) if stacked else ()
        if "pos" in path:
            return trunc(nd)
        if "attn" in path:  # (L, B, S, KV, hd): shard head_dim (KV often < 16)
            return trunc(nd, *lead, bd, None, None, "model") if nd >= 4 else P()
        if "ssm_h" in path:  # (L, B, H, p, n)
            return trunc(nd, *lead, bd, "model", None, None)
        if "ssm_conv" in path:  # (L, B, K-1, di)
            return trunc(nd, *lead, bd, None, "model")
        if "mlstm_c" in path:  # (L?, B, H, dk, dv)
            return trunc(nd, *lead, bd, None, None, "model")
        if "mlstm_n" in path:
            return trunc(nd, *lead, bd, None, None)
        if "mlstm_m" in path:
            return trunc(nd, *lead, bd, None)
        if "slstm" in path:  # (B, d)
            return trunc(nd, bd, "model")
        return trunc(nd)

    specs = jax.tree_util.tree_map_with_path(spec_for, shapes)
    shardings = sanitized_named(mesh, specs, shapes)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return structs, shardings


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_sgld_train_step(model: Model, shape: ShapeConfig, mode: str = "sync",
                         gamma: float = 1e-5, sigma: float = 1e-6):
    """Full training step: microbatched grads + SGLD update.

    sync:     params' = params - gamma * g(params) + noise
    pipeline: params' = params - gamma * pending  + noise; pending' = g(params)
    """
    grad_fn = make_grad_fn(model, shape.num_microbatches)
    scale = (2.0 * sigma * gamma) ** 0.5

    if mode == "sync":
        def step(params, batch, key):
            grads, metrics = grad_fn(params, batch)
            noise = langevin_noise(key, params, jnp.float32(scale), jnp.float32)
            new_params = apply_update(params, grads, jnp.float32(gamma), noise)
            return new_params, metrics["loss"]
        return step

    if mode == "pipeline":
        def step(params, pending, batch, key):
            grads, metrics = grad_fn(params, batch)
            noise = langevin_noise(key, params, jnp.float32(scale), jnp.float32)
            new_params = apply_update(params, pending, jnp.float32(gamma), noise)
            return new_params, grads, metrics["loss"]
        return step

    raise ValueError(mode)


def make_prefill_step(model: Model):
    def step(params, batch):
        return model.prefill(params, batch)
    return step


def make_decode_step(model: Model):
    def step(params, cache, batch):
        return model.serve_step(params, cache, batch["tokens"], batch["cur_pos"])
    return step

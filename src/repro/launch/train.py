"""CLI launcher: train any assigned architecture with async-SGLD.

Real-hardware entry point (and CPU-reduced driver with --reduced):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 50 --mode pipeline --batch 8 --seq 128

Training runs through the unified scan-chunked Engine: one jitted dispatch
per --chunk steps, delays fed as device arrays (no per-delay retraces), and
--fused commits through the Pallas fused Langevin kernel.

On a TPU slice, omit --reduced: the production mesh is built, parameters are
initialized sharded (init under jit with out_shardings), and the train step
runs under the mesh with the shape's microbatching.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ShapeConfig, get_arch, get_reduced
from repro.core import WorkerModel, simulate_async
from repro.core.sgld import SGLDConfig
from repro.data import make_batch
from repro.models.transformer import Model, init_params
from repro.train.engine import Engine, checkpoint_hook, log_hook
from repro.train.loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale smoke variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "consistent", "inconsistent", "pipeline"])
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--workers", type=int, default=8,
                    help="virtual workers for the delay trace")
    ap.add_argument("--gamma", type=float, default=1e-3)
    ap.add_argument("--sigma", type=float, default=1e-5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=10,
                    help="steps per jitted scan chunk")
    ap.add_argument("--fused", action="store_true",
                    help="commit through the Pallas fused Langevin kernel")
    ap.add_argument("--save", default=None, help="checkpoint path")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    model = Model(cfg, mesh=None)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, mode={args.mode}"
          f"{' (fused)' if args.fused else ''}, chunk={args.chunk}")

    sgld_cfg = SGLDConfig(mode=args.mode, gamma=args.gamma, sigma=args.sigma,
                          tau=args.tau if args.mode in ("consistent",
                                                        "inconsistent") else 0)
    sampler, _ = make_train_step(model, sgld_cfg, fused=args.fused,
                                 interpret=jax.default_backend() != "tpu")
    key, init_key = jax.random.split(key)
    state = sampler.init(params, init_key)

    delays = None
    if args.mode in ("consistent", "inconsistent"):
        trace = simulate_async(WorkerModel(num_workers=args.workers,
                                           seed=args.seed), args.steps,
                               seed=args.seed)
        delays = np.minimum(trace.delays, args.tau)

    hooks = [log_hook(every=10)]
    if args.save:
        hooks.append(checkpoint_hook(args.save, every=max(args.chunk, 100)))
    engine = Engine(sampler, batch_fn=lambda k: make_batch(cfg, shape, k, "train"),
                    chunk_size=args.chunk, hooks=hooks)
    state, _ = engine.run(state, steps=args.steps, delays=delays, key=key)

    if args.save:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.save, state.params, step=args.steps)
        print("saved", args.save)


if __name__ == "__main__":
    main()

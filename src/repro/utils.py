"""Small shared utilities: pytree helpers, key handling, shape math."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

if hasattr(jax, "shard_map"):  # jax >= 0.6 spelling
    shard_map = jax.shard_map
    SHARD_MAP_CHECK_KW = {"check_vma": False}
else:  # jax 0.4.x spelling (and the check_vma kwarg was check_rep)
    from jax.experimental.shard_map import shard_map
    SHARD_MAP_CHECK_KW = {"check_rep": False}

PyTree = Any


def tree_keys(key: jax.Array, tree: PyTree) -> PyTree:
    """Split `key` into one independent key per leaf of `tree`."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def tree_normal_like(key: jax.Array, tree: PyTree, dtype=None) -> PyTree:
    """A pytree of iid standard normals shaped like `tree`."""
    keytree = tree_keys(key, tree)
    return jax.tree_util.tree_map(
        lambda k, x: jax.random.normal(k, jnp.shape(x), dtype or jnp.result_type(x)),
        keytree,
        tree,
    )


def tree_add_scaled(a: PyTree, b: PyTree, scale) -> PyTree:
    """a + scale * b, leafwise."""
    return jax.tree_util.tree_map(lambda x, y: x + scale * y, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(a: PyTree, scale) -> PyTree:
    return jax.tree_util.tree_map(lambda x: scale * x, a)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_broadcast_leading(a: PyTree, n: int) -> PyTree:
    """Replicate every leaf along a new materialized leading axis of size
    ``n`` (ring-buffer history slots, ensemble chain axes)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + jnp.shape(x)).copy(), a)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    parts = jax.tree_util.tree_map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree_util.tree_reduce(jnp.add, parts, jnp.float32(0.0))


def tree_sq_norm(a: PyTree) -> jax.Array:
    return tree_dot(a, a)


def tree_size(a: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(a))


def tree_ravel(a: PyTree) -> jax.Array:
    """Flatten a pytree into a single 1-D vector (float32)."""
    leaves = jax.tree_util.tree_leaves(a)
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])


def use_mesh(mesh):
    """Context manager activating ``mesh`` across JAX versions:
    ``jax.set_mesh`` where it exists (>= 0.6), the ``Mesh`` context itself
    on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def bucket_size(n: int, buckets=None) -> int:
    """Smallest bucket ladder rung holding ``n`` items: the next power of two,
    or the smallest entry of an explicit ``buckets`` ladder (which is a
    contract — ``n`` larger than the top rung fails loudly instead of
    silently extending the ladder).  Shared by the serve request batcher and
    the heterogeneous-minibatch schedule compiler so both compile one trace
    per rung, never one per size."""
    if n < 1:
        raise ValueError(f"need at least one item, got {n}")
    if buckets is None:
        return 1 << (n - 1).bit_length()
    fits = [b for b in buckets if b >= n]
    if not fits:
        raise ValueError(f"{n} items exceed the largest bucket "
                         f"{max(buckets)}; pass a deeper `buckets` ladder")
    return min(fits)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.3g}{unit}"
        n /= 1000.0
    return f"{n:.3g}Q"


def gaussian_log_density(x: jax.Array, mean: jax.Array, cov_diag: jax.Array) -> jax.Array:
    d = x.shape[-1]
    quad = jnp.sum((x - mean) ** 2 / cov_diag, axis=-1)
    logdet = jnp.sum(jnp.log(cov_diag))
    return -0.5 * (quad + logdet + d * math.log(2.0 * math.pi))

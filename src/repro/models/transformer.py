"""Model assembly: init, forward (train/prefill), decode (serve), loss.

One ``Model`` class covers all 10 assigned architectures via
``cfg.block_pattern``:

- ``attn_mlp``   dense decoder layer (llama-style; qk-norm / qkv-bias /
                 sliding-window per config)
- ``attn_moe``   MoE decoder layer (expert-parallel, see moe.py)
- ``hymba_mlp``  parallel attention + SSD heads (Hymba), then MLP
- ``mlstm`` / ``slstm``  xLSTM blocks (no separate MLP)

Homogeneous patterns (len == 1) stack layer parameters on a leading axis and
run under ``lax.scan`` (compile-time O(1) in depth); heterogeneous patterns
(xLSTM) use a python loop.  Every block is wrapped in ``jax.checkpoint`` for
training memory.

Decode state is a dict of stacked-per-layer arrays so it threads through the
same scan.  VLM/audio frontends are embedding stubs + a trainable projector
(the one allowed stub, DESIGN.md §2).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.attention import (
    attention_any,
    decode_attention,
    paged_decode_attention,
)
from repro.models.common import (
    apply_rope,
    dense_init,
    dtype_of,
    embed_init,
    head_rms_norm,
    partition_tree,
    rms_norm,
)
from repro.models.mlp import apply_mlp, init_mlp

PyTree = Any

FRONTEND_DIM = 1024  # stub embedding width (ViT/EnCodec feature dim)


# ===========================================================================
# per-component init
# ===========================================================================
def init_attn(key, cfg, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, d), dtype,
                         scale=1.0 / math.sqrt(cfg.q_dim * 2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def init_block(key, cfg, block: str, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if block in ("attn_mlp", "attn_moe", "hymba_mlp"):
        p["attn"] = init_attn(ks[0], cfg, dtype)
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
    if block == "hymba_mlp":
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg, dtype)
    if block in ("attn_mlp", "hymba_mlp"):
        p["mlp"] = init_mlp(ks[2], cfg, dtype)
    if block == "attn_moe":
        p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
    if block == "mlstm":
        p["mlstm"] = xlstm_lib.init_mlstm(ks[0], cfg, dtype)
    if block == "slstm":
        p["slstm"] = xlstm_lib.init_slstm(ks[0], cfg, dtype)
    return p


def init_params(key, cfg) -> PyTree:
    dtype = dtype_of(cfg)
    k_embed, k_stack, k_head, k_front = jax.random.split(key, 4)
    params: dict = {"embed": {"w": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype)},
                    "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)}
    if cfg.frontend:
        params["frontend"] = {"proj": dense_init(k_front, (FRONTEND_DIM, cfg.d_model), dtype)}

    pattern = cfg.block_pattern
    if len(pattern) == 1:
        keys = jax.random.split(k_stack, cfg.num_layers)
        params["stack"] = jax.vmap(
            lambda k: init_block(k, cfg, pattern[0], dtype))(keys)
    else:
        keys = jax.random.split(k_stack, cfg.num_layers)
        params["layers"] = [
            init_block(keys[i], cfg, pattern[i % len(pattern)], dtype)
            for i in range(cfg.num_layers)
        ]
    return params


# ===========================================================================
# block application
# ===========================================================================
def apply_attn(p, x, cfg, positions, *, window, cache=None, cur_pos=None,
               mesh=None, batch_axes=("data",), fused=False,
               fused_interpret=True):
    """cache: dict(k, v, pos) for decode; returns (y, new_kv or kv-for-prefill).

    ``fused=True`` (decode only) routes the cached-attention read plus the
    KV-slot write through the Pallas decode-step kernel instead of the
    ``dynamic_update`` + ``decode_attention`` pair; ``fused_interpret``
    picks the kernel's interpret mode (True everywhere but TPU).
    """
    B, S, d = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    # §Perf O1: pin head-major sharding so GSPMD never reshards k/v inside
    # the flash chunk loops.  q-heads shard over "model" when divisible; k/v
    # are repeated to H heads and inherit q's sharding (their params are
    # replicated under this layout, see partition_rules).
    if cache is None and cfg.opt_attn_head_shard and mesh is not None:
        from jax.sharding import PartitionSpec as _P
        bd = tuple(batch_axes) or None
        shardable = cfg.num_heads % mesh.shape["model"] == 0
        hspec = _P(bd, None, "model" if shardable else None, None)
        G = cfg.num_heads // cfg.num_kv_heads
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        q = jax.lax.with_sharding_constraint(q, hspec)
        k = jax.lax.with_sharding_constraint(k, hspec)
        v = jax.lax.with_sharding_constraint(v, hspec)

    if cache is None:  # train / prefill
        o = attention_any(q, k, v, causal=True, window=window,
                          window_slice=cfg.opt_window_slice)
        new_kv = (k, v)
    else:  # decode: S == 1
        smax = cache["k"].shape[1]
        slot = jnp.mod(cur_pos, smax)
        pos_arr = jax.lax.dynamic_update_index_in_dim(
            cache["pos"], jnp.asarray(cur_pos, cache["pos"].dtype), slot, 0)
        if fused:
            from repro.kernels.ops import fused_decode_step

            valid = (pos_arr >= 0) & (pos_arr <= cur_pos)
            if window is not None:
                valid &= pos_arr > (cur_pos - window)
            o, k_cache, v_cache = fused_decode_step(
                q[:, 0], k[:, 0], v[:, 0], cache["k"], cache["v"],
                valid.astype(jnp.int32), slot, interpret=fused_interpret)
            o = o[:, None]
        else:
            k_cache = jax.lax.dynamic_update_index_in_dim(cache["k"], k[:, 0],
                                                          slot, 1)
            v_cache = jax.lax.dynamic_update_index_in_dim(cache["v"], v[:, 0],
                                                          slot, 1)
            o = decode_attention(q, k_cache, v_cache, pos_arr, cur_pos,
                                 window=window)
        new_kv = {"k": k_cache, "v": v_cache, "pos": pos_arr}
    y = o.reshape(B, S, cfg.q_dim) @ p["wo"]
    return y, new_kv


def apply_paged_attn(p, x, cfg, pages, tables, positions, *, fused=False,
                     fused_interpret=True):
    """Cached attention over a paged KV pool — one slot per row.

    x: (S, 1, d); pages: dict(k, v) of (n_pages, page_size, KV, hd) pools
    shared by every slot; tables: (S, maxp) int32; positions: (S,) absolute
    position per slot (rope + write + validity).  Returns (y, new pages).
    """
    S, _, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(S, 1, cfg.num_heads, cfg.head_dim)
    k = k.reshape(S, 1, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(S, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions[:, None], cfg.rope_theta)
    k = apply_rope(k, positions[:, None], cfg.rope_theta)
    ps = pages["k"].shape[1]
    if fused:
        from repro.kernels.ops import fused_paged_decode_step

        o, k_pool, v_pool = fused_paged_decode_step(
            q[:, 0], k[:, 0], v[:, 0], pages["k"], pages["v"], tables,
            positions, interpret=fused_interpret)
        o = o[:, None]
    else:
        widx = (tables[jnp.arange(S), positions // ps] * ps + positions % ps)
        kf = pages["k"].reshape(-1, *pages["k"].shape[2:]).at[widx].set(k[:, 0])
        vf = pages["v"].reshape(-1, *pages["v"].shape[2:]).at[widx].set(v[:, 0])
        o = paged_decode_attention(q, kf, vf, tables, positions, ps)
        k_pool = kf.reshape(pages["k"].shape)
        v_pool = vf.reshape(pages["v"].shape)
    y = o.reshape(S, 1, cfg.q_dim) @ p["wo"]
    return y, {"k": k_pool, "v": v_pool}


def apply_paged_block(p, x, cfg, block: str, pages, tables, positions, *,
                      mesh=None, batch_axes=("data",), fsdp_axes=("data",),
                      fused=False, fused_interpret=True):
    """One decode step of an attention block against the paged pool — the
    same residual/norm/MLP ops as :func:`apply_block`'s decode path with
    :func:`apply_paged_attn` in place of the ring-cache attention.  Returns
    (x, new pages)."""
    if block not in ("attn_mlp", "attn_moe"):
        raise ValueError(f"paged decode needs an attention block, got {block!r}")
    rs = cfg.residual_scale
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    attn_out, new_pages = apply_paged_attn(
        p["attn"], h, cfg, pages, tables, positions, fused=fused,
        fused_interpret=fused_interpret)
    x = x + rs * attn_out
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if block == "attn_moe":
        ff, _ = moe_lib.apply_moe(p["moe"], h2, cfg, mesh=mesh,
                                  batch_axes=batch_axes, fsdp_axes=fsdp_axes)
    else:
        ff = apply_mlp(p["mlp"], h2, cfg)
    x = x + rs * ff
    return x, new_pages


def apply_block(p, x, cfg, block: str, positions, *, mesh=None, batch_axes=("data",),
                fsdp_axes=("data",), cache=None, cur_pos=None, fused=False,
                fused_interpret=True):
    """Returns (x, aux_loss, new_cache)."""
    rs = cfg.residual_scale
    aux = jnp.float32(0.0)
    new_cache: dict = {}
    window = cfg.sliding_window

    if block in ("attn_mlp", "attn_moe", "hymba_mlp"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        attn_out, kv = apply_attn(p["attn"], h, cfg, positions, window=window,
                                  cache=None if cache is None else cache["attn"],
                                  cur_pos=cur_pos, mesh=mesh,
                                  batch_axes=batch_axes, fused=fused,
                                  fused_interpret=fused_interpret)
        if block == "hymba_mlp":
            if cache is None:
                ssm_out = ssm_lib.apply_ssm(p["ssm"], h, cfg)
            else:
                st = ssm_lib.SSMState(h=cache["ssm_h"], conv=cache["ssm_conv"])
                ssm_out, new_st = ssm_lib.apply_ssm(p["ssm"], h, cfg, state=st)
                new_cache["ssm_h"], new_cache["ssm_conv"] = new_st.h, new_st.conv
            mix = 0.5 * (attn_out + ssm_out)
        else:
            mix = attn_out
        if cache is not None:
            new_cache["attn"] = kv
        x = x + rs * mix
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if block == "attn_moe":
            ff, aux = moe_lib.apply_moe(p["moe"], h2, cfg, mesh=mesh,
                                        batch_axes=batch_axes,
                                        fsdp_axes=fsdp_axes)
        else:
            ff = apply_mlp(p["mlp"], h2, cfg)
        x = x + rs * ff
        return x, aux, (new_cache if cache is not None else kv)

    if block == "mlstm":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if cache is None:
            out = xlstm_lib.apply_mlstm(p["mlstm"], h, cfg)
        else:
            st = xlstm_lib.MLSTMState(c=cache["mlstm_c"], n=cache["mlstm_n"],
                                      m=cache["mlstm_m"])
            out, new_st = xlstm_lib.apply_mlstm(p["mlstm"], h, cfg, state=st)
            new_cache = {"mlstm_c": new_st.c, "mlstm_n": new_st.n,
                         "mlstm_m": new_st.m}
        return x + rs * out, aux, new_cache

    if block == "slstm":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if cache is None:
            out = xlstm_lib.apply_slstm(p["slstm"], h, cfg)
        else:
            st = xlstm_lib.SLSTMState(c=cache["slstm_c"], n=cache["slstm_n"],
                                      m=cache["slstm_m"], h=cache["slstm_h"])
            out, new_st = xlstm_lib.apply_slstm(p["slstm"], h, cfg, state=st)
            new_cache = {"slstm_c": new_st.c, "slstm_n": new_st.n,
                         "slstm_m": new_st.m, "slstm_h": new_st.h}
        return x + rs * out, aux, new_cache

    raise ValueError(f"unknown block {block!r}")


# ===========================================================================
# the Model
# ===========================================================================
class Model:
    """Config-driven decoder.  Methods are pure; jit at the call site."""

    def __init__(self, cfg, mesh=None, batch_axes=("data",),
                 fsdp_axes=("data",), remat: bool = True,
                 decode_fused: bool = False, decode_interpret=None):
        self.cfg = cfg
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes)
        self.fsdp_axes = tuple(fsdp_axes)
        self.remat = remat
        # opt-in Pallas fused decode step (cached-attention read + KV slot
        # write in one kernel); the unfused path is the parity reference.
        # interpret mode follows the repo's kernel convention: compiled on
        # TPU, interpreted everywhere else, overridable per Model
        self.decode_fused = decode_fused
        self.decode_interpret = (jax.default_backend() != "tpu"
                                 if decode_interpret is None
                                 else decode_interpret)

    # -- embedding ------------------------------------------------------------
    def embed(self, params, batch) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x (B,S,d), positions (B,S) or (S,))."""
        cfg = self.cfg
        parts = []
        if cfg.frontend:
            fe = batch["frontend"]  # (B, N, FRONTEND_DIM) stub embeddings
            parts.append((fe @ params["frontend"]["proj"]).astype(dtype_of(cfg)))
        if "tokens" in batch:
            tok = batch["tokens"]
            parts.append(jnp.take(params["embed"]["w"], tok, axis=0))
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
        positions = jnp.arange(x.shape[1])
        return x, positions

    def unembed(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        w = (params["embed"]["w"].T if cfg.tie_embeddings
             else params["lm_head"]["w"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ w

    # -- forward over layers ----------------------------------------------------
    def forward(self, params, batch, want_kv: bool = False):
        """Train/prefill forward. Returns (logits, aux, kv-stack or None)."""
        cfg = self.cfg
        x, positions = self.embed(params, batch)

        def block_fn(p, x, block):
            return apply_block(p, x, cfg, block, positions, mesh=self.mesh,
                               batch_axes=self.batch_axes,
                               fsdp_axes=self.fsdp_axes)

        if self.remat:
            block_fn = jax.checkpoint(block_fn, static_argnums=(2,),
                                      policy=jax.checkpoint_policies.nothing_saveable)

        aux_total = jnp.float32(0.0)
        kvs = None
        if "stack" in params and cfg.opt_unroll_layers:
            # §Perf: unrolled layers — each FSDP all-gather is a per-layer
            # slice instead of a full-stack gather inside the scan
            kvs = []
            for i in range(cfg.num_layers):
                layer_p = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                                 params["stack"])
                x, a, kv = block_fn(layer_p, x, cfg.block_pattern[0])
                aux_total = aux_total + a
                kvs.append(kv if want_kv else None)
            kvs = None if not want_kv else jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *kvs)
        elif "stack" in params:
            block = cfg.block_pattern[0]

            def scan_body(carry, layer_p):
                x, aux = carry
                x, a, kv = block_fn(layer_p, x, block)
                return (x, aux + a), (kv if want_kv else None)

            (x, aux_total), kvs = jax.lax.scan(scan_body, (x, aux_total),
                                               params["stack"])
        else:
            kvs = []
            for i, layer_p in enumerate(params["layers"]):
                block = cfg.block_pattern[i % len(cfg.block_pattern)]
                x, a, kv = block_fn(layer_p, x, block)
                aux_total = aux_total + a
                kvs.append(kv if want_kv else None)
        logits = self.unembed(params, x)
        return logits, aux_total / cfg.num_layers, kvs

    # -- decode -------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_seq: int, prefill_len: int = 0):
        """Decode cache, stacked per layer (scan-compatible)."""
        cfg = self.cfg
        dtype = dtype_of(cfg)
        L = cfg.num_layers
        window = cfg.sliding_window
        smax = min(max_seq, window) if window else max_seq

        def attn_entry():
            pos = jnp.where(jnp.arange(smax) < prefill_len,
                            jnp.arange(smax), -1).astype(jnp.int32)
            return {
                "k": jnp.zeros((batch_size, smax, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch_size, smax, cfg.num_kv_heads, cfg.head_dim), dtype),
                "pos": pos,
            }

        def entry_for(block):
            e: dict = {}
            if block in ("attn_mlp", "attn_moe", "hymba_mlp"):
                e["attn"] = attn_entry()
            if block == "hymba_mlp":
                st = ssm_lib.init_ssm_state(cfg, batch_size, dtype)
                e["ssm_h"], e["ssm_conv"] = st.h, st.conv
            if block == "mlstm":
                st = xlstm_lib.init_mlstm_state(cfg, batch_size)
                e.update(mlstm_c=st.c, mlstm_n=st.n, mlstm_m=st.m)
            if block == "slstm":
                st = xlstm_lib.init_slstm_state(cfg, batch_size)
                e.update(slstm_c=st.c, slstm_n=st.n, slstm_m=st.m, slstm_h=st.h)
            return e

        if len(cfg.block_pattern) == 1:
            one = entry_for(cfg.block_pattern[0])
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
        return [entry_for(cfg.block_pattern[i % len(cfg.block_pattern)])
                for i in range(L)]

    def _require_stacked_attention(self, what: str):
        cfg = self.cfg
        if len(cfg.block_pattern) != 1 or cfg.block_pattern[0] not in (
                "attn_mlp", "attn_moe"):
            raise ValueError(
                f"{what} needs a homogeneous attention stack "
                f"(block_pattern ('attn_mlp',) or ('attn_moe',)), got "
                f"{cfg.block_pattern}; SSM/xLSTM states have no prefill-"
                "fillable KV cache")
        if cfg.frontend:
            raise ValueError(f"{what} serves token prompts only "
                             f"(frontend={cfg.frontend!r})")

    def init_cache_bank(self, num_chains: int, batch_size: int, max_seq: int):
        """Chain-stacked decode cache: :meth:`init_cache` with every leaf
        gaining a leading ``(num_chains,)`` axis — the per-chain KV-cache
        bank a :class:`~repro.cluster.decode.DecodeEngine` allocates once
        per bucket rung and donates across serve steps."""
        from repro.utils import tree_broadcast_leading

        self._require_stacked_attention("init_cache_bank")
        return tree_broadcast_leading(self.init_cache(batch_size, max_seq),
                                      num_chains)

    def _require_paged(self, what: str):
        self._require_stacked_attention(what)
        if self.cfg.sliding_window:
            raise ValueError(
                f"{what} serves full attention only: a sliding window would "
                "need per-slot ring pages (the contiguous decode cache "
                "already implements windowed rings)")

    def init_paged_bank(self, num_chains: int, num_pages: int,
                        page_size: int):
        """Paged decode-cache bank: one shared block pool per chain.

        Returns ``{"k", "v"}`` of shape ``(num_chains, num_layers,
        num_pages, page_size, num_kv_heads, head_dim)`` — unlike
        :meth:`init_cache_bank` there is no per-sequence ring; every serving
        slot maps its logical pages into the shared pool through a per-slot
        page table, so mixed-length sequences share HBM without per-request
        reallocation.  Physical page 0 is reserved by the scheduler as the
        garbage page inactive slots write into.  The bank is donated across
        steps by :class:`~repro.cluster.paged.PagedDecodeEngine`.
        """
        self._require_paged("init_paged_bank")
        cfg = self.cfg
        shape = (num_chains, cfg.num_layers, num_pages, page_size,
                 cfg.num_kv_heads, cfg.head_dim)
        dtype = dtype_of(cfg)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def paged_prefill(self, params, tokens, pages, table, prompt_len):
        """Prefill one prompt into its slot's pages.

        ``tokens`` is a bucket-padded ``(1, T_pad)`` prompt with true length
        ``prompt_len`` (traced scalar); ``pages`` is the single-chain pool
        ``{"k", "v"}: (L, n_pages, page_size, KV, hd)``; ``table`` is this
        slot's ``(maxp,)`` page table.  The prompt's per-layer KV scatters
        into logical positions ``[0, T_pad)`` of the slot's pages (pad
        positions carry garbage but stay masked by the positional validity
        until overwritten).  Returns ``(logits at prompt_len - 1 (1, V),
        pages)``.  Single-chain; the engine vmaps it over the bank.
        """
        self._require_paged("paged_prefill")
        T = tokens.shape[1]
        L, _, ps = pages["k"].shape[:3]
        if T > table.shape[0] * ps:
            raise ValueError(
                f"padded prompt length {T} exceeds the slot's "
                f"{table.shape[0]} x {ps} paged capacity (raise max_seq, or "
                "loosen the prompt bucket ladder)")
        logits, _, (k, v) = self.forward(params, {"tokens": tokens},
                                         want_kv=True)  # (L, 1, T, KV, hd)
        last = jax.lax.dynamic_index_in_dim(logits, prompt_len - 1, axis=1,
                                            keepdims=False)  # (1, V)
        r = jnp.arange(T)
        idx = table[r // ps] * ps + r % ps  # logical -> flat physical rows
        kf = pages["k"].reshape(L, -1, *pages["k"].shape[3:])
        vf = pages["v"].reshape(L, -1, *pages["v"].shape[3:])
        return last, {
            "k": kf.at[:, idx].set(k[:, 0]).reshape(pages["k"].shape),
            "v": vf.at[:, idx].set(v[:, 0]).reshape(pages["v"].shape),
        }

    def paged_step(self, params, pages, tables, tokens, positions):
        """One decode step over the serving slots of a paged pool.

        tokens: (S, 1) int32 — the last token of each slot; tables:
        (S, maxp) int32; positions: (S,) int32 absolute position each
        slot's token is written at (the scheduler clamps inactive slots to
        0 and points their table rows at the garbage page).  Returns
        (logits (S, 1, V), new pages).  Single-chain; vmapped over the bank.
        """
        self._require_paged("paged_step")
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], tokens, axis=0)  # (S, 1, d)
        block = cfg.block_pattern[0]

        def scan_body(x, inp):
            layer_p, pg = inp
            x, new_pg = apply_paged_block(
                layer_p, x, cfg, block, pg, tables, positions,
                mesh=self.mesh, batch_axes=self.batch_axes,
                fsdp_axes=self.fsdp_axes, fused=self.decode_fused,
                fused_interpret=self.decode_interpret)
            return x, new_pg

        x, new_pages = jax.lax.scan(scan_body, x, (params["stack"], pages))
        logits = self.unembed(params, x)
        return logits, new_pages

    def prefill_cache(self, params, tokens, cache, prompt_len):
        """Padded-prompt prefill *into* a persistent decode cache.

        ``tokens`` is a bucket-padded prompt batch ``(B, T_pad)`` whose real
        length is the traced scalar ``prompt_len`` (<= T_pad); right-padding
        never leaks into real positions because attention is causal.  The
        prompt's per-layer KV lands in cache slots ``[0, T_pad)`` and slots
        at/after ``prompt_len`` are marked empty (pos = -1), so the pad
        entries stay masked until the decode loop overwrites them in ring
        order.  Returns ``(logits at position prompt_len - 1 (B, V), cache)``.

        Single-chain; a chain bank vmaps this together with
        :meth:`serve_step`.
        """
        self._require_stacked_attention("prefill_cache")
        T = tokens.shape[1]
        smax = cache["attn"]["k"].shape[2]  # (L, B, smax, KV, hd)
        if T > smax:
            raise ValueError(
                f"padded prompt length {T} exceeds the cache's {smax} slots "
                "(raise max_seq, or loosen the prompt bucket ladder)")
        logits, _, (k, v) = self.forward(params, {"tokens": tokens},
                                         want_kv=True)
        last = jax.lax.dynamic_index_in_dim(logits, prompt_len - 1, axis=1,
                                            keepdims=False)  # (B, V)
        L = cache["attn"]["k"].shape[0]
        pos = jnp.where(jnp.arange(smax) < prompt_len, jnp.arange(smax),
                        -1).astype(jnp.int32)
        return last, {"attn": {
            "k": cache["attn"]["k"].at[:, :, :T].set(k),
            "v": cache["attn"]["v"].at[:, :, :T].set(v),
            "pos": jnp.broadcast_to(pos[None], (L, smax)),
        }}

    def serve_step(self, params, cache, tokens, cur_pos):
        """One decode step. tokens: (B, 1) int32; cur_pos: scalar int32.

        Returns (logits (B, 1, V), new_cache).
        """
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], tokens, axis=0)  # (B, 1, d)
        positions = jnp.asarray(cur_pos)[None]

        def block_fn(p, x, block, c):
            return apply_block(p, x, cfg, block, positions, mesh=self.mesh,
                               batch_axes=self.batch_axes,
                               fsdp_axes=self.fsdp_axes, cache=c,
                               cur_pos=cur_pos, fused=self.decode_fused,
                               fused_interpret=self.decode_interpret)

        if "stack" in params:
            block = cfg.block_pattern[0]

            def scan_body(x, inp):
                layer_p, c = inp
                x, _, new_c = block_fn(layer_p, x, block, c)
                return x, new_c

            x, new_cache = jax.lax.scan(scan_body, x, (params["stack"], cache))
        else:
            new_cache = []
            for i, layer_p in enumerate(params["layers"]):
                block = cfg.block_pattern[i % len(cfg.block_pattern)]
                x, _, c = block_fn(layer_p, x, block, cache[i])
                new_cache.append(c)
        logits = self.unembed(params, x)
        return logits, new_cache

    def prefill(self, params, batch):
        """Full-prompt forward; returns (last-token logits, attn cache).

        For attention architectures the per-layer (k, v) from the forward pass
        become the decode cache (trimmed to the sliding window if set).  For
        SSM/hybrid/xLSTM blocks the recurrent state is rebuilt by the decode
        path itself (examples use ``init_cache`` + replay); the prefill SHAPE
        in the dry-run lowers this forward pass, which is the expensive part.
        """
        cfg = self.cfg
        logits, _, kvs = self.forward(params, batch, want_kv=True)
        window = cfg.sliding_window
        if "stack" in params and cfg.block_pattern[0] in ("attn_mlp", "attn_moe"):
            k, v = kvs  # (L, B, S, KV, hd) each
            S = k.shape[2]
            if window and S > window:
                k, v = k[:, :, -window:], v[:, :, -window:]
                pos = jnp.arange(S - window, S, dtype=jnp.int32)
            else:
                pos = jnp.arange(S, dtype=jnp.int32)
            return logits[:, -1:], {"attn": {"k": k, "v": v, "pos": pos}}
        return logits[:, -1:], None


# ===========================================================================
# loss
# ===========================================================================
def loss_fn(model: Model, params, batch) -> tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy (+ MoE aux).  batch carries 'tokens' (B, S+1)
    and optionally 'frontend'; loss is computed on token positions only."""
    cfg = model.cfg
    tokens = batch["tokens"]
    inp = dict(batch)
    inp["tokens"] = tokens[:, :-1]
    logits, aux, _ = model.forward(params, inp)
    labels = tokens[:, 1:]
    n_text = labels.shape[1]
    logits_text = logits[:, -n_text:]  # skip frontend positions
    logp = jax.nn.log_softmax(logits_text.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = -jnp.mean(ll)
    total = ce + cfg.router_aux_coef * aux
    return total, {"ce": ce, "aux": aux}


partition_tree = partition_tree  # re-export for repro.models namespace

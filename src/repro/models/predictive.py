"""Predict-fn builders: adapt the repo's models to ``ServeEngine``'s
per-chain forward contract ``(single-chain params, queries (Q, ...)) ->
predictions (Q, ...)``.

Each builder closes over the model/config and returns a pure function the
engine vmaps over the chain axis, so Bayesian model averaging and credible
intervals come from the same forward passes training used — the transformer
builder goes through ``Model.prefill``, the entry point of the decode/serve
path, not a parallel reimplementation.
"""

from __future__ import annotations

from typing import Any, Callable

import jax.numpy as jnp

from repro.models.mlp import apply_mlp

PyTree = Any
PredictFn = Callable[[PyTree, Any], jnp.ndarray]


def regression_predict(reg) -> PredictFn:
    """Posterior-predictive of :class:`~repro.core.potentials.PolyRegression`:
    queries are raw inputs ``z (Q,)``, predictions ``phi(z)·w + b (Q,)``."""

    def predict(w, z):
        return reg.predict(w, reg.features(z))

    return predict


def mlp_predict(cfg) -> PredictFn:
    """Feed-forward block as a regression head: queries ``x (Q, d_model)``,
    predictions ``(Q, d_model)`` through :func:`~repro.models.mlp.apply_mlp`."""

    def predict(params, x):
        return apply_mlp(params, x, cfg)

    return predict


def transformer_next_token_predict(model) -> PredictFn:
    """Next-token logits through the serving path: queries are a prompt batch
    (``{"tokens": (Q, T)}``), predictions the last-position logits ``(Q, V)``
    from :meth:`~repro.models.transformer.Model.prefill` — ensemble-averaging
    them is Bayesian model averaging over the chain bank at decode time."""

    def predict(params, batch):
        logits, _ = model.prefill(params, batch)  # (Q, 1, V)
        return logits[:, 0].astype(jnp.float32)

    return predict

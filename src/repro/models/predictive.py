"""Predict-fn builders: adapt the repo's models to ``ServeEngine``'s
per-chain forward contract ``(single-chain params, queries (Q, ...)) ->
predictions (Q, ...)``.

Each builder closes over the model/config and returns a pure function the
engine vmaps over the chain axis, so Bayesian model averaging and credible
intervals come from the same forward passes training used — the transformer
builder goes through ``Model.prefill``, the entry point of the decode/serve
path, not a parallel reimplementation.
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.mlp import apply_mlp

PyTree = Any
PredictFn = Callable[[PyTree, Any], jnp.ndarray]


def bma_logits(per_chain_logits: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Bayesian-model-averaged next-token log-probabilities.

    Reduces per-chain logits ``(C, ..., V)`` to the log of the *mean* of the
    per-chain softmax distributions — the posterior-predictive token law of
    the chain bank — computed stably in log space.  The single source of
    truth for the decode-time reduction: the sharded
    :class:`~repro.cluster.decode.DecodeEngine` path calls it on the
    all-gathered logit block, the single-device path on the vmapped output,
    so the two are bitwise-identical by construction (the serve-module
    parity contract).
    """
    C = per_chain_logits.shape[axis]
    logp = jax.nn.log_softmax(per_chain_logits.astype(jnp.float32), axis=-1)
    return jax.nn.logsumexp(logp, axis=axis) - jnp.float32(math.log(C))


def regression_predict(reg) -> PredictFn:
    """Posterior-predictive of :class:`~repro.core.potentials.PolyRegression`:
    queries are raw inputs ``z (Q,)``, predictions ``phi(z)·w + b (Q,)``."""

    def predict(w, z):
        return reg.predict(w, reg.features(z))

    return predict


def mlp_predict(cfg) -> PredictFn:
    """Feed-forward block as a regression head: queries ``x (Q, d_model)``,
    predictions ``(Q, d_model)`` through :func:`~repro.models.mlp.apply_mlp`."""

    def predict(params, x):
        return apply_mlp(params, x, cfg)

    return predict


def transformer_next_token_predict(model) -> PredictFn:
    """Next-token logits through the serving path: queries are a prompt batch
    (``{"tokens": (Q, T)}``), predictions the last-position logits ``(Q, V)``
    from :meth:`~repro.models.transformer.Model.prefill` — ensemble-averaging
    them is Bayesian model averaging over the chain bank at decode time."""

    def predict(params, batch):
        logits, _ = model.prefill(params, batch)  # (Q, 1, V)
        return logits[:, 0].astype(jnp.float32)

    return predict

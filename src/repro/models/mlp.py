"""Feed-forward blocks: gated (SwiGLU) and plain (GELU, for MusicGen)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init


def init_mlp(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "gelu":  # plain 2-matrix MLP
        k1, k2 = jax.random.split(key)
        return {
            "w_up": dense_init(k1, (d, f), dtype),
            "w_down": dense_init(k2, (f, d), dtype),
        }
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), dtype),
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype),
    }


def apply_mlp(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    act = activation(cfg.act)
    if "w_gate" in params:
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = act(x @ params["w_up"])
    return h @ params["w_down"]

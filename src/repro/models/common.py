"""Shared model components: norms, rotary embeddings, init, sharding rules.

Parameters are plain nested dicts.  Sharding is derived from *leaf path
names* (t5x-style logical rules): see ``partition_rules``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def head_rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Per-head qk-norm: x (..., H, hd), scale (hd,)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None) -> jnp.ndarray:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# sharding rules: leaf-path regexp-free suffix matching
# ---------------------------------------------------------------------------
# Each rule: (path_suffix, PartitionSpec). First match wins. "mdl" = tensor
# axis, "fsdp_axes" used only under param_sharding == "fsdp_tp".
def partition_rules(param_sharding: str, fsdp_axes=("data",), cfg=None,
                    model_size: int | None = None):
    mdl = "model"
    fsdp = fsdp_axes  # secondary axes for trillion-scale 2-D sharding
    two_d = param_sharding == "fsdp_tp"
    if param_sharding == "fsdp_full":
        # §Perf O3: pure FSDP/ZeRO-3 — every weight sharded over ALL
        # data-like+model axes (gathered per layer), batch over all axes,
        # no tensor-parallel activation all-reduces at all.
        mdl = tuple(fsdp_axes) + ("model",)
    # §Perf O1 layout: q heads shard over model (when divisible), k/v params
    # replicate (activations repeated to H heads inherit q's sharding)
    head_shard = bool(cfg is not None and getattr(cfg, "opt_attn_head_shard",
                                                  False))
    q_shardable = bool(head_shard and model_size
                       and cfg.num_heads % model_size == 0)
    # Never shard an attention projection finer than its head boundary:
    # splitting one head's head_dim across devices forces cross-shard
    # resharding inside rope/norm/attention (and miscompiles on some XLA
    # CPU builds).  Unknown cfg/model_size keeps the legacy always-shard
    # rule for backward compatibility.
    q_head_ok = bool(cfg is None or not model_size
                     or cfg.num_heads % model_size == 0)
    kv_head_ok = bool(cfg is None or not model_size
                      or cfg.num_kv_heads % model_size == 0)
    if head_shard:
        wq_spec = P(None, mdl) if q_shardable else P(None, None)
        wo_spec = P(mdl, None) if q_shardable else P(None, None)
        kv_spec = P(None, None)
        kvb_spec = P(None)
        qb_spec = P(mdl) if q_shardable else P(None)
    else:
        wq_spec = P(None, mdl) if q_head_ok else P(None, None)
        wo_spec = P(mdl, None) if q_head_ok else P(None, None)
        kv_spec = P(None, mdl) if kv_head_ok else P(None, None)
        kvb_spec = P(mdl) if kv_head_ok else P(None)
        qb_spec = P(mdl) if q_head_ok else P(None)
    rules = [
        # embeddings / head
        ("embed/w", P(mdl, None)),
        ("lm_head/w", P(None, mdl)),
        # attention
        ("attn/wq", wq_spec),
        ("attn/wk", kv_spec),
        ("attn/wv", kv_spec),
        ("attn/wo", wo_spec),
        ("attn/bq", qb_spec),
        ("attn/bk", kvb_spec),
        ("attn/bv", kvb_spec),
        ("attn/q_norm", P(None)),
        ("attn/k_norm", P(None)),
        # dense mlp
        ("mlp/w_gate", P(None, mdl)),
        ("mlp/w_up", P(None, mdl)),
        ("mlp/w_down", P(mdl, None)),
        # moe: experts over model axis; optionally d_ff over data axis (2-D)
        ("moe/w_gate", P(mdl, None, fsdp if two_d else None)),
        ("moe/w_up", P(mdl, None, fsdp if two_d else None)),
        ("moe/w_down", P(mdl, fsdp if two_d else None, None)),
        ("moe/router", P(None, None)),
        ("moe/shared_w_gate", P(None, mdl)),
        ("moe/shared_w_up", P(None, mdl)),
        ("moe/shared_w_down", P(mdl, None)),
        # mamba / hymba ssm heads
        ("ssm/in_proj", P(None, mdl)),
        ("ssm/conv_w", P(mdl, None)),
        ("ssm/dt_w", P(None, mdl)),
        ("ssm/dt_bias", P(mdl)),
        ("ssm/bc_proj", P(None, None)),
        ("ssm/a_log", P(mdl)),
        ("ssm/d_skip", P(mdl)),
        ("ssm/out_proj", P(mdl, None)),
        # xlstm
        # xLSTM blocks are batch-parallel with replicated params (§Perf
        # pair-4): every TP layout tried (column-TP baseline, dv-sharded
        # state) makes GSPMD reshard the (B,S,H,dk) <-> (B,S,di) views at
        # each layer (45s / 185s of collective vs 34s replicated).  The
        # right TP for matrix-state recurrences is a hand-written shard_map
        # (as done for MoE) — documented future work.
        ("mlstm/", P(None)),
        ("slstm/", P(None)),
        # frontend projector stub
        ("frontend/proj", P(None, mdl)),
        # norms & everything 1-D replicated
        ("norm", P(None)),
    ]
    return rules


def spec_for_path(path: str, rules) -> P:
    for suffix, spec in rules:
        if suffix in path:
            return spec
    return P()  # replicate


def partition_tree(params: PyTree, param_sharding: str = "tp",
                   fsdp_axes=("data",), cfg=None,
                   model_size: int | None = None) -> PyTree:
    """PartitionSpec pytree matching ``params`` by leaf path."""
    rules = partition_rules(param_sharding, fsdp_axes, cfg, model_size)

    def visit(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        spec = spec_for_path(path, rules)
        # stacked-layer params carry a leading L axis -> prepend None
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        if len(spec) < ndim and "/stack/" in "/" + path + "/":
            spec = P(*((None,) + tuple(spec)))
        if len(spec) > ndim:
            spec = P(*spec[:ndim])
        return spec

    return jax.tree_util.tree_map_with_path(visit, params)

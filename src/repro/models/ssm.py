"""Selective state-space heads (Mamba-2 / SSD formulation) — TPU-adapted.

HARDWARE ADAPTATION (DESIGN.md §2): Mamba-1's per-(channel, state) selective
scan is a GPU-shaped algorithm (deep sequential recurrence, poor MXU
utilization).  We implement the SSD (state-space duality) form used by
Mamba-2: scalar decay per head per step, so a sequence chunk becomes two
MXU-friendly matmuls (intra-chunk "attention-like" term + inter-chunk state
carry) and the recurrence runs only across chunks (lax.scan).  ``ssm_state``
(=16 for hymba) is the per-head state width n.

Shapes: inner dim di = 2*d_model, heads H (= attention heads), head dim
p = di/H, state n.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm


def init_ssm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = 2 * d
    H = cfg.num_heads
    n = cfg.ssm_state
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": (jax.random.normal(ks[1], (K, di), jnp.float32) / math.sqrt(K)
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "bc_proj": dense_init(ks[2], (di, 2 * n), dtype),
        "dt_w": dense_init(ks[3], (di, H), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (H,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(jnp.float32),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype),
    }


class SSMState(NamedTuple):
    h: jnp.ndarray        # (B, H, p, n) fp32
    conv: jnp.ndarray     # (B, K-1, di) last inputs for depthwise conv


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    di = 2 * cfg.d_model
    H, n, K = cfg.num_heads, cfg.ssm_state, cfg.ssm_conv
    p = di // H
    return SSMState(
        h=jnp.zeros((batch, H, p, n), jnp.float32),
        conv=jnp.zeros((batch, K - 1, di), dtype),
    )


def _depthwise_conv(x, conv_w, conv_b, conv_state=None):
    """Causal depthwise conv along seq. x: (B, S, di); conv_w: (K, di)."""
    K = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out + conv_b, new_state


def _ssd_chunk_scan(xh, bt, ct, dt, a, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, p); bt, ct: (B, S, n); dt: (B, S, H) (post-softplus);
    a: (H,) negative decay rate.  Returns y: (B, S, H, p) and final state
    h: (B, H, p, n).
    """
    B, S, H, p = xh.shape
    n = bt.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, f"seq {S} not divisible by ssm chunk {c}"
    nc = S // c

    # log-decay per step: la = dt * a  (negative), (B, S, H)
    la = dt * a[None, None, :]
    xc = xh.reshape(B, nc, c, H, p).swapaxes(0, 1)
    bc = bt.reshape(B, nc, c, n).swapaxes(0, 1)
    cc = ct.reshape(B, nc, c, n).swapaxes(0, 1)
    dtc = dt.reshape(B, nc, c, H).swapaxes(0, 1)
    lac = la.reshape(B, nc, c, H).swapaxes(0, 1)

    def chunk_step(h, inp):
        xb, bb, cb, dtb, lab = inp  # (B,c,H,p),(B,c,n),(B,c,n),(B,c,H),(B,c,H)
        seg = jnp.cumsum(lab, axis=1)  # (B, c, H) log decay from chunk start
        # intra-chunk: scores[t,s] = (C_t·B_s) * exp(seg_t - seg_s) * dt_s, s<=t
        logw = seg[:, :, None, :] - seg[:, None, :, :]  # (B, c, c, H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(tri[None, :, :, None], jnp.exp(logw), 0.0)
        cb32, bb32 = cb.astype(jnp.float32), bb.astype(jnp.float32)
        scores = jnp.einsum("btn,bsn->bts", cb32, bb32)[..., None] * w  # (B,c,c,H)
        scores = scores * dtb[:, None, :, :]  # dt_s
        y_intra = jnp.einsum("btsh,bshp->bthp", scores, xb.astype(jnp.float32))
        # inter-chunk: y_t += C_t · (exp(seg_t) * h)
        decay_t = jnp.exp(seg)  # (B, c, H)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", cb32, h, decay_t)
        # state update: h' = exp(seg_end)*h + sum_s exp(seg_end-seg_s) dt_s x_s B_s
        seg_end = seg[:, -1:, :]  # (B,1,H)
        w_end = jnp.exp(seg_end - seg) * dtb  # (B, c, H)
        h_new = (jnp.exp(seg_end[:, 0, :])[:, :, None, None] * h
                 + jnp.einsum("bch,bchp,bcn->bhpn", w_end,
                              xb.astype(jnp.float32), bb32))
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, p, n), jnp.float32)
    h, yc = jax.lax.scan(chunk_step, h0, (xc, bc, cc, dtc, lac))
    y = yc.swapaxes(0, 1).reshape(B, S, H, p)
    return y, h


def apply_ssm(params, x, cfg, *, chunk: int = 64, state: SSMState | None = None):
    """Full-sequence SSD block.  x: (B, S, d) -> (B, S, d).

    With ``state`` (decode) S must be 1 and the recurrence is single-step.
    """
    B, S, d = x.shape
    di = 2 * d
    H, n = cfg.num_heads, cfg.ssm_state
    p = di // H

    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each
    conv_state = state.conv if state is not None else None
    xi, new_conv = _depthwise_conv(xi, params["conv_w"], params["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    bcm = xi @ params["bc_proj"]  # (B, S, 2n)
    bt, ct = jnp.split(bcm, 2, axis=-1)
    dt = jax.nn.softplus((xi @ params["dt_w"]).astype(jnp.float32)
                         + params["dt_bias"])  # (B, S, H)
    a = -jnp.exp(params["a_log"])  # (H,) negative
    xh = xi.reshape(B, S, H, p)

    if state is None:
        y, h_final = _ssd_chunk_scan(xh, bt, ct, dt, a, chunk)  # a negative
        new_state = None
    else:
        # single-step decode: h' = exp(dt*a) h + dt * x ⊗ B ; y = h'·C
        la = jnp.exp(dt[:, 0] * a[None, :])  # (B, H)
        xb = jnp.einsum("bhp,bn->bhpn", xh[:, 0].astype(jnp.float32),
                        bt[:, 0].astype(jnp.float32))
        h_new = la[:, :, None, None] * state.h + dt[:, 0][:, :, None, None] * xb
        y = jnp.einsum("bhpn,bn->bhp", h_new, ct[:, 0].astype(jnp.float32))[:, None]
        new_state = SSMState(h=h_new, conv=new_conv)
        h_final = h_new

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if state is None:
        return out
    return out, new_state

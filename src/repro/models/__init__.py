from repro.models.transformer import (  # noqa: F401
    Model,
    init_params,
    loss_fn,
    partition_tree,
)

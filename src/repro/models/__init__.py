from repro.models.predictive import (  # noqa: F401
    bma_logits,
    mlp_predict,
    regression_predict,
    transformer_next_token_predict,
)
from repro.models.transformer import (  # noqa: F401
    Model,
    init_params,
    loss_fn,
    partition_tree,
)

"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential) [arXiv:2405.04517].

HARDWARE ADAPTATION: the mLSTM recurrence C_t = f_t C_{t-1} + i_t k_t v_t^T
is computed chunkwise (linear-attention duality) so the inner work is MXU
matmuls and only the cross-chunk carry is sequential — same pattern as the
SSD scan in ssm.py.  Exponential gating is stabilized in log space with a
carried max-state m, following the paper's Appendix formulation.  sLSTM is
inherently sequential (its recurrent weights feed h_{t-1} through a dense
matrix) and runs as a lax.scan over time; xLSTM[7:1] keeps only 1-in-8
layers sLSTM, so the sequential fraction is small.

mLSTM state per head: C (dk, dv), n (dk,), m scalar.
sLSTM state per unit: c, n, m, h.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================
def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = 2 * d                      # pre-up-projection factor 2
    H = cfg.num_heads
    dk = di // H
    ks = jax.random.split(key, 8)
    def headmat(k):  # block-diagonal per-head proj (paper's param budget)
        return (jax.random.normal(k, (H, dk, dk), jnp.float32)
                / math.sqrt(dk)).astype(dtype)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),     # x and gate z
        # separate q/k/v head-mats: fused (dk,3dk) would be resharded by
        # GSPMD at the split point (§Perf pair-4 lesson)
        "wq": headmat(ks[1]),
        "wk": headmat(ks[6]),
        "wv": headmat(ks[7]),
        "gates": dense_init(ks[2], (di, 2 * H), dtype),       # i~, f~ per head
        "gates_b": jnp.concatenate([
            jnp.zeros((H,), jnp.float32),                     # input gate bias
            jnp.linspace(3.0, 6.0, H),                        # forget bias (high)
        ]).astype(jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[3], (di, d), dtype),
        "skip": jnp.ones((di,), jnp.float32),
    }


class MLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, H, dk, dv) fp32
    n: jnp.ndarray  # (B, H, dk) fp32
    m: jnp.ndarray  # (B, H) fp32 stabilizer


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    di = 2 * cfg.d_model
    H = cfg.num_heads
    dk = di // H
    return MLSTMState(
        c=jnp.zeros((batch, H, dk, dk), jnp.float32),
        n=jnp.zeros((batch, H, dk), jnp.float32),
        m=jnp.full((batch, H), 0.0, jnp.float32),
    )


def _mlstm_chunk(q, k, v, lf, li, chunk: int, state: MLSTMState):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B, S, H, dk) fp32; lf: (B, S, H) log forget gate (logsigmoid);
    li: (B, S, H) input gate pre-activation (log space).
    Returns y: (B, S, H, dk) and final MLSTMState.
    """
    B, S, H, dk = q.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c

    qc = q.reshape(B, nc, c, H, dk).swapaxes(0, 1)
    kc = k.reshape(B, nc, c, H, dk).swapaxes(0, 1)
    vc = v.reshape(B, nc, c, H, dk).swapaxes(0, 1)
    lfc = lf.reshape(B, nc, c, H).swapaxes(0, 1)
    lic = li.reshape(B, nc, c, H).swapaxes(0, 1)

    def step(carry, inp):
        C, n, m = carry  # (B,H,dk,dv), (B,H,dk), (B,H)
        qb, kb, vb, lfb, lib = inp
        seg = jnp.cumsum(lfb, axis=1)                      # (B, c, H)
        # log weight of source s seen at target t: seg_t - seg_s + li_s
        logw = seg[:, :, None, :] - seg[:, None, :, :] + lib[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        logw = jnp.where(tri[None, :, :, None], logw, NEG)  # (B,t,s,H)
        # inter-chunk contribution enters with log weight seg_t + m
        log_inter = seg + m[:, None, :]                    # (B, c, H)
        m_intra = jnp.max(logw, axis=2)                    # (B, c, H)
        m_t = jnp.maximum(m_intra, log_inter)              # stabilizer per t
        w = jnp.exp(logw - m_t[:, :, None, :])             # (B,t,s,H)
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) / math.sqrt(dk)
        num_intra = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, vb)
        den_intra = jnp.einsum("btsh,btsh->bth", scores, w)
        inter_scale = jnp.exp(log_inter - m_t)             # (B, c, H)
        num_inter = jnp.einsum("bthd,bhde,bth->bthe", qb, C, inter_scale) / math.sqrt(dk)
        den_inter = jnp.einsum("bthd,bhd,bth->bth", qb, n, inter_scale) / math.sqrt(dk)
        num = num_intra + num_inter
        den = den_intra + den_inter
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to chunk end
        seg_end = seg[:, -1, :]                            # (B, H)
        m_new = jnp.maximum(seg_end + m, jnp.max(seg_end[:, None, :] - seg + lib, axis=1))
        w_end = jnp.exp(seg_end[:, None, :] - seg + lib - m_new[:, None, :])  # (B,c,H)
        carry_scale = jnp.exp(seg_end + m - m_new)         # (B, H)
        C_new = (carry_scale[:, :, None, None] * C
                 + jnp.einsum("bch,bchd,bche->bhde", w_end, kb, vb))
        n_new = carry_scale[:, :, None] * n + jnp.einsum("bch,bchd->bhd", w_end, kb)
        return (C_new, n_new, m_new), y

    (C, n, m), yc = jax.lax.scan(step, (state.c, state.n, state.m),
                                 (qc, kc, vc, lfc, lic))
    y = yc.swapaxes(0, 1).reshape(B, S, H, dk)
    return y, MLSTMState(c=C, n=n, m=m)


def apply_mlstm(params, x, cfg, *, chunk: int = 64, state: MLSTMState | None = None):
    """x: (B, S, d) -> (B, S, d) [, new state when decoding]."""
    B, S, d = x.shape
    di = 2 * d
    H = cfg.num_heads
    dk = di // H

    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xh = xi.reshape(B, S, H, dk)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"]).astype(jnp.float32)
    k = jnp.einsum("bshd,hde->bshe", xh, params["wk"]).astype(jnp.float32)
    v = jnp.einsum("bshd,hde->bshe", xh, params["wv"]).astype(jnp.float32)
    gates = (xi @ params["gates"]).astype(jnp.float32) + params["gates_b"]
    li, lf_pre = jnp.split(gates, 2, axis=-1)  # (B, S, H) each
    lf = jax.nn.log_sigmoid(lf_pre)

    st = state if state is not None else init_mlstm_state(cfg, B)
    y, new_state = _mlstm_chunk(q, k, v, lf, li, chunk if state is None else 1, st)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y + params["skip"].astype(x.dtype) * xi
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if state is None:
        return out
    return out, new_state


# ===========================================================================
# sLSTM
# ===========================================================================
def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    f = int(d * 4 / 3)
    ks = jax.random.split(key, 5)
    return {
        "wx": dense_init(ks[0], (d, 4 * d), dtype),
        "wr": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
               / math.sqrt(dh)).astype(dtype),
        "bias": jnp.concatenate([
            jnp.zeros((d,), jnp.float32),          # i
            jnp.linspace(3.0, 6.0, d),             # f (high forget bias)
            jnp.zeros((2 * d,), jnp.float32),      # z, o
        ]),
        "ffn_up": dense_init(ks[2], (d, 2 * f), dtype),
        "ffn_down": dense_init(ks[3], (f, d), dtype),
        "norm": jnp.ones((d,), jnp.float32),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, d)
    n: jnp.ndarray  # (B, d)
    m: jnp.ndarray  # (B, d)
    h: jnp.ndarray  # (B, d)


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, m=z, h=z)


def _slstm_cell(params, cfg, xt, st: SLSTMState) -> tuple[SLSTMState, jnp.ndarray]:
    """One timestep. xt: (B, d) pre-projected gate inputs (B, 4d)."""
    B = xt.shape[0]
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    hr = st.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, params["wr"].astype(jnp.float32))
    rec = rec.reshape(B, 4 * d)
    # interleave per head: rec gives (4*dh per head) -> reorder to gate-major
    rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    pre = xt.astype(jnp.float32) + rec + params["bias"]
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_pre + st.m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_pre + st.m - m_new)
    z_g = jnp.tanh(z_pre)
    o_g = jax.nn.sigmoid(o_pre)
    c_new = f_g * st.c + i_g * z_g
    n_new = f_g * st.n + i_g
    h_new = o_g * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c=c_new, n=n_new, m=m_new, h=h_new), h_new


def apply_slstm(params, x, cfg, *, state: SLSTMState | None = None):
    """x: (B, S, d) -> (B, S, d) [, new state when decoding]."""
    B, S, d = x.shape
    xg = x @ params["wx"]  # (B, S, 4d)
    st = state if state is not None else init_slstm_state(cfg, B)

    def step(s, xt):
        s, h = _slstm_cell(params, cfg, xt, s)
        return s, h

    new_state, hs = jax.lax.scan(step, st, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)  # (B, S, d)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    up = y @ params["ffn_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ params["ffn_down"]
    if state is None:
        return out
    return out, new_state

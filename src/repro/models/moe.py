"""Mixture-of-Experts FFN with expert parallelism.

Design (DESIGN.md §5): experts are sharded over the ``model`` mesh axis
(2-D ``fsdp_tp`` additionally shards d_ff over ``data`` and all-gathers per
layer, FSDP-style).  Token dispatch is scatter-based (sort-free GShard-style
capacity buffers) inside ``shard_map``: every device routes its local tokens,
keeps the pairs destined to its local experts, and the final psum over the
``model`` axis combines disjoint expert contributions together with the
column-sharded shared-expert partials.  No dense (T, E, C) dispatch tensor is
ever materialized.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation, dense_init
from repro.utils import SHARD_MAP_CHECK_KW as _CHECK_KW
from repro.utils import shard_map

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg, dtype) -> dict:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 7)
    params = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.num_shared_experts > 0:
        fs = f * cfg.num_shared_experts
        params["shared_w_gate"] = dense_init(ks[4], (d, fs), dtype)
        params["shared_w_up"] = dense_init(ks[5], (d, fs), dtype)
        params["shared_w_down"] = dense_init(ks[6], (fs, d), dtype)
    return params


def capacity(tokens_local: int, cfg) -> int:
    c = math.ceil(tokens_local * cfg.experts_per_token / cfg.num_experts
                  * CAPACITY_FACTOR)
    return max(4, min(c, tokens_local))


def _moe_local(params, xt, cfg, e_local: int, e_offset, cap: int, act):
    """Route/dispatch/compute for the local expert slice.

    xt: (T, d) local tokens; returns (out (T, d) partial, aux loss scalar).
    """
    T, d = xt.shape
    k = cfg.experts_per_token
    E = cfg.num_experts

    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = vals.reshape(-1)

    le = flat_e - e_offset  # local expert index; OOB handled by mode=drop/fill
    in_range = (le >= 0) & (le < e_local)
    le_safe = jnp.where(in_range, le, e_local)  # e_local row is OOB for buffers
    oh = jax.nn.one_hot(le_safe, e_local + 1, dtype=jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(oh, axis=0), le_safe[:, None], axis=1)[:, 0] - 1

    buf = jnp.zeros((e_local, cap, d), xt.dtype)
    buf = buf.at[le_safe, rank].add(xt[flat_t], mode="drop")

    h = act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, params["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    vals_back = out_e.at[le_safe, rank].get(mode="fill", fill_value=0)  # (T*k, d)
    out = jnp.zeros((T, d), xt.dtype)
    out = out.at[flat_t].add((flat_w[:, None] * vals_back.astype(jnp.float32)
                              ).astype(xt.dtype))

    # Switch-style load-balance aux (computed on full router output).
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _shared_partial(params, xt, act):
    if "shared_w_gate" not in params:
        return 0.0
    h = act(xt @ params["shared_w_gate"]) * (xt @ params["shared_w_up"])
    return h @ params["shared_w_down"]


def apply_moe(params, x, cfg, mesh=None, batch_axes=("data",),
              fsdp_axes=("data",)):
    """x: (B, S, d) -> (y, aux).  Sharded path uses shard_map over mesh."""
    act = activation(cfg.act)
    B, S, d = x.shape

    if mesh is None:
        xt = x.reshape(B * S, d)
        cap = capacity(B * S, cfg)
        out, aux = _moe_local(params, xt, cfg, cfg.num_experts, 0, cap, act)
        out = out + _shared_partial(params, xt, act)
        return out.reshape(B, S, d), aux

    batch_axes = tuple(batch_axes)
    fsdp_axes = tuple(fsdp_axes)
    model_size = mesh.shape["model"]
    e_local = cfg.num_experts // model_size
    data_size = 1
    for a in batch_axes:
        data_size *= mesh.shape[a]
    tokens_local = (B // data_size) * S
    cap = capacity(tokens_local, cfg)
    two_d = cfg.param_sharding == "fsdp_tp"

    bspec = P(batch_axes if batch_axes else None, None, None)
    expert_spec = P("model", None, fsdp_axes) if two_d else P("model", None, None)
    expert_spec_dn = P("model", fsdp_axes, None) if two_d else P("model", None, None)
    shared_spec = {"shared_w_gate": P(None, "model"),
                   "shared_w_up": P(None, "model"),
                   "shared_w_down": P("model", None)}
    pspecs = {"router": P(None, None), "w_gate": expert_spec,
              "w_up": expert_spec, "w_down": expert_spec_dn}
    for name, sp in shared_spec.items():
        if name in params:
            pspecs[name] = sp

    @partial(shard_map, mesh=mesh, in_specs=(pspecs, bspec),
             out_specs=(bspec, P()), **_CHECK_KW)
    def sharded(prm, xl):
        bl, sl, _ = xl.shape
        xt = xl.reshape(bl * sl, d)
        m_idx = jax.lax.axis_index("model")
        if two_d:  # FSDP: all-gather the d_ff shards for this layer's use
            prm = dict(prm)
            prm["w_gate"] = jax.lax.all_gather(prm["w_gate"], fsdp_axes, axis=2, tiled=True)
            prm["w_up"] = jax.lax.all_gather(prm["w_up"], fsdp_axes, axis=2, tiled=True)
            prm["w_down"] = jax.lax.all_gather(prm["w_down"], fsdp_axes, axis=1, tiled=True)
        out, aux = _moe_local(prm, xt, cfg, e_local, m_idx * e_local, cap, act)
        out = out + _shared_partial(prm, xt, act)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, ("model",) + tuple(batch_axes))
        return out.reshape(bl, sl, d), aux

    return sharded(params, x)

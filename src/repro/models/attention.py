"""Attention: naive reference, chunked flash (custom_vjp), and decode paths.

``flash_attention`` is a pure-JAX online-softmax implementation (lax.scan
over query/key chunks) with a manual backward that recomputes per-block
scores — O(S) memory at 32k/512k sequence lengths where a naive softmax
would materialize S x S scores.  Supports causal masking, GQA and static
sliding windows.  The naive path is the test oracle.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_block(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(qc, kc) boolean mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


# ---------------------------------------------------------------------------
# reference implementation (oracle)
# ---------------------------------------------------------------------------
def naive_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  fp32 softmax."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bqngh,bcnh->bngqc", qh, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = _mask_block(q_pos, k_pos, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqc,bcnh->bqngh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention: forward
# ---------------------------------------------------------------------------
def _n_win(window, k_chunk, nk):
    """number of k chunks a q chunk can see under a sliding window."""
    return min(nk, -(-window // k_chunk) + 1)


def _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk, window_slice=False):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = 1.0 / math.sqrt(hd)

    qc = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, k_chunk, KV, hd)
    vc = v.reshape(B, nk, k_chunk, KV, hd)
    sliced = window_slice and window is not None and causal and nq == nk

    def q_step(_, qi):
        qb, q_idx = qi  # (B, qc, KV, G, hd)
        q_pos = q_idx * q_chunk + jnp.arange(q_chunk)
        qb32 = qb.astype(jnp.float32) * scale

        def block(carry, kb, vb, k_idx, valid=True):
            m_run, l_run, acc = carry
            k_pos = k_idx * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqngh,bcnh->bngqc", qb32, kb.astype(jnp.float32))
            mask = (q_pos[:, None] >= k_pos[None, :]) if causal else jnp.ones(
                (q_chunk, k_chunk), bool)
            if window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            mask &= valid  # sliced iters clipped to chunk 0 must not re-count
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bngqc,bcnh->bngqh", p, vb.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc)

        def k_step(carry, ki):
            kb, vb, k_idx = ki
            return block(carry, kb, vb, k_idx), None

        def k_step_sliced(carry, t):
            # only the in-window chunks: k_idx in [q_idx - n_win + 1, q_idx];
            # clipped duplicates are invalidated via the mask
            raw = q_idx - (nwin - 1) + t
            k_idx = jnp.clip(raw, 0, nk - 1)
            kb = jax.lax.dynamic_index_in_dim(kc, k_idx, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, k_idx, 1, keepdims=False)
            return block(carry, kb, vb, k_idx, valid=(raw >= 0)), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        if sliced:
            nwin = _n_win(window, k_chunk, nk)
            (m, l, acc), _ = jax.lax.scan(k_step_sliced, (m0, l0, a0),
                                          jnp.arange(nwin))
        else:
            (m, l, acc), _ = jax.lax.scan(
                k_step, (m0, l0, a0), (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
                                       jnp.arange(nk)))
        l_safe = jnp.maximum(l, 1e-30)
        o = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return None, (o, lse)

    _, (o, lse) = jax.lax.scan(q_step, None, (qc.swapaxes(0, 1), jnp.arange(nq)))
    # o: (nq, B, KV, G, qc, hd) -> (B, Sq, H, hd)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd).astype(q.dtype)
    # lse: (nq, B, KV, G, qc) -> (B, KV, G, Sq)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Sq)
    return o, lse


# ---------------------------------------------------------------------------
# flash attention: backward (recompute scores per block)
# ---------------------------------------------------------------------------
def _flash_bwd_impl(q, k, v, o, lse, do, causal, window, q_chunk, k_chunk,
                    window_slice=False):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = 1.0 / math.sqrt(hd)

    qc = q.reshape(B, nq, q_chunk, KV, G, hd).swapaxes(0, 1)
    oc = o.reshape(B, nq, q_chunk, KV, G, hd).swapaxes(0, 1)
    doc = do.reshape(B, nq, q_chunk, KV, G, hd).swapaxes(0, 1)
    lsec = lse.reshape(B, KV, G, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    kc = k.reshape(B, nk, k_chunk, KV, hd)
    vc = v.reshape(B, nk, k_chunk, KV, hd)

    # delta = rowsum(do * o): (nq, B, KV, G, qc)
    delta = jnp.einsum("nbqkgh,nbqkgh->nbkgq",
                       doc.astype(jnp.float32), oc.astype(jnp.float32))
    sliced = window_slice and window is not None and causal and nq == nk
    nwin = _n_win(window, k_chunk, nk) if sliced else nk

    def q_step(carry, qi):
        dk_all, dv_all = carry
        qb, dob, lseb, deltab, q_idx = qi
        q_pos = q_idx * q_chunk + jnp.arange(q_chunk)
        qb32 = qb.astype(jnp.float32) * scale
        dob32 = dob.astype(jnp.float32)

        def k_step(carry2, ki):
            dq_acc, dk_all, dv_all = carry2
            if sliced:
                raw = q_idx - (nwin - 1) + ki
                k_idx = jnp.clip(raw, 0, nk - 1)
                valid = raw >= 0
            else:
                k_idx = ki
                valid = True
            kb = jax.lax.dynamic_index_in_dim(kc, k_idx, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, k_idx, axis=1, keepdims=False)
            k_pos = k_idx * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqngh,bcnh->bngqc", qb32, kb.astype(jnp.float32))
            mask = (q_pos[:, None] >= k_pos[None, :]) if causal else jnp.ones(
                (q_chunk, k_chunk), bool)
            if window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            mask &= valid
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])  # (B, KV, G, qc, kc)
            dp = jnp.einsum("bqngh,bcnh->bngqc", dob32, vb.astype(jnp.float32))
            ds = p * (dp - deltab[..., None])  # fp32
            dq_acc = dq_acc + jnp.einsum("bngqc,bcnh->bqngh", ds,
                                         kb.astype(jnp.float32)) * scale
            dk_b = jnp.einsum("bngqc,bqngh->bcnh", ds, qb32)
            dv_b = jnp.einsum("bngqc,bqngh->bcnh", p, dob32)
            dk_all = jax.lax.dynamic_update_index_in_dim(
                dk_all, jax.lax.dynamic_index_in_dim(dk_all, k_idx, 1, False) + dk_b,
                k_idx, 1)
            dv_all = jax.lax.dynamic_update_index_in_dim(
                dv_all, jax.lax.dynamic_index_in_dim(dv_all, k_idx, 1, False) + dv_b,
                k_idx, 1)
            return (dq_acc, dk_all, dv_all), None

        dq0 = jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32)
        (dq, dk_all, dv_all), _ = jax.lax.scan(
            k_step, (dq0, dk_all, dv_all), jnp.arange(nwin if sliced else nk))
        return (dk_all, dv_all), dq

    dk0 = jnp.zeros((B, nk, k_chunk, KV, hd), jnp.float32)
    dv0 = jnp.zeros((B, nk, k_chunk, KV, hd), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        q_step, (dk0, dv0), (qc, doc, lsec, delta, jnp.arange(nq)))
    dq = dq.swapaxes(0, 1).reshape(B, Sq, H, hd).astype(q.dtype)
    dk = dk.reshape(B, Sk, KV, hd).astype(k.dtype)
    dv = dv.reshape(B, Sk, KV, hd).astype(v.dtype)
    # note: dk_b above used scaled q; ds already has the 1/sqrt(hd) folded via
    # qb32, so dk is correct as-is.
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, q_chunk=512,
                    k_chunk=512, window_slice=False):
    o, _ = _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk, window_slice)
    return o


def _flash_vjp_fwd(q, k, v, causal, window, q_chunk, k_chunk, window_slice):
    o, lse = _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk, window_slice)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(causal, window, q_chunk, k_chunk, window_slice, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, o, lse, do, causal, window,
                                 q_chunk, k_chunk, window_slice)
    return dq, dk, dv


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def attention_any(q, k, v, *, causal=True, window=None, q_chunk=512,
                  k_chunk=512, window_slice=False):
    """Dispatch: chunked flash when divisible and long enough, else naive."""
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq % q_chunk == 0 and Sk % k_chunk == 0 and Sq > q_chunk:
        return flash_attention(q, k, v, causal, window, q_chunk, k_chunk,
                               window_slice)
    return naive_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# decode: one query against a (possibly ring) KV cache
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, cache_pos, cur_pos, *, window=None):
    """q: (B, 1, H, hd); caches: (B, Smax, KV, hd);
    cache_pos: (Smax,) or (B, Smax) absolute position of each slot (-1 empty);
    cur_pos: scalar current absolute position.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bngh,bcnh->bngc", qh, k_cache.astype(jnp.float32))
    pos = cache_pos if cache_pos.ndim == 2 else cache_pos[None, :]
    valid = (pos >= 0) & (pos <= cur_pos)
    if window is not None:
        valid &= pos > (cur_pos - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngc,bcnh->bngh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def paged_decode_attention(q, k_flat, v_flat, tables, positions, page_size):
    """Single-query attention over a paged KV pool (unfused reference path).

    q: (S, 1, H, hd) — one query per *slot*; k_flat, v_flat:
    (n_pages * page_size, KV, hd) — the shared block pool, flattened, with
    this step's k/v already written; tables: (S, maxp) int32 per-slot page
    table; positions: (S,) absolute position per slot.

    Each slot's pages are gathered in **logical** order (so the result is
    invariant to the physical page permutation) and attended with exactly
    the ops :func:`decode_attention` uses — fp32 softmax, same einsum
    orders — which keeps the paged path bitwise-equal to the contiguous
    ring on a single-sequence stream (validity is ``logical index <=
    position``; full attention only — sliding windows keep the ring path).
    """
    S, _, H, hd = q.shape
    KV = k_flat.shape[1]
    G = H // KV
    maxp = tables.shape[1]
    qh = q.reshape(S, KV, G, hd).astype(jnp.float32) / math.sqrt(hd)
    gidx = ((tables * page_size)[:, :, None]
            + jnp.arange(page_size)[None, None]).reshape(S, maxp * page_size)
    kg = k_flat[gidx]                                 # (S, maxp*ps, KV, hd)
    vg = v_flat[gidx]
    s = jnp.einsum("bngh,bcnh->bngc", qh, kg.astype(jnp.float32))
    valid = jnp.arange(maxp * page_size)[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngc,bcnh->bngh", p, vg.astype(jnp.float32))
    return o.reshape(S, 1, H, hd).astype(q.dtype)

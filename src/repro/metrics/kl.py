"""KL divergence estimators (the paper's second convergence metric)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gaussian_kl(mu1, cov1, mu2, cov2) -> jnp.ndarray:
    """KL(N(mu1,cov1) || N(mu2,cov2)) closed form."""
    mu1, mu2 = jnp.atleast_1d(mu1), jnp.atleast_1d(mu2)
    cov1, cov2 = jnp.atleast_2d(cov1), jnp.atleast_2d(cov2)
    d = mu1.shape[0]
    c2inv = jnp.linalg.inv(cov2)
    diff = mu2 - mu1
    term_tr = jnp.trace(c2inv @ cov1)
    term_quad = diff @ c2inv @ diff
    _, ld1 = jnp.linalg.slogdet(cov1)
    _, ld2 = jnp.linalg.slogdet(cov2)
    return 0.5 * (term_tr + term_quad - d + ld2 - ld1)


def kl_samples_to_gaussian(samples: jnp.ndarray, mu, cov) -> jnp.ndarray:
    """Moment-matched KL of an iterate cloud to a Gaussian target."""
    m = jnp.mean(samples, axis=0)
    c = jnp.atleast_2d(jnp.cov(samples, rowvar=False))
    c = c + 1e-9 * jnp.eye(c.shape[0])
    return gaussian_kl(m, c, jnp.atleast_1d(mu), jnp.atleast_2d(cov))


def knn_kl_estimate(x: jnp.ndarray, y: jnp.ndarray, k: int = 1) -> jnp.ndarray:
    """Nonparametric k-NN KL(P||Q) estimator (Wang et al. 2009) between
    samples x ~ P (n, d) and y ~ Q (m, d)."""
    n, d = x.shape
    m = y.shape[0]

    def kth_dist(a, b, k, skip_self):
        d2 = jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
        if skip_self:
            d2 = d2 + jnp.where(jnp.eye(a.shape[0], b.shape[0], dtype=bool), jnp.inf, 0.0)
        vals = -jax.lax.top_k(-d2, k)[0][:, -1]
        return jnp.sqrt(jnp.clip(vals, 1e-30, None))

    rho = kth_dist(x, x, k, skip_self=True)
    nu = kth_dist(x, y, k, skip_self=False)
    return d * jnp.mean(jnp.log(nu / rho)) + jnp.log(m / (n - 1.0))

"""Wasserstein-2 distances in pure JAX (offline stand-in for the POT library
the paper uses [5]).

Three estimators, cross-validated in tests:

- ``w2_empirical_1d``  exact for 1-D empirical measures (sorted quantiles).
- ``gaussian_w2``      closed form between Gaussians (Bures metric).
- ``sinkhorn_w2``      entropy-regularized OT between point clouds, debiased;
                       converges to exact W2 as eps -> 0.
- ``w2_to_gaussian``   moment-matched upper-bound-style surrogate used for
                       the paper's figures: fits a Gaussian to the iterate
                       cloud and takes the closed form against the target
                       posterior Gaussian (what the paper effectively tracks
                       around x*).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def w2_empirical_1d(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Exact W2 between two equal-size 1-D samples."""
    xs = jnp.sort(x.ravel())
    ys = jnp.sort(y.ravel())
    return jnp.sqrt(jnp.mean((xs - ys) ** 2))


def _sqrtm_psd(a: jnp.ndarray) -> jnp.ndarray:
    """Symmetric PSD matrix square root via eigh."""
    w, v = jnp.linalg.eigh(a)
    w = jnp.clip(w, 0.0, None)
    return (v * jnp.sqrt(w)) @ v.T


def gaussian_w2(mu1, cov1, mu2, cov2) -> jnp.ndarray:
    """Bures–Wasserstein: ||mu1-mu2||^2 + tr(C1 + C2 - 2 (C2^1/2 C1 C2^1/2)^1/2)."""
    mu1, mu2 = jnp.atleast_1d(mu1), jnp.atleast_1d(mu2)
    cov1, cov2 = jnp.atleast_2d(cov1), jnp.atleast_2d(cov2)
    s2 = _sqrtm_psd(cov2)
    cross = _sqrtm_psd(s2 @ cov1 @ s2)
    t = jnp.trace(cov1) + jnp.trace(cov2) - 2.0 * jnp.trace(cross)
    return jnp.sqrt(jnp.clip(jnp.sum((mu1 - mu2) ** 2) + t, 0.0, None))


def w2_to_gaussian(samples: jnp.ndarray, mu: jnp.ndarray, cov: jnp.ndarray) -> jnp.ndarray:
    """Moment-matched W2 of an iterate cloud (n, d) to a Gaussian target."""
    m = jnp.mean(samples, axis=0)
    c = jnp.cov(samples, rowvar=False)
    c = jnp.atleast_2d(c)
    return gaussian_w2(m, c, mu, jnp.atleast_2d(cov))


@partial(jax.jit, static_argnames=("num_iters",))
def _sinkhorn_cost(x, y, eps, num_iters):
    n, m = x.shape[0], y.shape[0]
    c = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    log_a = jnp.full((n,), -jnp.log(n))
    log_b = jnp.full((m,), -jnp.log(m))
    f = jnp.zeros((n,))
    g = jnp.zeros((m,))

    def body(_, fg):
        f, g = fg
        f = -eps * jax.scipy.special.logsumexp((g[None, :] - c) / eps + log_b[None, :], axis=1)
        g = -eps * jax.scipy.special.logsumexp((f[:, None] - c) / eps + log_a[:, None], axis=0)
        return f, g

    f, g = jax.lax.fori_loop(0, num_iters, body, (f, g))
    log_p = (f[:, None] + g[None, :] - c) / eps + log_a[:, None] + log_b[None, :]
    return jnp.sum(jnp.exp(log_p) * c)


def sinkhorn_w2(x: jnp.ndarray, y: jnp.ndarray, eps: float = 0.05,
                num_iters: int = 200, debias: bool = True) -> jnp.ndarray:
    """Entropy-regularized W2 between point clouds x:(n,d), y:(m,d).

    With ``debias`` uses the Sinkhorn divergence S = OT(x,y) - (OT(x,x) +
    OT(y,y))/2, which removes the entropic bias and is ~exact for moderate eps.
    """
    cost_xy = _sinkhorn_cost(x, y, eps, num_iters)
    if not debias:
        return jnp.sqrt(jnp.clip(cost_xy, 0.0, None))
    cost_xx = _sinkhorn_cost(x, x, eps, num_iters)
    cost_yy = _sinkhorn_cost(y, y, eps, num_iters)
    return jnp.sqrt(jnp.clip(cost_xy - 0.5 * (cost_xx + cost_yy), 0.0, None))

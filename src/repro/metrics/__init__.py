from repro.metrics.kl import gaussian_kl, kl_samples_to_gaussian, knn_kl_estimate  # noqa: F401
from repro.metrics.wasserstein import (  # noqa: F401
    gaussian_w2,
    sinkhorn_w2,
    w2_empirical_1d,
    w2_to_gaussian,
)

"""repro — Stochastic Gradient Langevin with Delayed Gradients (async-SGLD).

A production-grade JAX framework reproducing Kungurtsev, Chatterjee, Alistarh
(2020): delayed-gradient SGLD (Sync / W-Con / W-Icon) as a first-class
distributed sampler, plus the substrate (model zoo, data pipeline,
checkpointing, launcher, multi-pod sharding) needed to run it at scale.
"""

__version__ = "0.1.0"

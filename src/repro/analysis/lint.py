"""jaxlint — an AST linter for the repo's JAX/Pallas invariants.

The correctness of the delayed-gradient executor and the serving stack
rests on invariants no off-the-shelf linter knows about: staleness must
come from the :class:`~repro.core.delay_model.DelayTrace`, not from a
silent retrace; donated buffers must die at the call; every noise draw
must consume a fresh key; scan bodies must stay on device; in-place Pallas
kernels must tell XLA they alias.  Each rule below encodes one of those
invariants as a syntactic pattern tight enough to run clean over the real
tree (``scripts/jaxlint.py src benchmarks examples`` is a CI gate) while
firing on the seeded violations in ``tests/fixtures/jaxlint``:

========  ==============================================================
JL001     retrace hazard: a Python-scalar argument (``int()``, ``len()``,
          ``.shape[...]``) derived from a loop-varying value passed to a
          jitted callable inside a loop — every iteration traces a new
          program.
JL002     use-after-donation: a buffer passed at a ``donate_argnums``
          position of a jitted callable is read again afterwards in the
          caller — the buffer was handed to XLA and may already be
          overwritten.
JL003     RNG key reuse: the same PRNG key is consumed by two
          ``jax.random`` draws without an intervening ``split`` /
          ``fold_in`` rebinding — the draws are silently identical.
JL004     host sync in traced code: ``.item()`` / ``.tolist()`` /
          ``np.asarray`` / scalar coercions / data-dependent ``if`` inside
          a jitted function or a ``lax.scan``-family body — a device sync
          (or tracer leak) on the hot path.
JL005     in-place Pallas kernel without ``input_output_aliases``: a
          ``pallas_call`` whose output mirrors an input's shape and dtype
          updates that buffer in place; without the alias declaration XLA
          double-buffers it through HBM.
JL006     ``shard_map``/``NamedSharding`` spec references a mesh axis the
          statically visible mesh does not define — shards silently
          replicate (or the program fails only at scale).
========  ==============================================================

False positives are suppressed inline::

    x = jitted(int(n))  # jaxlint: disable=JL001
    # jaxlint: disable-file=JL003   (anywhere in the file, whole file)

The linter is pure stdlib ``ast``  — no imports of the linted code — so it
runs in the lint CI job without a JAX install.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional, Sequence

__all__ = ["Finding", "RULES", "lint_source", "lint_file", "lint_paths"]

RULES = {
    "JL001": "retrace hazard: loop-varying Python scalar in a jitted call",
    "JL002": "use-after-donation: donated buffer read after the call",
    "JL003": "RNG key reuse: key consumed twice without split/fold_in",
    "JL004": "host sync inside traced code",
    "JL005": "in-place Pallas kernel missing input_output_aliases",
    "JL006": "shard_map/sharding spec axis not in the mesh",
}

_PRAGMA = re.compile(r"#\s*jaxlint:\s*(disable(?:-file)?)\s*=\s*"
                     r"([A-Za-z0-9_,\s]+)")

#: jax.random.* callees that *consume* a key (first positional argument)
_KEY_ROTATORS = {"split", "fold_in", "clone", "key_data", "wrap_key_data",
                 "key_impl", "PRNGKey", "key"}
#: scalar coercions that force a host sync when applied to a traced value
_SCALAR_COERCIONS = {"int", "float", "bool", "complex"}
#: (callee, body-argument positions) for the scan family
_TRACED_BODY_POS = {
    "jax.lax.scan": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": None,  # every arg from 1 on is a branch
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}{tag}"


# --------------------------------------------------------------------------
# pragma collection
# --------------------------------------------------------------------------

def _pragmas(source: str):
    """-> (per-line {lineno: set of rules}, file-wide set of rules).

    ``# jaxlint: disable=JL001[,JL002]`` suppresses on its physical line;
    ``# jaxlint: disable-file=JL001`` (or ``=all``) suppresses file-wide.
    """
    per_line: dict[int, set] = {}
    file_wide: set = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA.search(tok.string)
            if not m:
                continue
            rules = {r.strip().upper() for r in m.group(2).split(",")
                     if r.strip()}
            if "ALL" in rules:
                rules = set(RULES)
            if m.group(1) == "disable-file":
                file_wide |= rules
            else:
                per_line.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass
    return per_line, file_wide


# --------------------------------------------------------------------------
# small AST helpers
# --------------------------------------------------------------------------

def _dotted(node: ast.AST, aliases: dict) -> Optional[str]:
    """Resolve ``jr.normal`` / ``jax.random.normal`` / ``normal`` to a full
    dotted path using the file's import aliases; None when not a name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    parts.reverse()
    head = aliases.get(parts[0], parts[0])
    return ".".join([head] + parts[1:])


def _import_aliases(tree: ast.Module) -> dict:
    """{local name: dotted path} for every import in the module."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _assigned_names(node: ast.AST) -> set:
    """Names bound anywhere under ``node`` (assign/aug/ann/for/with/walrus)."""
    out: set = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out


def _call_name(call: ast.Call, aliases: dict) -> Optional[str]:
    return _dotted(call.func, aliases)


def _is_jit_expr(node: ast.AST, aliases: dict) -> bool:
    """True for ``jax.jit``, ``jit``, ``partial(jax.jit, ...)``."""
    path = _dotted(node, aliases)
    if path in ("jax.jit", "jax.pmap"):
        return True
    if isinstance(node, ast.Call):
        head = _dotted(node.func, aliases)
        if head in ("functools.partial", "partial") and node.args:
            return _is_jit_expr(node.args[0], aliases)
        return _is_jit_expr(node.func, aliases)
    return False


def _const_int_tuple(node: ast.AST) -> Optional[tuple]:
    """A constant int / tuple-of-ints expression, else None."""
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return None
    if isinstance(val, int):
        return (val,)
    if isinstance(val, (tuple, list)) and all(isinstance(v, int)
                                              for v in val):
        return tuple(val)
    return None


def _last_attr(node: ast.AST) -> Optional[str]:
    """``self._run`` -> ``_run``; ``name`` -> ``name``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


# --------------------------------------------------------------------------
# the linter
# --------------------------------------------------------------------------

class _FileLinter:
    def __init__(self, tree: ast.Module, source: str, path: str):
        self.tree = tree
        self.path = path
        self.aliases = _import_aliases(tree)
        self.findings: list[Finding] = []
        self.per_line, self.file_wide = _pragmas(source)
        # name -> donated positional indices, for jit-wrapped callables
        self.donated: dict[str, tuple] = {}
        # function defs considered traced (jitted / scan-family bodies)
        self.traced_funcs: set = set()
        self.jitted_names: set = set()

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        suppressed = (rule in self.file_wide
                      or rule in self.per_line.get(line, ()))
        self.findings.append(Finding(
            rule=rule, path=self.path, line=line,
            col=getattr(node, "col_offset", 0) + 1, message=message,
            suppressed=suppressed))

    # -- pass 1: collect jitted / donated / traced functions ----------------
    def collect(self) -> None:
        defs: dict[str, list] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
                for dec in node.decorator_list:
                    if _is_jit_expr(dec, self.aliases):
                        self.traced_funcs.add(node)
                        self.jitted_names.add(node.name)
                        donate = self._donate_argnums(dec)
                        if donate:
                            self.donated[node.name] = donate

        def mark(name_node):
            name = _last_attr(name_node)
            for d in defs.get(name or "", ()):
                self.traced_funcs.add(d)

        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            path = _call_name(node, self.aliases)
            if path in ("jax.jit", "jax.pmap") and node.args:
                mark(node.args[0])
                donate = self._donate_argnums(node)
                target = self._assign_target(node)
                if target:
                    self.jitted_names.add(target)
                    if donate:
                        self.donated[target] = donate
            elif path is not None and (path.endswith("shard_map")
                                       or path.endswith("checkpoint")):
                if node.args:
                    mark(node.args[0])
            elif path is not None:
                tail = "jax.lax." + path.rsplit(".", 1)[-1]
                if tail in _TRACED_BODY_POS and path.rsplit(".", 1)[-1] in (
                        "scan", "while_loop", "fori_loop", "cond", "switch"):
                    pos = _TRACED_BODY_POS[tail]
                    idxs = (range(1, len(node.args)) if pos is None else pos)
                    for i in idxs:
                        if i < len(node.args):
                            mark(node.args[i])
        # nested defs inside a traced function are traced too
        for fn in list(self.traced_funcs):
            for sub in ast.walk(fn):
                if (isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and sub is not fn):
                    self.traced_funcs.add(sub)

    def _donate_argnums(self, call: ast.AST) -> tuple:
        if not isinstance(call, ast.Call):
            return ()
        for kw in call.keywords:
            if kw.arg in ("donate_argnums", "donate_argnames"):
                val = _const_int_tuple(kw.value)
                return val or ()
        return ()

    def _assign_target(self, call: ast.Call) -> Optional[str]:
        """The name (or trailing attribute) a ``x = jax.jit(...)`` binds."""
        parent = getattr(call, "_jaxlint_parent", None)
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1:
            return _last_attr(parent.targets[0])
        return None

    # -- driving -------------------------------------------------------------
    def run(self) -> list[Finding]:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._jaxlint_parent = node
        self.collect()
        self.check_jl001()
        self.check_jl002()
        self.check_jl003()
        self.check_jl004()
        self.check_jl005()
        self.check_jl006()
        deduped, seen = [], set()
        for f in self.findings:
            key = (f.rule, f.line, f.col, f.message)
            if key not in seen:
                seen.add(key)
                deduped.append(f)
        deduped.sort(key=lambda f: (f.line, f.col, f.rule))
        self.findings = deduped
        return self.findings

    # -- JL001: retrace hazard ------------------------------------------------
    def check_jl001(self) -> None:
        for loop in ast.walk(self.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            varying = _assigned_names(loop)
            if isinstance(loop, ast.For):
                varying |= _names_in(loop.target)
            for call in ast.walk(loop):
                if not isinstance(call, ast.Call):
                    continue
                callee = _last_attr(call.func)
                if callee not in self.jitted_names:
                    continue
                for arg in list(call.args) + [k.value for k in call.keywords]:
                    if self._scalarish(arg, varying):
                        self.emit(
                            "JL001", call,
                            f"jitted `{callee}` called in a loop with a "
                            "loop-varying Python scalar argument — every "
                            "distinct value compiles a new program; pass a "
                            "device array or bucket the value")
                        break

    def _scalarish(self, node: ast.AST, varying: set) -> bool:
        """A Python-scalar expression whose value changes across the loop:
        int()/len()/... coercions, ``.shape`` accesses, or arithmetic on a
        loop-varying name."""
        if isinstance(node, ast.Call):
            head = _dotted(node.func, self.aliases)
            if head in (_SCALAR_COERCIONS | {"len", "round"}):
                return bool(_names_in(node) & varying)
        if isinstance(node, (ast.Subscript, ast.Attribute)):
            src = ast.unparse(node)
            if (".shape" in src or ".size" in src or ".ndim" in src):
                return bool(_names_in(node) & varying)
        if isinstance(node, ast.BinOp):
            return (self._scalarish(node.left, varying)
                    or self._scalarish(node.right, varying))
        return False

    # -- JL002: use-after-donation ---------------------------------------------
    def check_jl002(self) -> None:
        for fn in ast.walk(self.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stmts = self._flat_statements(fn)
            for i, stmt in enumerate(stmts):
                for call in self._own_calls(stmt):
                    callee = _last_attr(call.func)
                    donate = self.donated.get(callee or "")
                    if not donate:
                        continue
                    for pos in donate:
                        if pos >= len(call.args):
                            continue
                        arg = call.args[pos]
                        if not isinstance(arg, ast.Name):
                            continue
                        self._flag_reads_after(stmts, i, stmt, arg.id,
                                               callee)

    def _own_calls(self, stmt) -> list:
        """Calls belonging to ``stmt`` itself, not to statements nested in
        its body (those appear later in the flattened list and would be
        processed twice)."""
        out = []

        def visit(node):
            for name, value in ast.iter_fields(node):
                if isinstance(value, list) and value and isinstance(
                        value[0], ast.stmt):
                    continue  # a nested statement list: not ours
                for sub in (value if isinstance(value, list) else [value]):
                    if isinstance(sub, ast.AST):
                        if isinstance(sub, ast.Call):
                            out.append(sub)
                        visit(sub)
        visit(stmt)
        return out

    def _flat_statements(self, fn) -> list:
        """The function's statements in source order (branch bodies
        flattened; nested defs excluded — they are separate scopes)."""
        out = []

        def visit(body):
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue
                out.append(stmt)
                for _, value in ast.iter_fields(stmt):
                    if isinstance(value, list) and value and \
                            isinstance(value[0], ast.stmt):
                        visit(value)
        visit(fn.body)
        return out

    def _flag_reads_after(self, stmts, idx, call_stmt, name, callee):
        # the donating statement itself may rebind the name via its targets
        if isinstance(call_stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (call_stmt.targets
                       if isinstance(call_stmt, ast.Assign)
                       else [call_stmt.target])
            if any(name in _names_in(t) for t in targets):
                return
        for stmt in stmts[idx + 1:]:
            # a store to the name kills the tracking...
            for sub in ast.walk(stmt):
                if (isinstance(sub, ast.Name) and sub.id == name
                        and isinstance(sub.ctx, ast.Load)):
                    self.emit(
                        "JL002", sub,
                        f"`{name}` was donated to `{callee}` "
                        f"(donate_argnums) at line {call_stmt.lineno} and "
                        "read again here — the buffer may already be "
                        "overwritten; copy it before the call or stop "
                        "donating")
                    return
            if name in _assigned_names(stmt):
                return

    # -- JL003: RNG key reuse ---------------------------------------------------
    def check_jl003(self) -> None:
        funcs = [n for n in ast.walk(self.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        scopes = funcs + [self.tree]
        for scope in scopes:
            self._check_key_reuse(scope)

    def _check_key_reuse(self, scope) -> None:
        # (lineno, kind, name): kind is 'draw' | 'rebind'
        events: list = []
        own_defs = {n for n in ast.walk(scope)
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and n is not scope}
        nested = set()
        for d in own_defs:
            for sub in ast.walk(d):
                nested.add(sub)
        for node in ast.walk(scope):
            if node in nested or node is scope and not isinstance(
                    node, ast.Module):
                pass
            if node in nested:
                continue
            if isinstance(node, ast.Call):
                path = _call_name(node, self.aliases) or ""
                if path.startswith("jax.random."):
                    fn = path.rsplit(".", 1)[-1]
                    if fn in _KEY_ROTATORS or not node.args:
                        continue
                    key = node.args[0]
                    if isinstance(key, ast.Name):
                        events.append((node.lineno, "draw", key.id, node))
            elif isinstance(node, ast.Name) and isinstance(node.ctx,
                                                           ast.Store):
                events.append((node.lineno, "rebind", node.id, node))
        events.sort(key=lambda e: e[0])
        live_draw: dict[str, int] = {}
        for lineno, kind, name, node in events:
            if kind == "rebind":
                live_draw.pop(name, None)
            elif name in live_draw:
                self.emit(
                    "JL003", node,
                    f"key `{name}` already consumed by a jax.random draw at "
                    f"line {live_draw[name]} — the two draws are identical; "
                    "split or fold_in between them")
            else:
                live_draw[name] = lineno

    # -- JL004: host sync in traced code -----------------------------------------
    def check_jl004(self) -> None:
        seen: set = set()
        for fn in self.traced_funcs:
            scan_params = self._scan_body_params(fn)
            for node in ast.walk(fn):
                if id(node) in seen:
                    continue
                if isinstance(node, ast.Call):
                    msg = self._host_sync_call(node)
                    if msg:
                        seen.add(id(node))
                        self.emit("JL004", node, msg)
                elif isinstance(node, (ast.If, ast.While)) and scan_params:
                    if _names_in(node.test) & scan_params:
                        seen.add(id(node))
                        self.emit(
                            "JL004", node,
                            "`if`/`while` on a value derived from the "
                            "traced body's arguments — Python control flow "
                            "cannot branch on a tracer; use lax.cond / "
                            "jnp.where")

    def _scan_body_params(self, fn) -> set:
        """Params of a scan-family body function, plus names unpacked from
        them at the top of the body (the carry tuple)."""
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return set()
        # only scan bodies get the data-dependent-`if` check: jit functions
        # routinely branch on static (non-array) arguments
        if not self._is_scan_body(fn):
            return set()
        params = {a.arg for a in fn.args.args} - {"self"}
        for stmt in fn.body:
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.value, ast.Name)
                    and stmt.value.id in params):
                params |= _names_in(stmt.targets[0])
        return params

    def _is_scan_body(self, fn) -> bool:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            path = _call_name(node, self.aliases) or ""
            leaf = path.rsplit(".", 1)[-1]
            if leaf not in ("scan", "while_loop", "fori_loop", "cond",
                            "switch"):
                continue
            for arg in node.args:
                if _last_attr(arg) == fn.name:
                    return True
        return False

    def _host_sync_call(self, call: ast.Call) -> Optional[str]:
        if isinstance(call.func, ast.Attribute) and call.func.attr in (
                "item", "tolist", "__array__"):
            return (f"`.{call.func.attr}()` inside traced code forces a "
                    "device->host sync (or fails on a tracer); keep the "
                    "value on device")
        path = _dotted(call.func, self.aliases) or ""
        head = path.split(".", 1)[0]
        if head in ("numpy", "np") and path.rsplit(".", 1)[-1] in (
                "asarray", "array", "copy"):
            if call.args and not isinstance(call.args[0], ast.Constant):
                return (f"`{path.rsplit('.', 1)[-1]}` from numpy inside "
                        "traced code materializes on host — use jnp, or "
                        "move this to the host driver")
        if path in _SCALAR_COERCIONS and call.args:
            arg = call.args[0]
            src = ast.unparse(arg)
            static_shape = (".shape" in src or ".ndim" in src
                            or "len(" in src or isinstance(arg, ast.Constant))
            if not static_shape:
                return (f"`{path}()` on a traced value forces a host sync "
                        "(ConcretizationTypeError under jit); keep it as an "
                        "array or mark the argument static")
        return None

    # -- JL005: pallas in-place without aliases -----------------------------------
    def check_jl005(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            path = _call_name(node, self.aliases) or ""
            if path.rsplit(".", 1)[-1] != "pallas_call":
                continue
            if any(kw.arg == "input_output_aliases" for kw in node.keywords):
                continue
            out_shape = next((kw.value for kw in node.keywords
                              if kw.arg == "out_shape"), None)
            if out_shape is None:
                continue
            operands = self._pallas_operands(node)
            shape_unpacks = self._shape_unpacks(node)
            entries = (out_shape.elts if isinstance(
                out_shape, (ast.List, ast.Tuple)) else [out_shape])
            for entry in entries:
                src_name = self._mirrored_input(entry, operands,
                                                shape_unpacks)
                if src_name:
                    self.emit(
                        "JL005", node,
                        f"pallas_call output mirrors input `{src_name}` "
                        "(same shape and dtype) — an in-place update must "
                        "declare input_output_aliases so XLA reuses the "
                        "buffer instead of double-buffering it through HBM")
                    return

    def _pallas_operands(self, call: ast.Call) -> set:
        """Names passed to the callable ``pallas_call(...)(...)`` returns,
        or (fallback) the enclosing function's parameters."""
        parent = getattr(call, "_jaxlint_parent", None)
        if isinstance(parent, ast.Call) and parent.func is call:
            return {a.id for a in parent.args if isinstance(a, ast.Name)}
        node = call
        while node is not None and not isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            node = getattr(node, "_jaxlint_parent", None)
        if node is not None:
            return {a.arg for a in node.args.args} - {"self"}
        return set()

    def _shape_unpacks(self, call: ast.Call) -> dict:
        """{(name_i, name_j, ...): source} for ``a, b = x.shape`` unpacks in
        the enclosing function."""
        node = call
        while node is not None and not isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            node = getattr(node, "_jaxlint_parent", None)
        out: dict = {}
        if node is None:
            return out
        for stmt in ast.walk(node):
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1):
                continue
            tgt, val = stmt.targets[0], stmt.value
            if (isinstance(val, ast.Attribute) and val.attr == "shape"
                    and isinstance(val.value, ast.Name)
                    and isinstance(tgt, ast.Tuple)
                    and all(isinstance(e, ast.Name) for e in tgt.elts)):
                out[tuple(e.id for e in tgt.elts)] = val.value.id
        return out

    def _mirrored_input(self, entry: ast.AST, operands: set,
                        shape_unpacks: dict) -> Optional[str]:
        """The operand name whose full shape+dtype ``entry``
        (a ShapeDtypeStruct(...) expression) mirrors, else None."""
        if not (isinstance(entry, ast.Call) and entry.args
                and len(entry.args) >= 2):
            return None
        if (_call_name(entry, self.aliases) or "").rsplit(
                ".", 1)[-1] != "ShapeDtypeStruct":
            return None
        shape_arg, dtype_arg = entry.args[0], entry.args[1]
        if not (isinstance(dtype_arg, ast.Attribute)
                and dtype_arg.attr == "dtype"
                and isinstance(dtype_arg.value, ast.Name)):
            return None
        name = dtype_arg.value.id
        if name not in operands:
            return None
        # shape is literally `name.shape`
        if (isinstance(shape_arg, ast.Attribute)
                and shape_arg.attr == "shape"
                and isinstance(shape_arg.value, ast.Name)
                and shape_arg.value.id == name):
            return name
        # ... or the full tuple unpacked from `name.shape`, in order
        if isinstance(shape_arg, ast.Tuple) and all(
                isinstance(e, ast.Name) for e in shape_arg.elts):
            elts = tuple(e.id for e in shape_arg.elts)
            if shape_unpacks.get(elts) == name:
                return name
        return None

    # -- JL006: spec axis not in mesh ----------------------------------------------
    def check_jl006(self) -> None:
        meshes = self._static_meshes()
        if not meshes:
            return
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            path = _call_name(node, self.aliases) or ""
            leaf = path.rsplit(".", 1)[-1]
            if leaf == "shard_map":
                mesh_kw = next((kw.value for kw in node.keywords
                                if kw.arg == "mesh"), None)
                mesh_name = (mesh_kw.id if isinstance(mesh_kw, ast.Name)
                             else None)
                spec_nodes = [kw.value for kw in node.keywords
                              if kw.arg in ("in_specs", "out_specs")]
            elif leaf == "NamedSharding":
                mesh_name = (node.args[0].id if node.args
                             and isinstance(node.args[0], ast.Name)
                             else None)
                spec_nodes = node.args[1:2]
            else:
                continue
            axes = meshes.get(mesh_name or "")
            if axes is None:
                continue
            for spec in spec_nodes:
                for used in self._spec_axes(spec):
                    if used not in axes:
                        self.emit(
                            "JL006", node,
                            f"partition spec names axis {used!r} but mesh "
                            f"`{mesh_name}` only defines {sorted(axes)} — "
                            "the dimension silently replicates (or fails "
                            "only at scale)")

    def _static_meshes(self) -> dict:
        """{name: set of axis names} for meshes built with literal axis
        tuples anywhere in the file."""
        out: dict = {}
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                continue
            call = node.value
            path = _call_name(call, self.aliases) or ""
            leaf = path.rsplit(".", 1)[-1]
            if leaf not in ("make_mesh", "Mesh"):
                continue
            axis_arg = None
            if leaf == "make_mesh" and len(call.args) >= 2:
                axis_arg = call.args[1]
            elif leaf == "Mesh" and len(call.args) >= 2:
                axis_arg = call.args[1]
            for kw in call.keywords:
                if kw.arg == "axis_names":
                    axis_arg = kw.value
            if axis_arg is None:
                continue
            try:
                axes = ast.literal_eval(axis_arg)
            except (ValueError, SyntaxError):
                continue
            if isinstance(axes, str):
                axes = (axes,)
            if isinstance(axes, (tuple, list)) and all(
                    isinstance(a, str) for a in axes):
                out[node.targets[0].id] = set(axes)
        return out

    def _spec_axes(self, spec: ast.AST) -> set:
        """Axis-name string literals inside P(...) constructors under
        ``spec``."""
        axes: set = set()
        for node in ast.walk(spec):
            if not isinstance(node, ast.Call):
                continue
            path = _call_name(node, self.aliases) or ""
            leaf = path.rsplit(".", 1)[-1]
            if leaf not in ("P", "PartitionSpec"):
                continue
            for arg in node.args:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Constant) and isinstance(
                            sub.value, str):
                        axes.add(sub.value)
        return axes


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint one source string; returns every finding (``suppressed`` marks
    pragma-silenced ones)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="JL000", path=path, line=e.lineno or 1,
                        col=(e.offset or 0) + 1,
                        message=f"syntax error: {e.msg}")]
    return _FileLinter(tree, source, path).run()


def lint_file(path) -> list[Finding]:
    p = Path(path)
    return lint_source(p.read_text(), str(p))


def lint_paths(paths: Sequence, *,
               exclude: Iterable[str] = ()) -> list[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories)."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    exclude = tuple(exclude)
    findings: list[Finding] = []
    for f in files:
        if any(part in exclude for part in f.parts):
            continue
        findings.extend(lint_file(f))
    return findings

"""repro.analysis — static analysis and runtime instrumentation.

Two halves of one correctness story for the delayed-gradient executor and
the serving stack built on it:

- :mod:`repro.analysis.lint` is an AST linter for the JAX/Pallas invariants
  no off-the-shelf tool checks — retrace hazards, use-after-donation, RNG
  key reuse, host syncs inside traced code, in-place Pallas kernels without
  ``input_output_aliases``, ``shard_map`` specs naming axes the mesh lacks
  (rules JL001–JL006, ``scripts/jaxlint.py`` is the CLI, the CI lint job
  runs it over ``src benchmarks examples``);
- :mod:`repro.analysis.instrument` is the runtime half: one event bus for
  jit traces, host pad-scratch allocations, XLA compile events, and
  donation warnings, consumed by the engines, the benchmarks, and the
  tests instead of per-site counters.

See ``ANALYSIS.md`` for the rule catalog and pragma syntax.
"""

from repro.analysis.instrument import (  # noqa: F401
    Counters,
    Report,
    counters,
    instrument,
)
from repro.analysis.lint import (  # noqa: F401
    Finding,
    RULES,
    lint_file,
    lint_paths,
    lint_source,
)

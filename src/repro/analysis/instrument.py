"""Unified runtime instrumentation for the repo's compiled hot paths.

Every engine in the system promises the same three invariants on its hot
path: **one trace per shape rung** (a mixed request/commit stream must not
retrace), **zero per-request host pad allocations** (padding writes into a
per-rung scratch), and **donated carries** (state buffers update in place).
Before this module each engine kept its own ad-hoc counters and each
benchmark hand-diffed them around the measured stream; now there is one
event bus:

- engines own a :class:`Counters` handle (``counters("ServeEngine")``) and
  report every jit trace (:meth:`Counters.trace`, labelled per compiled
  function) and every host pad-scratch creation (:meth:`Counters.pad_alloc`)
  through it — the engines' public ``num_traces`` / ``num_host_pad_allocs``
  are thin views over the handle;
- callers wrap a region in :func:`instrument` and get a :class:`Report` of
  everything that happened inside it: per-(engine, function) trace counts,
  pad allocs, XLA compile events and wall-time (via :mod:`jax`'s monitoring
  listener, best-effort), and captured donation warnings.  A measured
  request stream whose rungs are warm must produce an *empty* report —
  :meth:`Report.stream_flags` is that assertion packaged for the benchmark
  JSON rows, and ``scripts/check_bench.py`` gates on its fields.

Compile **wall-time** rides the same listener: jax's monitoring bus emits
per-phase durations (jaxpr trace, MLIR lowering, backend compile) with no
function identity attached, but the engines' ``Counters.trace(fn)`` side
effect fires *during* tracing — so the bus attributes each duration to the
most recently traced (engine, function) pair.  Totals land in
:attr:`Report.compile_ms` per function and, process-wide, in the
:mod:`repro.obs.metrics` registry (``xla.compile_ms_total`` counter plus a
cumulative ``xla.compile_ms/<engine>/<fn>`` gauge per compiled function),
so a trace-count regression comes with its compile-time cost.

The context manager nests (inner regions report a subset of outer ones) and
costs two dict updates per event, so it is safe to leave on in production
serving loops.
"""

from __future__ import annotations

import threading
import warnings
from collections import Counter
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["Counters", "Report", "counters", "instrument"]

_lock = threading.Lock()
_active: list["Report"] = []  # instrument() stack, innermost last
_last_traced = [""]  # "label/fn" of the newest jit trace (compile attribution)
_compile_ms_by_fn: Counter = Counter()  # process-lifetime per-fn compile ms


class Counters:
    """Per-engine instrument handle: monotone trace / pad-alloc counters.

    ``trace(fn)`` is called from inside a jitted function body (a Python
    side effect runs once per trace, never per execution), ``pad_alloc()``
    from the host padding path whenever a new scratch buffer is created.
    Both also broadcast into every active :func:`instrument` region.
    """

    __slots__ = ("label", "traces", "pad_allocs", "per_fn")

    def __init__(self, label: str):
        self.label = label
        self.traces = 0
        self.pad_allocs = 0
        self.per_fn: Counter = Counter()  # compiled-function name -> traces

    def trace(self, fn: str = "") -> None:
        """Record one jit trace of compiled function ``fn``."""
        with _lock:
            self.traces += 1
            self.per_fn[fn] += 1
            _last_traced[0] = f"{self.label}/{fn}" if fn else self.label
            for rep in _active:
                rep._traces[(self.label, fn)] += 1

    def pad_alloc(self) -> None:
        """Record one host pad-scratch buffer creation."""
        with _lock:
            self.pad_allocs += 1
            for rep in _active:
                rep._pad_allocs[self.label] += 1


def counters(label: str) -> Counters:
    """A fresh per-engine instrument handle."""
    _ensure_compile_listener()  # engines exist before they compile
    return Counters(label)


@dataclass
class Report:
    """Everything the instrument bus saw inside one :func:`instrument`
    region.  ``num_traces``/``num_pad_allocs`` are the totals; the dict
    views break them down per (engine label, compiled function)."""

    _traces: Counter = field(default_factory=Counter)
    _pad_allocs: Counter = field(default_factory=Counter)
    #: per-"label/fn" compile wall-time (seconds) observed inside the region
    #: by jax's monitoring bus, attributed to the most recent trace
    _compile_secs: Counter = field(default_factory=Counter)
    #: XLA jaxpr-trace events observed by jax's monitoring bus (best-effort:
    #: 0 when the listener API is unavailable; a cross-check that the
    #: engines' python-side counters are not lying about retraces)
    xla_compiles: int = 0
    #: "Some donated buffers were not usable" / "Donation is not implemented"
    #: warnings captured inside the region
    donation_warnings: list = field(default_factory=list)

    @property
    def num_traces(self) -> int:
        return sum(self._traces.values())

    @property
    def num_pad_allocs(self) -> int:
        return sum(self._pad_allocs.values())

    @property
    def traces(self) -> dict:
        """{(engine label, compiled fn): trace count} inside the region."""
        return dict(self._traces)

    @property
    def pad_allocs(self) -> dict:
        """{engine label: pad-scratch creations} inside the region."""
        return dict(self._pad_allocs)

    def traces_for(self, label: str) -> int:
        return sum(n for (lbl, _), n in self._traces.items() if lbl == label)

    @property
    def compile_ms(self) -> dict:
        """{"label/fn": compile wall-time ms} inside the region (best-effort
        attribution; durations before the first trace land under "")."""
        return {k: v * 1e3 for k, v in sorted(self._compile_secs.items())}

    @property
    def compile_time_ms(self) -> float:
        """Total XLA compile wall-time (all phases, ms) inside the region."""
        return sum(self._compile_secs.values()) * 1e3

    def stream_flags(self) -> dict:
        """The hot-stream invariant, packaged for a benchmark JSON row:
        a measured stream over warm rungs must trace nothing and allocate
        no pad scratch.  ``check_bench`` gates on these fields."""
        return {
            "retraced_in_stream": self.num_traces > 0,
            "pad_allocs_in_stream": self.num_pad_allocs,
        }

    def to_dict(self) -> dict:
        """JSON-serializable summary (keys flattened to 'label/fn')."""
        return {
            "traces": {f"{lbl}/{fn}" if fn else lbl: n
                       for (lbl, fn), n in sorted(self._traces.items())},
            "pad_allocs": {lbl: n
                           for lbl, n in sorted(self._pad_allocs.items())},
            "xla_compiles": self.xla_compiles,
            "compile_ms": {k: round(v, 3)
                           for k, v in self.compile_ms.items()},
            "donation_warnings": len(self.donation_warnings),
        }


_listener_installed = [False]


def _ensure_compile_listener() -> None:
    """Install the process-global compile listener once (idempotent,
    best-effort: a silent no-op when jax or its private monitoring API is
    missing).  The listener feeds every active :func:`instrument` report
    *and* the :mod:`repro.obs.metrics` registry, so compile cost is visible
    even for compiles that happen outside any instrumented region (warmup
    loops, first requests)."""
    if _listener_installed[0]:
        return
    _listener_installed[0] = True
    try:
        from jax._src import monitoring
        from jax._src.dispatch import JAXPR_TRACE_EVENT
    except ImportError:
        return

    from repro.obs.metrics import registry

    def listener(event: str, duration: float, **_kw) -> None:
        if "/jax/core/compile" not in event:
            return
        with _lock:
            key = _last_traced[0]
            _compile_ms_by_fn[key] += duration * 1e3
            total_fn_ms = _compile_ms_by_fn[key]
            for rep in _active:
                rep._compile_secs[key] += duration
                if event == JAXPR_TRACE_EVENT:
                    rep.xla_compiles += 1
        reg = registry()
        reg.counter("xla.compile_ms_total",
                    "cumulative XLA compile wall-time (all phases)"
                    ).inc(duration * 1e3)
        reg.gauge(f"xla.compile_ms/{key or 'other'}",
                  "cumulative compile wall-time of one compiled function"
                  ).set(total_fn_ms)

    try:
        monitoring.register_event_duration_secs_listener(listener)
    except Exception:
        pass


@contextmanager
def instrument(*, transfer_guard: Optional[str] = None,
               capture_donation_warnings: bool = True):
    """Collect every engine trace / pad-alloc event in the ``with`` body
    into a :class:`Report`.

    ``transfer_guard`` optionally applies :func:`jax.transfer_guard` to the
    region (``"disallow"`` turns an implicit host sync inside the measured
    stream into a hard error — the runtime teeth behind lint rule JL004;
    ``"log"`` merely reports).  ``capture_donation_warnings`` records
    donation-related warnings into the report instead of letting them
    scroll past (all other warnings are re-emitted on exit).

        with instrument() as rep:
            for q in stream:
                engine(q)
        assert rep.num_traces == 0          # warm stream never retraces
        row.update(rep.stream_flags())      # -> benchmark JSON / check_bench
    """
    report = Report()
    _ensure_compile_listener()
    catcher = None
    caught: list = []
    if capture_donation_warnings:
        catcher = warnings.catch_warnings(record=True)
        caught = catcher.__enter__()
        warnings.simplefilter("always")
    with _lock:
        _active.append(report)
    try:
        if transfer_guard is not None:
            import jax

            with jax.transfer_guard(transfer_guard):
                yield report
        else:
            yield report
    finally:
        with _lock:
            _active.remove(report)
        if catcher is not None:
            catcher.__exit__(None, None, None)
            for w in caught:
                msg = str(w.message)
                if "donat" in msg.lower():
                    report.donation_warnings.append(msg)
                else:  # not ours: hand it back to the outer filters
                    warnings.warn_explicit(w.message, w.category,
                                           w.filename, w.lineno)

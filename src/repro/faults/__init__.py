"""repro.faults — deterministic fault injection and the self-healing story.

The async-SGLD convergence theory survives staleness; production clusters
add a second adversary the paper never models: machines die.  This package
is the one-stop facade over the repo's fault surface — every primitive
lives next to the subsystem it stresses, and is re-exported here so chaos
experiments read as one vocabulary:

- :class:`FaultPlan` (:mod:`repro.core.delay_model`) — worker chaos
  schedules: Poisson crash/pause events compiled into the same
  :class:`~repro.cluster.schedule.WorkerSchedule` the healthy cluster
  replays, with a per-commit liveness mask.  Dead commits execute as
  masked no-ops on device (:func:`~repro.cluster.schedule.stack_liveness`)
  — same single scan trace, and a zero-rate plan is **bitwise-identical**
  to no plan at all.
- :class:`HealthState` (:mod:`repro.cluster.executor`) — the sticky
  per-chain quarantine mask: a chain whose iterate goes non-finite stops
  committing (on-device ``where`` masking, no retrace), drops out of every
  ensemble reduction (:func:`~repro.cluster.ensemble.healthy_chains`), and
  is respawned at the next chunk boundary from a healthy donor with a
  ``fold_in``-freshened key.  :func:`nan_storm` below builds the poison
  masks that drive it in tests and benches.
- :class:`CorruptCheckpointError` (:mod:`repro.checkpoint.io`) — per-leaf
  CRC32 manifests make a truncated or bit-flipped checkpoint fail loudly,
  naming the damaged leaf; :meth:`ClusterEngine.resume` stitches a
  SIGKILL'd run back together **bitwise** from the last good one.
- :class:`QueueFullError` + deadline shedding
  (:mod:`repro.cluster.api` / :mod:`repro.cluster.paged`) — the serving
  degradation path: bounded queues reject instead of bloating, expired
  requests are shed (:data:`~repro.cluster.api.STATUS_SHED`) or cut short
  (:data:`~repro.cluster.api.STATUS_TIMEOUT`) instead of convoying the
  live ones, and a partially-quarantined bank serves a degraded BMA from
  the surviving chains (:meth:`BankEngine.from_cluster`).

Everything is observable: ``faults.injected`` / ``chains.quarantined`` /
``chains.respawned`` / ``chains.unhealthy`` / ``requests.shed`` /
``requests.timeout`` / ``requests.rejected`` in the metrics registry and
``faults.respawn`` / ``paged.shed`` spans on the tracer.  The operational
walkthrough lives in ``docs/ROBUSTNESS.md``.
"""

from __future__ import annotations

import numpy as np

from repro.checkpoint.io import CorruptCheckpointError  # noqa: F401
from repro.cluster.api import (  # noqa: F401
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    QueueFullError,
)
from repro.cluster.executor import HealthState  # noqa: F401
from repro.cluster.schedule import stack_liveness  # noqa: F401
from repro.core.delay_model import FaultPlan  # noqa: F401

__all__ = [
    "CorruptCheckpointError",
    "FaultPlan",
    "HealthState",
    "QueueFullError",
    "STATUS_OK",
    "STATUS_SHED",
    "STATUS_TIMEOUT",
    "nan_storm",
    "stack_liveness",
]


def nan_storm(steps: int, num_chains: int, *, rate: float = 0.01,
              seed: int = 0) -> np.ndarray:
    """A ``(steps, num_chains)`` bool poison mask: True cells NaN the
    chain's iterate *after* that commit's sampler step.

    Feed it to :meth:`ClusterEngine.run(..., poison=...)
    <repro.cluster.executor.ClusterEngine.run>` (with
    ``health_check=True``) to drive quarantine/respawn deterministically —
    the mask is host-side data, so the same seed reproduces the same storm
    on any backend.  ``rate`` is the per-commit-per-chain poison
    probability; the RNG is dedicated (salted stream), so adding a storm
    never perturbs schedule or sampler randomness.
    """
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"rate must be in [0, 1], got {rate}")
    rng = np.random.default_rng((seed, 0x5A17))
    return rng.random((steps, num_chains)) < rate

"""Pure-jnp oracles for the Pallas kernels (bit-identical math)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.rng import normal_from_counter


def langevin_update_ref(x: jnp.ndarray, g: jnp.ndarray, seed: jnp.ndarray,
                        gamma, scale) -> jnp.ndarray:
    """x, g: (R, L) float32; seed (2,) uint32 — same counter scheme as the
    kernel (row-major global element index)."""
    R, L = x.shape
    counter = jnp.arange(R * L, dtype=jnp.uint32).reshape(R, L)
    xi = normal_from_counter(seed[0], seed[1], counter)
    return x - jnp.float32(gamma) * g + jnp.float32(scale) * xi


def delay_gather_ref(history: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """history: (depth, N); slots: (N,) -> (N,)."""
    return jnp.take_along_axis(history, slots[None, :], axis=0)[0]


def decode_step_ref(q, k_new, v_new, k_cache, v_cache, valid, slot):
    """Oracle for the fused decode step — the same slot select, fp32
    softmax, and einsum orders as the kernel body, batched over rows.

    q: (B, KV, G, hd); k_new/v_new: (B, KV, hd); caches: (B, smax, KV, hd);
    valid: (smax,) int32; slot: scalar int32.
    """
    smax, _, hd = k_cache.shape[1:]
    scale = 1.0 / math.sqrt(hd)
    sel = jax.lax.broadcasted_iota(jnp.int32, k_cache.shape[1:], 0) == slot
    k = jnp.where(sel[None], k_new[:, None], k_cache)
    v = jnp.where(sel[None], v_new[:, None], v_cache)
    q32 = q.astype(jnp.float32) * scale
    s = jnp.einsum("bngh,bcnh->bngc", q32, k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :] == 1, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bngc,bcnh->bngh", p, v.astype(jnp.float32))
    return o.astype(q.dtype), k, v

"""Pure-jnp oracles for the Pallas kernels (bit-identical math)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.rng import normal_from_counter


def langevin_update_ref(x: jnp.ndarray, g: jnp.ndarray, seed: jnp.ndarray,
                        gamma, scale) -> jnp.ndarray:
    """x, g: (R, L) float32; seed (2,) uint32 — same counter scheme as the
    kernel (row-major global element index)."""
    R, L = x.shape
    counter = jnp.arange(R * L, dtype=jnp.uint32).reshape(R, L)
    xi = normal_from_counter(seed[0], seed[1], counter)
    return x - jnp.float32(gamma) * g + jnp.float32(scale) * xi


def delay_gather_ref(history: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """history: (depth, N); slots: (N,) -> (N,)."""
    return jnp.take_along_axis(history, slots[None, :], axis=0)[0]


def decode_step_ref(q, k_new, v_new, k_cache, v_cache, valid, slot):
    """Oracle for the fused decode step — the same slot select, fp32
    softmax, and einsum orders as the kernel body, batched over rows.

    q: (B, KV, G, hd); k_new/v_new: (B, KV, hd); caches: (B, smax, KV, hd);
    valid: (smax,) int32; slot: scalar int32.
    """
    smax, _, hd = k_cache.shape[1:]
    scale = 1.0 / math.sqrt(hd)
    sel = jax.lax.broadcasted_iota(jnp.int32, k_cache.shape[1:], 0) == slot
    k = jnp.where(sel[None], k_new[:, None], k_cache)
    v = jnp.where(sel[None], v_new[:, None], v_cache)
    q32 = q.astype(jnp.float32) * scale
    s = jnp.einsum("bngh,bcnh->bngc", q32, k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :] == 1, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bngc,bcnh->bngh", p, v.astype(jnp.float32))
    return o.astype(q.dtype), k, v


def paged_decode_step_ref(q, k_new, v_new, k_pages, v_pages, tables, pos):
    """Oracle for the fused *paged* decode step — the same logical-order
    page gather, new-row overlay, fp32 softmax, and einsum orders as the
    kernel body, batched over slots.

    q: (S, KV, G, hd); k_new/v_new: (S, KV, hd); k_pages/v_pages:
    (n_pages, page_size, KV, hd) shared pool; tables: (S, maxp) int32;
    pos: (S,) int32.  Returns (o, k_pages', v_pages').
    """
    S, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    maxp = tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kf = k_pages.reshape(-1, KV, hd)
    vf = v_pages.reshape(-1, KV, hd)
    # gather each slot's logical window from the pre-store pool, then overlay
    # the new row at its logical position (matching the kernel's ordering-
    # insensitive select)
    gidx = ((tables * ps)[:, :, None]
            + jnp.arange(ps)[None, None]).reshape(S, maxp * ps)
    sel = (jnp.arange(maxp * ps)[None, :, None, None]
           == pos[:, None, None, None])
    k = jnp.where(sel, k_new[:, None], kf[gidx])      # (S, maxp*ps, KV, hd)
    v = jnp.where(sel, v_new[:, None], vf[gidx])
    q32 = q.astype(jnp.float32) * scale
    s = jnp.einsum("bngh,bcnh->bngc", q32, k.astype(jnp.float32))
    valid = jnp.arange(maxp * ps)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bngc,bcnh->bngh", p, v.astype(jnp.float32))
    widx = tables[jnp.arange(S), pos // ps] * ps + pos % ps
    return (o.astype(q.dtype),
            kf.at[widx].set(k_new).reshape(k_pages.shape),
            vf.at[widx].set(v_new).reshape(v_pages.shape))

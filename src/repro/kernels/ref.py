"""Pure-jnp oracles for the Pallas kernels (bit-identical math)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rng import normal_from_counter


def langevin_update_ref(x: jnp.ndarray, g: jnp.ndarray, seed: jnp.ndarray,
                        gamma, scale) -> jnp.ndarray:
    """x, g: (R, L) float32; seed (2,) uint32 — same counter scheme as the
    kernel (row-major global element index)."""
    R, L = x.shape
    counter = jnp.arange(R * L, dtype=jnp.uint32).reshape(R, L)
    xi = normal_from_counter(seed[0], seed[1], counter)
    return x - jnp.float32(gamma) * g + jnp.float32(scale) * xi


def delay_gather_ref(history: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """history: (depth, N); slots: (N,) -> (N,)."""
    return jnp.take_along_axis(history, slots[None, :], axis=0)[0]

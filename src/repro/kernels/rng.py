"""Counter-based RNG (threefry2x32) + Box-Muller, in plain jnp ops.

Used *inside* the Pallas langevin_update kernel (plain jnp lowers fine in
kernels) and by the pure-jnp oracle in ref.py — so kernel and oracle are
bit-identical by construction.  Counter = global element index, key = user
seed: reproducible regardless of block shape or sharding.
"""

from __future__ import annotations

import jax.numpy as jnp

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA  # python int: jnp constants must be created in-trace
                      # (pallas kernels reject closure-captured arrays)


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(key0, key1, x0, x1):
    """20-round threefry2x32 (same schedule as JAX's reference)."""
    x0, x1 = x0.astype(jnp.uint32), x1.astype(jnp.uint32)
    k0 = jnp.uint32(key0)
    k1 = jnp.uint32(key1)
    k2 = k0 ^ k1 ^ jnp.uint32(_PARITY)
    ks = (k0, k1, k2)

    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for block in range(5):
        rots = _ROTATIONS[block % 2]
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + jnp.uint32(block + 1)
    return x0, x1


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 -> float32 uniform in (0, 1): top 24 bits, offset by 2^-25."""
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2**-24)
    return u + jnp.float32(2**-25)


def normal_from_counter(seed0, seed1, counter: jnp.ndarray) -> jnp.ndarray:
    """Standard normals from int32/uint32 element counters (Box-Muller).

    counter: any-shape uint32 global element index (pairs share bits).
    """
    c = counter.astype(jnp.uint32)
    b0, b1 = threefry2x32(seed0, seed1, c, c ^ jnp.uint32(0x9E3779B9))
    u1 = uniform_from_bits(b0)
    u2 = uniform_from_bits(b1)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.14159265358979) * u2)

"""Pallas TPU kernel: per-coordinate stale read (the W-Icon hot path).

Gathers x_hat[i] = history[(head - delay_i) mod depth, i] from the ring
buffer.  A naive take_along_axis materializes the flattened index arithmetic
in HBM; this kernel streams one (depth, BLOCK) VMEM tile of history per
output block and reduces the slot-select on chip:

    out = sum_d history[d, :] * (d == slot)

which is a (depth x BLOCK) broadcast-compare + multiply-reduce — ideal VPU
shape since depth = tau+1 is small (<= 8 in fidelity runs).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # lanes per grid step (32 sublanes x 128 lanes fp32)


def _kernel(hist_ref, slot_ref, o_ref):
    depth, blk = hist_ref.shape
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (depth, blk), 0)
    sel = (d_ids == slot_ref[...][None, :]).astype(hist_ref.dtype)
    o_ref[...] = jnp.sum(hist_ref[...] * sel, axis=0)


@partial(jax.jit, static_argnames=("interpret",))
def delay_gather_1d(history, slots, *, interpret=True):
    """history: (depth, N) float32; slots: (N,) int32 in [0, depth).
    N % BLOCK == 0.  Returns (N,) gathered values."""
    depth, N = history.shape
    assert N % BLOCK == 0, N
    grid = (N // BLOCK,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((depth, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), history.dtype),
        interpret=interpret,
    )(history, slots)

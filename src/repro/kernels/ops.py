"""Jit'd public wrappers: pad/reshape pytrees into kernel-friendly tiles.

``fused_langevin_update(params, grads, seed, gamma, scale)`` applies the
fused SGLD update leafwise; ``fused_delay_gather(ring_history, slots)`` does
the W-Icon read.  ``interpret=True`` (default on CPU) runs the kernel body in
Python for validation; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import delay_gather as dg
from repro.kernels import langevin_update as lu
from repro.utils import round_up

PyTree = Any


def _pad_to_tiles(flat: jnp.ndarray, lanes: int, rows_mult: int):
    n = flat.shape[0]
    rows = max(rows_mult, round_up(-(-n // lanes), rows_mult))
    padded = jnp.zeros((rows * lanes,), flat.dtype).at[:n].set(flat)
    return padded.reshape(rows, lanes), n


def langevin_update_flat(x: jnp.ndarray, g: jnp.ndarray, seed, gamma, scale,
                         *, interpret: bool = True) -> jnp.ndarray:
    """Fused update on a flat fp32 vector (any length)."""
    x2, n = _pad_to_tiles(x.astype(jnp.float32), lu.LANES, lu.BLOCK_ROWS)
    g2, _ = _pad_to_tiles(g.astype(jnp.float32), lu.LANES, lu.BLOCK_ROWS)
    out = lu.langevin_update_2d(x2, g2, jnp.asarray(seed, jnp.uint32),
                                gamma, scale, interpret=interpret)
    return out.reshape(-1)[:n].astype(x.dtype)


def fused_langevin_update(params: PyTree, grads: PyTree, seed, gamma, scale,
                          *, interpret: bool = True) -> PyTree:
    """Leafwise fused SGLD update with a distinct seed fold per leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    seed = jnp.asarray(seed, jnp.uint32)
    out = []
    for i, (p, g) in enumerate(zip(leaves, gleaves)):
        leaf_seed = jnp.stack([seed[0] ^ jnp.uint32((0x85EBCA6B * (i + 1)) & 0xFFFFFFFF),
                               seed[1] + jnp.uint32(i)])
        flat = langevin_update_flat(p.reshape(-1), g.reshape(-1), leaf_seed,
                                    gamma, scale, interpret=interpret)
        out.append(flat.reshape(p.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def delay_gather_flat(history: jnp.ndarray, slots: jnp.ndarray,
                      *, interpret: bool = True) -> jnp.ndarray:
    """history: (depth, N) any N; slots: (N,) int32."""
    depth, n = history.shape
    n_pad = max(dg.BLOCK, round_up(n, dg.BLOCK))
    h = jnp.zeros((depth, n_pad), history.dtype).at[:, :n].set(history)
    s = jnp.zeros((n_pad,), jnp.int32).at[:n].set(slots)
    out = dg.delay_gather_1d(h, s, interpret=interpret)
    return out[:n]


def fused_decode_step(q: jnp.ndarray, k_new: jnp.ndarray, v_new: jnp.ndarray,
                      k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                      valid: jnp.ndarray, slot, *, interpret: bool = True):
    """Fused streaming decode step in model layout.

    q: (B, H, hd); k_new, v_new: (B, KV, hd); caches: (B, smax, KV, hd);
    valid: (smax,) int32 slot-validity mask (already includes the window and
    the just-written slot); slot: scalar int32 ring slot for the new token.
    Returns (o (B, H, hd), k_cache', v_cache').
    """
    from repro.kernels import decode_step as ds

    B, H, hd = q.shape
    KV = k_cache.shape[2]
    o, kc, vc = ds.decode_step_2d(
        q.reshape(B, KV, H // KV, hd), k_new, v_new, k_cache, v_cache,
        jnp.asarray(valid, jnp.int32),
        jnp.asarray(slot, jnp.int32).reshape(1), interpret=interpret)
    return o.reshape(B, H, hd), kc, vc


def fused_paged_decode_step(q: jnp.ndarray, k_new: jnp.ndarray,
                            v_new: jnp.ndarray, k_pages: jnp.ndarray,
                            v_pages: jnp.ndarray, tables: jnp.ndarray,
                            pos: jnp.ndarray, *, interpret: bool = True):
    """Fused paged decode step in model layout.

    q: (S, H, hd); k_new, v_new: (S, KV, hd); k_pages, v_pages:
    (n_pages, page_size, KV, hd) block pool shared by all slots; tables:
    (S, maxp) int32 per-slot page table; pos: (S,) int32 absolute position
    per slot.  Returns (o (S, H, hd), k_pages', v_pages').
    """
    from repro.kernels import decode_step as ds

    S, H, hd = q.shape
    KV = k_pages.shape[2]
    o, kp, vp = ds.paged_decode_step(
        q.reshape(S, KV, H // KV, hd), k_new, v_new, k_pages, v_pages,
        jnp.asarray(tables, jnp.int32), jnp.asarray(pos, jnp.int32),
        interpret=interpret)
    return o.reshape(S, H, hd), kp, vp


def fused_delay_gather(ring_history: PyTree, slots: PyTree, head, depth: int,
                       *, interpret: bool = True) -> PyTree:
    """W-Icon read over a ring-buffer pytree (leaves (depth, *shape)) with
    per-coordinate delay pytree ``slots`` (leaves shaped like params)."""

    def one(h, s):
        shape = h.shape[1:]
        slot = jnp.mod(head - s.reshape(-1), depth).astype(jnp.int32)
        flat = delay_gather_flat(h.reshape(depth, -1), slot, interpret=interpret)
        return flat.reshape(shape)

    return jax.tree_util.tree_map(one, ring_history, slots)

"""Pallas TPU kernel: fused SGLD update  x <- x - gamma*g + sqrt(2*sigma*gamma)*xi.

The paper's per-iterate hot path touches every parameter once; unfused, XLA
emits (RNG -> HBM), (read x, g, noise -> write x'): three HBM round trips of
the full parameter vector.  This kernel generates the Langevin noise *in
VMEM* (counter-based threefry, rng.py) and fuses the update: one read of
(x, g), one write of x'.

Tiling: flat parameters are padded/reshaped by ops.py to (rows, LANES=128·k);
the grid walks row blocks of 256 rows x 1024 lanes (1 MiB fp32 per operand —
3 operands resident = 3 MiB of ~16 MiB VMEM, leaving room for double
buffering).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rng import normal_from_counter

BLOCK_ROWS = 256
LANES = 1024


def _kernel(x_ref, g_ref, seed_ref, gamma_ref, scale_ref, o_ref):
    i = pl.program_id(0)
    rows, lanes = x_ref.shape
    # global element counter for this block
    base = (i * rows * lanes).astype(jnp.uint32) if hasattr(
        i, "astype") else jnp.uint32(i * rows * lanes)
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 0)
    lane_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 1)
    counter = base + row_ids * jnp.uint32(lanes) + lane_ids
    xi = normal_from_counter(seed_ref[0], seed_ref[1], counter)
    gamma = gamma_ref[0]
    scale = scale_ref[0]
    o_ref[...] = x_ref[...] - gamma * g_ref[...] + scale * xi


@partial(jax.jit, static_argnames=("interpret",))
def langevin_update_2d(x, g, seed: jnp.ndarray, gamma, scale, *, interpret=True):
    """x, g: (R, LANES) float32, R % BLOCK_ROWS == 0; seed: (2,) uint32."""
    R, L = x.shape
    assert L == LANES and R % BLOCK_ROWS == 0, (R, L)
    grid = (R // BLOCK_ROWS,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),  # seed (scalar prefetch-ish)
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, L), x.dtype),
        # the update overwrites x block-for-block: alias it so XLA reuses
        # the buffer instead of double-buffering R*L fp32 through HBM
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x, g, seed, jnp.asarray(gamma, jnp.float32).reshape(1),
      jnp.asarray(scale, jnp.float32).reshape(1))

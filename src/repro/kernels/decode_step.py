"""Pallas TPU kernel: fused streaming decode step (the per-token hot path).

One decode step of the chain-bank BMA server touches, per (chain, batch) row:
the new token's k/v written into the ring-cache slot for the current
position, then single-query attention over the whole cache.  Unfused, XLA
emits (write k slot), (write v slot), (read k cache), (read v cache): four
HBM round trips of the (smax, KV, hd) cache per layer.  This kernel fuses
the slot update with the attention read — the cache streams through VMEM
exactly once per operand and the updated slot never round-trips to HBM
before being attended over.

Layout: the grid walks batch rows (the vmapped chain axis of a
:class:`~repro.cluster.decode.DecodeEngine` batches into extra grid
dimensions via the pallas batching rule, so a (C, B) bank is a (C, B) grid);
each step holds one ``(smax, KV, hd)`` cache tile per operand in VMEM —
1 MiB at (1024, 8, 128) bf16, three tiles resident well inside ~16 MiB.  The
slot select is the same broadcast-compare + select idiom as
``delay_gather``; masking arrives precomputed as a ``(smax,)`` validity
vector so the kernel stays free of position arithmetic.  On TPU, ``hd``
should be a multiple of 128 lanes and ``smax`` of 8 sublanes;
``interpret=True`` (the CPU default, matching the other kernels) has no
tiling constraints.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


__all__ = ["decode_step_2d", "paged_decode_step"]


def _kernel(q_ref, kn_ref, vn_ref, kc_ref, vc_ref, valid_ref, slot_ref,
            o_ref, ko_ref, vo_ref):
    _, smax, KV, hd = kc_ref.shape
    scale = 1.0 / math.sqrt(hd)
    slot = slot_ref[0]
    # in-VMEM slot update: broadcast-compare + select (delay_gather idiom)
    sel = jax.lax.broadcasted_iota(jnp.int32, (smax, KV, hd), 0) == slot
    k = jnp.where(sel, kn_ref[0][None], kc_ref[0])
    v = jnp.where(sel, vn_ref[0][None], vc_ref[0])
    ko_ref[0] = k
    vo_ref[0] = v
    # single-query attention over the updated cache, fp32 softmax
    q32 = q_ref[0].astype(jnp.float32) * scale            # (KV, G, hd)
    s = jnp.einsum("ngh,cnh->ngc", q32, k.astype(jnp.float32))
    s = jnp.where(valid_ref[...][None, None, :] == 1, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("ngc,cnh->ngh", p, v.astype(jnp.float32))
    o_ref[0] = o.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("interpret",))
def decode_step_2d(q, k_new, v_new, k_cache, v_cache, valid, slot,
                   *, interpret=True):
    """q: (B, KV, G, hd); k_new, v_new: (B, KV, hd);
    k_cache, v_cache: (B, smax, KV, hd); valid: (smax,) int32 (1 = attend);
    slot: (1,) int32 — the ring slot the new k/v lands in.

    Returns (o (B, KV, G, hd) in q.dtype, k_cache', v_cache') with the slot
    row replaced in both caches (aliased in place).
    """
    B, KV, G, hd = q.shape
    smax = k_cache.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, smax, KV, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, smax, KV, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((smax,), lambda _i: (0,)),
            pl.BlockSpec(memory_space=pl.ANY),  # slot scalar
        ],
        out_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, smax, KV, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, smax, KV, hd), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
            jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
            jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype),
        ],
        input_output_aliases={3: 1, 4: 2},  # caches update in place
        interpret=interpret,
    )(q, k_new, v_new, k_cache, v_cache, valid, slot)


# ---------------------------------------------------------------------------
# paged variant: page-table gather over a shared block pool
# ---------------------------------------------------------------------------
def _paged_kernel(tbl_ref, pos_ref, q_ref, kn_ref, vn_ref, kc_ref, vc_ref,
                  o_ref, ko_ref, vo_ref):
    _, ps, KV, hd = kc_ref.shape
    maxp = tbl_ref.shape[1]
    scale = 1.0 / math.sqrt(hd)
    pos = pos_ref[0]
    # store the new k/v into this slot's page for the current position; only
    # these two rows of the shared pool are touched (partial store on the
    # aliased output), every other page survives bit-for-bit
    pg = tbl_ref[0, pos // ps]
    off = pos % ps
    ko_ref[pl.ds(pg, 1), pl.ds(off, 1)] = kn_ref[0][None, None]
    vo_ref[pl.ds(pg, 1), pl.ds(off, 1)] = vn_ref[0][None, None]

    # gather this slot's pages in *logical* order — the attention result is
    # invariant to how the allocator permuted the physical pages
    def gather(j, acc):
        ka, va = acc
        page = tbl_ref[0, j]
        kt = kc_ref[pl.ds(page, 1)][0]
        vt = vc_ref[pl.ds(page, 1)][0]
        return (jax.lax.dynamic_update_index_in_dim(ka, kt, j, 0),
                jax.lax.dynamic_update_index_in_dim(va, vt, j, 0))

    zero = jnp.zeros((maxp, ps, KV, hd), kc_ref.dtype)
    k_all, v_all = jax.lax.fori_loop(0, maxp, gather, (zero, zero))
    # overlay the new row at its logical position: the gather may observe the
    # pool before or after this step's store (the output aliases the input),
    # and the select makes both orders produce identical attention inputs
    sel = jax.lax.broadcasted_iota(jnp.int32, (maxp * ps, KV, hd), 0) == pos
    k = jnp.where(sel, kn_ref[0][None], k_all.reshape(maxp * ps, KV, hd))
    v = jnp.where(sel, vn_ref[0][None], v_all.reshape(maxp * ps, KV, hd))
    # single-query attention over the gathered logical window, fp32 softmax
    # (the same math as _kernel; validity is positional: logical index <= pos)
    q32 = q_ref[0].astype(jnp.float32) * scale            # (KV, G, hd)
    s = jnp.einsum("ngh,cnh->ngc", q32, k.astype(jnp.float32))
    valid = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2) <= pos
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("ngc,cnh->ngh", p, v.astype(jnp.float32))
    o_ref[0] = o.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_step(q, k_new, v_new, k_pages, v_pages, tables, pos,
                      *, interpret=True):
    """Fused paged decode step: slot-table gather + slot write + attention.

    q: (S, KV, G, hd); k_new, v_new: (S, KV, hd); k_pages, v_pages:
    (n_pages, page_size, KV, hd) — the block pool **shared by every slot**;
    tables: (S, maxp) int32 per-slot page table (logical page j of slot i
    lives in physical page ``tables[i, j]``); pos: (S,) int32 absolute
    position the new token is written at (and the highest logical index
    attended — validity is ``logical index <= pos``, full attention only).

    Returns (o (S, KV, G, hd) in q.dtype, k_pages', v_pages') with exactly
    one ``(page, offset)`` row per slot replaced in each pool (aliased in
    place).  The grid walks slots; a chain-vmapped engine batches the pool
    into extra grid dimensions via the pallas batching rule.
    """
    S, KV, G, hd = q.shape
    maxp = tables.shape[1]
    return pl.pallas_call(
        _paged_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, maxp), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, KV, G, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),  # shared k pool
            pl.BlockSpec(memory_space=pl.ANY),  # shared v pool
        ],
        out_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, KV, G, hd), q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        input_output_aliases={5: 1, 6: 2},  # pools update in place
        interpret=interpret,
    )(tables, pos, q, k_new, v_new, k_pages, v_pages)

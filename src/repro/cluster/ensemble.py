"""Vmapped chain ensembles: the whole sampler transform chain (including the
iterate :class:`~repro.core.delay.RingBuffer`) batched over C independent
chains, so one ``lax.scan`` step advances the entire population.

The paper's convergence claim is *in measure*: the law of the iterate
approaches the Gibbs posterior.  A single chain only exposes that law
through time averages (the moment-matched ``w2_to_gaussian`` proxy); a
C-chain ensemble exposes it directly — at any commit count the chain cloud
``(C, d)`` *is* a sample from the current law, and
:func:`ensemble_w2` measures empirical W2 against target-posterior draws
(``sinkhorn_w2``, or exact sorted quantiles in 1-D).

Every helper here is shape-transparent: chain ``c`` of the vmapped ensemble
computes bit-for-bit what an independent single-chain
:class:`~repro.samplers.base.Sampler` would with the same key and schedule
(asserted in ``tests/test_cluster.py``).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.metrics.wasserstein import sinkhorn_w2, w2_empirical_1d
from repro.obs.metrics import registry as _registry
from repro.samplers.base import Sampler, SamplerState
from repro.utils import tree_broadcast_leading, tree_normal_like

PyTree = Any


def init_ensemble(sampler: Sampler, params: PyTree, key: jax.Array | None = None,
                  *, num_chains: int | None = None,
                  keys: jax.Array | None = None,
                  jitter: float = 0.0) -> SamplerState:
    """Initialize C chains: every :class:`SamplerState` leaf gains a leading
    chain axis.

    Pass ``key`` + ``num_chains`` (chain ``c``'s key is exactly
    ``split(key, C)[c]`` — the spelling single-chain parity checks use) or
    explicit per-chain ``keys``.  ``jitter`` adds iid N(0, jitter^2)
    perturbations to each chain's start point (overdispersed starts make the
    early W2 trajectory an honest mixing diagnostic); the parity tests use
    ``jitter=0``.
    """
    if keys is None:
        if key is None or num_chains is None:
            raise ValueError("pass either `keys` or (`key`, `num_chains`)")
        keys = jax.random.split(key, num_chains)
        k_jitter = jax.random.fold_in(key, 0x6A17)
    else:
        k_jitter = jax.random.fold_in(keys[0], 0x6A17)  # distinct per key set
    num_chains = keys.shape[0]
    stacked = tree_broadcast_leading(params, num_chains)
    if jitter > 0.0:
        noise = tree_normal_like(k_jitter, stacked)
        stacked = jax.tree_util.tree_map(
            lambda x, n: x + jnp.asarray(jitter, x.dtype) * n.astype(x.dtype),
            stacked, noise)
    return jax.vmap(sampler.init)(stacked, keys)


#: fold_in tags separating the worker-attributed noise and coordinate-delay
#: streams (arbitrary distinct constants, fixed forever for reproducibility)
_WORKER_NOISE_TAG = 0x5747_4E01
_WORKER_DELAY_TAG = 0x5747_4401


def worker_keys(chain_key: jax.Array, worker_id: jax.Array,
                slot: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-commit ``(noise, coordinate-delay)`` keys derived from the chain
    key and the commit's ``(worker_id, worker-local slot)`` identity.

    Unlike the default sequential split off the carried chain key, this
    stream depends only on *which worker* made *its how-manieth* commit —
    permuting the global commit order (two simulations interleaving the same
    worker histories differently) permutes the noise draws with it instead
    of redrawing them, so each worker's noise stream is reproducible
    independently of commit order."""
    k_noise = jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(chain_key, _WORKER_NOISE_TAG),
                           worker_id), slot)
    k_delay = jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(chain_key, _WORKER_DELAY_TAG),
                           worker_id), slot)
    return k_noise, k_delay


def ensemble_step(sampler: Sampler, *, batch_axis: Optional[int] = None,
                  worker_rng: bool = False) -> Callable:
    """The population commit: ``step`` vmapped over (state, batch?, delay).

    ``batch_axis=None`` broadcasts one batch to every chain (chains then
    differ only through their keys and schedules — the parity configuration);
    ``batch_axis=0`` gives each chain its own minibatch.  With
    ``worker_rng`` the returned callable takes two extra per-chain arrays
    ``(worker_id, slot)`` and derives the per-commit keys with
    :func:`worker_keys` instead of the sequential split.
    """
    if worker_rng:
        def step_attributed(state, batch, delay, worker_id, slot):
            return sampler.step(state, batch, delay,
                                keys=worker_keys(state.key, worker_id, slot))

        return jax.vmap(step_attributed, in_axes=(0, batch_axis, 0, 0, 0))
    return jax.vmap(sampler.step, in_axes=(0, batch_axis, 0))


def chain_positions(tree: PyTree) -> jnp.ndarray:
    """Flatten per-chain params ``(C, ...)`` into the cloud ``(C, d)``."""
    leaves = jax.tree_util.tree_leaves(tree)
    c = leaves[0].shape[0]
    return jnp.concatenate(
        [x.reshape(c, -1).astype(jnp.float32) for x in leaves], axis=1)


def ensemble_w2(positions: jnp.ndarray, target_samples: jnp.ndarray, *,
                method: str = "auto", eps: float = 0.05,
                num_iters: int = 200) -> jnp.ndarray:
    """Empirical W2 between the chain cloud and target-posterior draws.

    ``auto`` picks the exact 1-D quantile estimator when both clouds are
    1-D with equal counts, else debiased Sinkhorn.  This replaces the
    single-chain moment-matched Gaussian proxy: no Gaussianity assumption,
    honest in any dimension.
    """
    positions = jnp.atleast_2d(positions)
    target_samples = jnp.atleast_2d(target_samples)
    if method == "auto":
        one_d = positions.shape[1] == 1 and target_samples.shape[1] == 1
        method = "1d" if one_d and positions.shape[0] == target_samples.shape[0] \
            else "sinkhorn"
    if method == "1d":
        return w2_empirical_1d(positions[:, 0], target_samples[:, 0])
    if method != "sinkhorn":
        raise ValueError(f"unknown W2 method {method!r}")
    return sinkhorn_w2(positions, target_samples, eps=eps, num_iters=num_iters)


# ---------------------------------------------------------------------------
# cross-chain convergence diagnostics: split-R-hat and ESS over the chain axis
# ---------------------------------------------------------------------------
@jax.jit
def split_rhat(draws: jnp.ndarray) -> jnp.ndarray:
    """Split-R-hat over the chain axis: ``draws (C, N, d) -> (d,)``.

    Each chain's N draws are split in half (2C sequences of N//2), then the
    classic Gelman-Rubin ratio of pooled-to-within variance — everything is
    a mean/variance over the chain and time axes, i.e. exactly the cheap
    psum-shaped reductions a sharded ensemble can afford every few commits.
    Splitting catches the failure plain R-hat misses: chains that agree in
    marginal law but are still drifting within themselves.
    """
    C, N, d = draws.shape
    if N < 4:
        raise ValueError(f"split-R-hat needs >= 4 draws per chain, got {N}")
    n = N // 2
    halves = jnp.concatenate([draws[:, :n], draws[:, n:2 * n]], axis=0)
    halves = halves.astype(jnp.float32)                      # (2C, n, d)
    means = jnp.mean(halves, axis=1)                         # (2C, d)
    within = jnp.mean(jnp.var(halves, axis=1, ddof=1), axis=0)
    between = n * jnp.var(means, axis=0, ddof=1)
    var_plus = (n - 1) / n * within + between / n
    return jnp.sqrt(var_plus / jnp.maximum(within, 1e-30))


@jax.jit
def ess(draws: jnp.ndarray) -> jnp.ndarray:
    """Bulk effective sample size over the chain axis:
    ``draws (C, N, d) -> (d,)``.

    The multi-chain (Vehtari/Stan) estimator: per-chain autocovariances via
    FFT, combined through ``rho_t = 1 - (W - mean acov_t) / var_plus`` —
    ``var_plus`` includes the *between*-chain variance, so chains stuck in
    different modes collapse the ESS even though each chain looks iid from
    the inside — with Geyer's initial-positive-sequence truncation.
    ``ESS ~= C*N`` for iid same-law draws; small under within-chain
    correlation or cross-chain disagreement.
    """
    C, N, d = draws.shape
    if N < 4:
        raise ValueError(f"ESS needs >= 4 draws per chain, got {N}")
    if C < 2:
        raise ValueError("multi-chain ESS needs >= 2 chains")
    x = draws.astype(jnp.float32)
    means = jnp.mean(x, axis=1, keepdims=True)
    xc = x - means
    # per-chain autocovariance by FFT (biased, standard for ESS)
    f = jnp.fft.rfft(xc, n=2 * N, axis=1)
    acov = jnp.fft.irfft(f * jnp.conj(f), n=2 * N, axis=1)[:, :N] / N
    mean_acov = jnp.mean(acov, axis=0)                       # (N, d)
    within = jnp.mean(acov[:, 0], axis=0) * N / (N - 1)      # W (d,)
    between_over_n = jnp.var(means[:, 0], axis=0, ddof=1)    # B/N (d,)
    var_plus = (N - 1) / N * within + between_over_n
    rho = 1.0 - (within - mean_acov) / jnp.maximum(var_plus, 1e-30)
    # Geyer: truncate at the first negative sum of adjacent pairs
    pairs = rho[0:2 * (N // 2):2] + rho[1:2 * (N // 2):2]    # (N//2, d)
    positive = jnp.cumprod(pairs > 0.0, axis=0)
    tau = -1.0 + 2.0 * jnp.sum(pairs * positive, axis=0)
    # antithetic draws can push tau toward 0/negative; cap super-efficiency
    # at C*N*log10(C*N) (Stan's bound) instead of letting 1/tau blow up
    cap = C * N * max(np.log10(C * N), 1.0)
    return jnp.minimum(C * N / jnp.maximum(tau, 1e-6), cap)


def healthy_chains(cloud: np.ndarray, state=None) -> np.ndarray:
    """``(C,)`` bool mask of chains fit for ensemble reductions.

    A chain qualifies when its ``cloud`` row (from
    :func:`chain_positions`) is all-finite *and* — when ``state`` carries
    the executor's sticky ``health`` mask
    (:class:`~repro.cluster.executor.HealthState`) — it is not
    quarantined.  The W2/R-hat/ESS recorders drop the complement so one
    diverged chain degrades the diagnostics instead of NaN-poisoning them.
    """
    ok = np.isfinite(np.asarray(cloud)).all(axis=1)
    health = getattr(state, "health", None)
    if health is not None:
        ok &= np.asarray(health)
    return ok


def diagnostics_recorder(*, every: int = 1, window: int = 64) -> Callable:
    """An Engine-style hook recording split-R-hat and ESS of the chain cloud
    next to :func:`w2_recorder`.

    Keeps a rolling window of the last ``window`` recorded clouds (one
    ``chain_positions`` snapshot per ``every`` commits, at chunk-boundary
    granularity like every Engine hook) and, once the window is full,
    reduces the ``(C, window, d)`` history on device — the fixed window
    keeps the jitted reductions at one trace.  ``flush`` emits a final row
    from however much history exists (>= 4 snapshots).  Rows land in
    ``hook.record`` as ``{"step", "rhat_max", "ess_min", "n_draws"}``
    (worst coordinate each, the scalars dashboards alarm on).
    """
    record: list[dict] = []
    history: list[np.ndarray] = []
    last = [-every]
    latest_health = [None]  # newest sticky quarantine mask, if the engine has one

    def measure(step_end: int) -> None:
        if len(history) < 4:  # too few snapshots for a split estimate
            return
        draws = jnp.stack(history, axis=1)  # (C, n, d)
        ok = np.isfinite(np.asarray(draws)).all(axis=(1, 2))
        if latest_health[0] is not None:
            ok &= latest_health[0]
        if not ok.all():
            if int(ok.sum()) < 2:  # cross-chain estimates need >= 2 chains
                return
            draws = draws[np.flatnonzero(ok)]
        row = {
            "step": step_end,
            "rhat_max": float(jnp.max(split_rhat(draws))),
            "ess_min": float(jnp.min(ess(draws))),
            "n_draws": int(draws.shape[1]),
        }
        record.append(row)
        reg = _registry()
        reg.gauge("cluster.rhat_max",
                  "worst-coordinate split R-hat of the chain cloud"
                  ).set(row["rhat_max"])
        reg.gauge("cluster.ess_min",
                  "worst-coordinate effective sample size"
                  ).set(row["ess_min"])

    def hook(step_end: int, state: SamplerState, _aux) -> None:
        health = getattr(state, "health", None)
        if health is not None:
            latest_health[0] = np.asarray(health)
        if step_end - last[0] < every:
            return
        last[0] = step_end
        cloud = np.asarray(chain_positions(state.params))
        if cloud.shape[0] < 2:  # fail on the FIRST call, not window fills later
            raise ValueError(
                "diagnostics_recorder needs an ensemble of >= 2 chains "
                f"(got {cloud.shape[0]})")
        history.append(cloud)
        if len(history) > window:
            del history[0]
        if len(history) == window:
            measure(step_end)

    def flush(step_end: int, state: SamplerState) -> None:
        health = getattr(state, "health", None)
        if health is not None:
            latest_health[0] = np.asarray(health)
        if not record or record[-1]["step"] < step_end:
            if step_end > last[0]:
                history.append(np.asarray(chain_positions(state.params)))
                if len(history) > window:
                    del history[0]
            measure(step_end)

    hook.record = record
    hook.flush = flush
    return hook


def w2_recorder(target_samples: jnp.ndarray, *, every: int = 1,
                **w2_kw) -> Callable:
    """A :class:`~repro.train.engine.Engine`-style hook measuring empirical
    W2 of the chain cloud every ``every`` commits.

    Rows land in ``hook.record`` as ``{"step", "w2", "commit_time",
    "grad_evals"}``; ``commit_time`` is the ensemble wall clock (max over
    chains) and ``grad_evals`` the cumulative gradient-evaluation count
    (mean over chains) when the executor threads them into the aux, else
    ``None``.
    """
    record: list[dict] = []
    last = [-every]
    seen_time = [None]   # newest commit time, even across skipped chunks
    seen_evals = [None]  # newest cumulative grad evals

    def measure(step_end: int, state: SamplerState) -> None:
        last[0] = step_end
        cloud = chain_positions(state.params)
        ok = healthy_chains(cloud, state)
        dropped = int(cloud.shape[0] - ok.sum())
        reg = _registry()
        if dropped:
            reg.gauge("chains.unhealthy",
                      "chains currently quarantined or non-finite"
                      ).set(float(dropped))
        if dropped == cloud.shape[0]:  # nothing servable left to measure
            w2 = float("nan")
        else:
            if dropped:
                cloud = cloud[np.flatnonzero(ok)]
            w2 = float(ensemble_w2(cloud, target_samples, **w2_kw))
        record.append({"step": step_end, "w2": w2,
                       "commit_time": seen_time[0],
                       "grad_evals": seen_evals[0]})
        reg.gauge(
            "cluster.w2", "newest empirical W2 of the chain cloud").set(w2)

    def hook(step_end: int, state: SamplerState, aux) -> None:
        if isinstance(aux, dict) and "commit_time" in aux:
            seen_time[0] = float(np.max(np.asarray(aux["commit_time"])[-1]))
        if isinstance(aux, dict) and "grad_evals" in aux:
            seen_evals[0] = float(np.mean(np.asarray(aux["grad_evals"])[-1]))
        if step_end - last[0] >= every:
            measure(step_end, state)

    def flush(step_end: int, state: SamplerState) -> None:
        if step_end > last[0]:  # cadence skipped the final chunk
            measure(step_end, state)

    hook.record = record
    hook.flush = flush
    return hook

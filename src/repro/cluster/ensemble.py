"""Vmapped chain ensembles: the whole sampler transform chain (including the
iterate :class:`~repro.core.delay.RingBuffer`) batched over C independent
chains, so one ``lax.scan`` step advances the entire population.

The paper's convergence claim is *in measure*: the law of the iterate
approaches the Gibbs posterior.  A single chain only exposes that law
through time averages (the moment-matched ``w2_to_gaussian`` proxy); a
C-chain ensemble exposes it directly — at any commit count the chain cloud
``(C, d)`` *is* a sample from the current law, and
:func:`ensemble_w2` measures empirical W2 against target-posterior draws
(``sinkhorn_w2``, or exact sorted quantiles in 1-D).

Every helper here is shape-transparent: chain ``c`` of the vmapped ensemble
computes bit-for-bit what an independent single-chain
:class:`~repro.samplers.base.Sampler` would with the same key and schedule
(asserted in ``tests/test_cluster.py``).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.metrics.wasserstein import sinkhorn_w2, w2_empirical_1d
from repro.samplers.base import Sampler, SamplerState
from repro.utils import tree_broadcast_leading, tree_normal_like

PyTree = Any


def init_ensemble(sampler: Sampler, params: PyTree, key: jax.Array | None = None,
                  *, num_chains: int | None = None,
                  keys: jax.Array | None = None,
                  jitter: float = 0.0) -> SamplerState:
    """Initialize C chains: every :class:`SamplerState` leaf gains a leading
    chain axis.

    Pass ``key`` + ``num_chains`` (chain ``c``'s key is exactly
    ``split(key, C)[c]`` — the spelling single-chain parity checks use) or
    explicit per-chain ``keys``.  ``jitter`` adds iid N(0, jitter^2)
    perturbations to each chain's start point (overdispersed starts make the
    early W2 trajectory an honest mixing diagnostic); the parity tests use
    ``jitter=0``.
    """
    if keys is None:
        if key is None or num_chains is None:
            raise ValueError("pass either `keys` or (`key`, `num_chains`)")
        keys = jax.random.split(key, num_chains)
        k_jitter = jax.random.fold_in(key, 0x6A17)
    else:
        k_jitter = jax.random.fold_in(keys[0], 0x6A17)  # distinct per key set
    num_chains = keys.shape[0]
    stacked = tree_broadcast_leading(params, num_chains)
    if jitter > 0.0:
        noise = tree_normal_like(k_jitter, stacked)
        stacked = jax.tree_util.tree_map(
            lambda x, n: x + jnp.asarray(jitter, x.dtype) * n.astype(x.dtype),
            stacked, noise)
    return jax.vmap(sampler.init)(stacked, keys)


#: fold_in tags separating the worker-attributed noise and coordinate-delay
#: streams (arbitrary distinct constants, fixed forever for reproducibility)
_WORKER_NOISE_TAG = 0x5747_4E01
_WORKER_DELAY_TAG = 0x5747_4401


def worker_keys(chain_key: jax.Array, worker_id: jax.Array,
                slot: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-commit ``(noise, coordinate-delay)`` keys derived from the chain
    key and the commit's ``(worker_id, worker-local slot)`` identity.

    Unlike the default sequential split off the carried chain key, this
    stream depends only on *which worker* made *its how-manieth* commit —
    permuting the global commit order (two simulations interleaving the same
    worker histories differently) permutes the noise draws with it instead
    of redrawing them, so each worker's noise stream is reproducible
    independently of commit order."""
    k_noise = jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(chain_key, _WORKER_NOISE_TAG),
                           worker_id), slot)
    k_delay = jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(chain_key, _WORKER_DELAY_TAG),
                           worker_id), slot)
    return k_noise, k_delay


def ensemble_step(sampler: Sampler, *, batch_axis: Optional[int] = None,
                  worker_rng: bool = False) -> Callable:
    """The population commit: ``step`` vmapped over (state, batch?, delay).

    ``batch_axis=None`` broadcasts one batch to every chain (chains then
    differ only through their keys and schedules — the parity configuration);
    ``batch_axis=0`` gives each chain its own minibatch.  With
    ``worker_rng`` the returned callable takes two extra per-chain arrays
    ``(worker_id, slot)`` and derives the per-commit keys with
    :func:`worker_keys` instead of the sequential split.
    """
    if worker_rng:
        def step_attributed(state, batch, delay, worker_id, slot):
            return sampler.step(state, batch, delay,
                                keys=worker_keys(state.key, worker_id, slot))

        return jax.vmap(step_attributed, in_axes=(0, batch_axis, 0, 0, 0))
    return jax.vmap(sampler.step, in_axes=(0, batch_axis, 0))


def chain_positions(tree: PyTree) -> jnp.ndarray:
    """Flatten per-chain params ``(C, ...)`` into the cloud ``(C, d)``."""
    leaves = jax.tree_util.tree_leaves(tree)
    c = leaves[0].shape[0]
    return jnp.concatenate(
        [x.reshape(c, -1).astype(jnp.float32) for x in leaves], axis=1)


def ensemble_w2(positions: jnp.ndarray, target_samples: jnp.ndarray, *,
                method: str = "auto", eps: float = 0.05,
                num_iters: int = 200) -> jnp.ndarray:
    """Empirical W2 between the chain cloud and target-posterior draws.

    ``auto`` picks the exact 1-D quantile estimator when both clouds are
    1-D with equal counts, else debiased Sinkhorn.  This replaces the
    single-chain moment-matched Gaussian proxy: no Gaussianity assumption,
    honest in any dimension.
    """
    positions = jnp.atleast_2d(positions)
    target_samples = jnp.atleast_2d(target_samples)
    if method == "auto":
        one_d = positions.shape[1] == 1 and target_samples.shape[1] == 1
        method = "1d" if one_d and positions.shape[0] == target_samples.shape[0] \
            else "sinkhorn"
    if method == "1d":
        return w2_empirical_1d(positions[:, 0], target_samples[:, 0])
    if method != "sinkhorn":
        raise ValueError(f"unknown W2 method {method!r}")
    return sinkhorn_w2(positions, target_samples, eps=eps, num_iters=num_iters)


def w2_recorder(target_samples: jnp.ndarray, *, every: int = 1,
                **w2_kw) -> Callable:
    """A :class:`~repro.train.engine.Engine`-style hook measuring empirical
    W2 of the chain cloud every ``every`` commits.

    Rows land in ``hook.record`` as ``{"step", "w2", "commit_time",
    "grad_evals"}``; ``commit_time`` is the ensemble wall clock (max over
    chains) and ``grad_evals`` the cumulative gradient-evaluation count
    (mean over chains) when the executor threads them into the aux, else
    ``None``.
    """
    record: list[dict] = []
    last = [-every]
    seen_time = [None]   # newest commit time, even across skipped chunks
    seen_evals = [None]  # newest cumulative grad evals

    def measure(step_end: int, state: SamplerState) -> None:
        last[0] = step_end
        w2 = float(ensemble_w2(chain_positions(state.params), target_samples,
                               **w2_kw))
        record.append({"step": step_end, "w2": w2,
                       "commit_time": seen_time[0],
                       "grad_evals": seen_evals[0]})

    def hook(step_end: int, state: SamplerState, aux) -> None:
        if isinstance(aux, dict) and "commit_time" in aux:
            seen_time[0] = float(np.max(np.asarray(aux["commit_time"])[-1]))
        if isinstance(aux, dict) and "grad_evals" in aux:
            seen_evals[0] = float(np.mean(np.asarray(aux["grad_evals"])[-1]))
        if step_end - last[0] >= every:
            measure(step_end, state)

    def flush(step_end: int, state: SamplerState) -> None:
        if step_end > last[0]:  # cadence skipped the final chunk
            measure(step_end, state)

    hook.record = record
    hook.flush = flush
    return hook

"""Posterior-predictive serving from the sharded chain bank.

A converged :class:`~repro.cluster.executor.ClusterEngine` ensemble is a
device-resident cloud of posterior samples — exactly what the paper's
convergence-in-measure guarantee promises.  The practical payoff (as in
Chen et al.'s stale-gradient SG-MCMC) is Bayesian model averaging at
prediction time: :class:`ServeEngine` answers batched predictive queries
straight from the chain axis — ensemble-averaged forward passes, per-query
credible intervals/quantiles, and predictive variance — without ever
gathering the parameter bank to host.

Collective layout (``mesh=``): the bank stays sharded over ``chain_axis``
and the query batch is replicated; each shard vmaps the model forward over
its local chains, then only the per-chain *predictions* ``(C, Q, ...)`` —
a model-size-independent block — cross the shards via ``all_gather`` before
every shard reduces them to the final per-query statistics.  The reduction
runs on the gathered block with exactly the ops the single-device path
uses (sorted quantiles included), so sharded and unsharded statistics are
bitwise-identical — asserted in ``tests/test_serve.py``.  A psum-of-partial-
sums mean would save the gather but floats add non-associatively, which
would silently break that parity contract.

Request batching is shape-bucketed: query counts are padded up a bucket
ladder (powers of two by default) by edge-replicating the last query, so a
mixed request stream compiles **one trace per bucket** and the padded query
buffer — created fresh per request — is donated to the jitted call.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis.instrument import Counters as _Counters, counters as _counters
from repro.obs.metrics import LATENCY_MS_BUCKETS, registry as _registry
from repro.obs.trace import now as _now, span as _span
from repro.samplers.base import SamplerState
from repro.utils import SHARD_MAP_CHECK_KW, bucket_size, shard_map

PyTree = Any
#: per-chain forward: (single-chain params, queries (Q, ...)) -> preds (Q, ...)
PredictFn = Callable[[PyTree, PyTree], jax.Array]


class ServeResult(NamedTuple):
    """Per-query predictive statistics over the chain axis.

    ``mean``/``var`` are ``(Q, ...)`` (ensemble average and population
    variance of the per-chain predictions); ``quantiles`` is
    ``(len(qs), Q, ...)`` in the order the engine's ``quantiles`` were
    given — ``result.quantiles[0]``/``[-1]`` bracket the credible interval
    for the default ``(0.05, 0.5, 0.95)``.
    """

    mean: jax.Array
    var: jax.Array
    quantiles: jax.Array

    @property
    def std(self):
        """Posterior-predictive standard deviation, ``sqrt(var)`` in
        whichever array namespace ``var`` lives in."""
        if isinstance(self.var, np.ndarray):
            return np.sqrt(self.var)
        return jnp.sqrt(self.var)


def predictive_stats(preds: jax.Array, qs: jax.Array) -> ServeResult:
    """Reduce per-chain predictions ``(C, Q, ...)`` to per-query statistics.

    The single source of truth for the reduction: the sharded path calls it
    on the all-gathered prediction block, the single-device path on the
    vmapped output, so the two are bitwise-identical by construction.
    """
    mean = jnp.mean(preds, axis=0)
    var = jnp.mean(jnp.square(preds - mean), axis=0)
    quantiles = jnp.quantile(preds, qs, axis=0)
    return ServeResult(mean=mean, var=var, quantiles=quantiles)


# `bucket_size` is re-exported here (and from repro.cluster) for backwards
# compatibility; the ladder lives in repro.utils because the heterogeneous-
# minibatch schedule compiler applies the same one-trace-per-rung discipline
# to training batches.


class HostScratch:
    """Reusable host-side pad buffers, one per (bucket rung, leaf).

    Padding a request up its bucket rung is shape-varying glue that must
    stay in numpy on the serving hot path — but a fresh ``np.concatenate``
    per request still allocates (and touches) a buffer every call.  This
    keeps one scratch array per ``(rung, leaf key, trailing shape, dtype)``
    and rewrites it in place, so a steady-state request stream performs
    **zero** per-request allocations on the padding path (``allocs`` stops
    growing once every rung has been seen — asserted by the serve/decode
    benches).  Reuse is safe because ``jit`` copies host arrays to device
    synchronously at dispatch.

    Every buffer creation is reported to ``counters``
    (a :class:`repro.analysis.instrument.Counters` handle) when one is
    given, so an :func:`~repro.analysis.instrument.instrument` region around
    a warm request stream sees zero pad-alloc events.
    """

    def __init__(self, counters: Optional[_Counters] = None):
        self._bufs: dict = {}
        self.allocs = 0  # scratch-buffer creations, NOT per-request work
        self._counters = counters

    def get(self, key, shape, dtype) -> np.ndarray:
        """The scratch buffer for ``key`` (caller fills it)."""
        k = (key, tuple(shape), np.dtype(dtype).str)
        buf = self._bufs.get(k)
        if buf is None:
            buf = np.empty(shape, dtype)
            self._bufs[k] = buf
            self.allocs += 1
            if self._counters is not None:
                self._counters.pad_alloc()
        return buf

    def pad(self, x: np.ndarray, n: int, key=0) -> np.ndarray:
        """``x`` with its leading axis padded to ``n`` by edge-replicating
        the last row, written into the reused scratch."""
        q = x.shape[0]
        if q == n:
            return x  # jit transfers host arrays; caller's buffer intact
        buf = self.get(("pad", key), (n,) + x.shape[1:], x.dtype)
        buf[:q] = x
        buf[q:] = x[-1:]
        return buf


def _pad_queries(queries: PyTree, n: int, *, copy_exact: bool,
                 scratch: HostScratch) -> PyTree:
    """Pad every leaf's leading (query) axis to ``n`` by edge-replicating the
    last query.  ``copy_exact`` shields an exact-bucket-size device array
    behind a copy so a donating engine never consumes the caller's buffer;
    a non-donating engine skips that copy on its hot path.

    Host (numpy) queries — the common serving entry point — are padded
    with numpy: unlike an eager ``jnp.concatenate``, that compiles nothing,
    so a stream of distinct request sizes stays at one XLA program per
    *bucket* instead of one pad program per *size*; the pad writes into the
    engine's per-rung ``scratch`` instead of allocating per request.
    """
    leaves, treedef = jax.tree_util.tree_flatten(queries)
    out = []
    for i, x in enumerate(leaves):
        if not isinstance(x, jax.Array):  # host query: numpy pad, no compile
            out.append(scratch.pad(np.asarray(x), n, key=i))
            continue
        extra = n - x.shape[0]
        if extra == 0:
            # only a donating engine needs to shield the caller's buffer
            out.append(x.copy() if copy_exact else x)
        else:
            out.append(jnp.concatenate(
                [x, jnp.broadcast_to(x[-1:], (extra,) + x.shape[1:])], axis=0))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class ServeEngine:
    """Batched posterior-predictive serving over a chain-stacked parameter
    bank.

    ``predict_fn(params, queries) -> preds`` is the *single-chain* forward
    (leading query axis in and out); ``params`` is the chain-stacked bank
    ``(C, ...)`` — a :class:`ClusterEngine` state's params, or anything
    :func:`~repro.checkpoint.restore_ensemble` produces.  With ``mesh=`` the
    bank is sharded over ``chain_axis`` and only per-chain predictions cross
    the shards (see module docstring).

    ``donate`` hands the padded query buffer to the jitted call.  Donation
    only pays off when a query leaf can alias a float statistic buffer; for
    dtypes that never can (e.g. int token batches) set ``donate=False`` to
    skip the exact-bucket shield copy and jax's unusable-donation warning.
    """

    predict_fn: PredictFn
    params: PyTree
    quantiles: Sequence[float] = (0.05, 0.5, 0.95)
    buckets: Optional[Sequence[int]] = None
    mesh: Any = None
    chain_axis: str = "data"
    donate: bool = True

    def __post_init__(self):
        leaves = jax.tree_util.tree_leaves(self.params)
        if not leaves:
            raise ValueError("params bank is empty")
        self.num_chains = int(leaves[0].shape[0])
        self._counters = _counters("ServeEngine")
        self._host_scratch = HostScratch(self._counters)
        reg = _registry()
        self._m_requests = reg.counter("serve.requests", "serve() calls")
        self._m_queries = reg.counter("serve.queries",
                                      "queries answered (pre-padding)")
        self._m_latency = reg.histogram(
            "serve.request_ms", LATENCY_MS_BUCKETS,
            "serve() wall time per request, result on host")
        self._m_util = reg.gauge(
            "serve.bucket_utilization",
            "last request's Q / padded bucket size")
        if self.buckets is not None:
            self.buckets = sorted(int(b) for b in self.buckets)
        self._qs = jnp.asarray(self.quantiles, jnp.float32)
        if self.mesh is not None:
            n_shards = self.mesh.shape[self.chain_axis]
            if self.num_chains % n_shards:
                raise ValueError(
                    f"num_chains={self.num_chains} must be divisible by mesh "
                    f"axis {self.chain_axis!r} (size {n_shards})")
            sharding = jax.sharding.NamedSharding(self.mesh, P(self.chain_axis))
            self.params = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, sharding), self.params)
        self._stats = jax.jit(self._build_stats(),
                              donate_argnums=(1,) if self.donate else ())

    def _build_stats(self):
        forward = jax.vmap(self.predict_fn, in_axes=(0, None))

        def stats(params, queries):
            # python side effect: runs once per trace, never per call
            self._counters.trace("stats")
            return predictive_stats(forward(params, queries), self._qs)

        if self.mesh is None:
            return stats
        ax = self.chain_axis

        def sharded_stats(params, queries):
            self._counters.trace("sharded_stats")

            def body(p, q):
                local = forward(p, q)  # (C/shards, Q, ...)
                preds = jax.lax.all_gather(local, ax, axis=0, tiled=True)
                return predictive_stats(preds, self._qs)

            return shard_map(body, mesh=self.mesh, in_specs=(P(ax), P()),
                             out_specs=P(), **SHARD_MAP_CHECK_KW)(
                                 params, queries)

        return sharded_stats

    @property
    def num_traces(self) -> int:
        """Jit traces so far (one per shape bucket) — a thin view over the
        engine's :mod:`repro.analysis.instrument` counters."""
        return self._counters.traces

    @property
    def num_host_pad_allocs(self) -> int:
        """Host scratch-buffer creations so far — one per (bucket rung,
        query leaf), NOT one per request; the serve bench asserts this stops
        growing once the stream's rungs have all been seen.  A thin view
        over the engine's :mod:`repro.analysis.instrument` counters."""
        return self._counters.pad_allocs

    # -- streaming ------------------------------------------------------------
    def decoder(self, model, **kw) -> "Any":
        """Streaming entrypoint: a :class:`~repro.cluster.decode.DecodeEngine`
        over the *same* bank, mesh, and bucket ladder — single-shot
        predictive queries and multi-token BMA generation served from one
        restored checkpoint.  ``model`` is the
        :class:`~repro.models.transformer.Model` the bank parameterizes;
        extra ``kw`` (``max_seq``, ``fused``, ...) pass through.
        """
        from repro.cluster.decode import DecodeEngine

        kw.setdefault("buckets", self.buckets)
        kw.setdefault("mesh", self.mesh)
        kw.setdefault("chain_axis", self.chain_axis)
        return DecodeEngine(model=model, params=self.params, **kw)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_cluster(cls, state: SamplerState | PyTree,
                     predict_fn: PredictFn, **kw) -> "ServeEngine":
        """Serve directly from a (possibly still sharded) ClusterEngine
        state — or any chain-stacked params pytree."""
        params = state.params if isinstance(state, SamplerState) else state
        return cls(predict_fn=predict_fn, params=params, **kw)

    @classmethod
    def from_checkpoint(cls, path: str, like: PyTree, predict_fn: PredictFn,
                        *, num_chains: Optional[int] = None,
                        **kw) -> "ServeEngine":
        """Restore a bank saved by :meth:`ClusterEngine.save_ensemble` (or
        broadcast a single-model checkpoint to ``num_chains``) and serve it.
        ``like`` is the *single-chain* params structure."""
        from repro.checkpoint import restore_ensemble

        params = restore_ensemble(path, like, num_chains=num_chains)
        return cls(predict_fn=predict_fn, params=params, **kw)

    # -- serving --------------------------------------------------------------
    def serve(self, queries: PyTree) -> ServeResult:
        """Answer one batched predictive request.

        ``queries`` leaves share a leading query axis ``Q``; the batch is
        padded to its shape bucket and pushed through the
        traced-once-per-bucket jitted reduction.  Returns a
        :class:`ServeResult` of *host* (numpy) per-query statistics — this
        is the serving boundary, and trimming the padding on host keeps a
        stream of distinct request sizes from compiling one slice program
        per ``(bucket, Q)`` pair.
        """
        q = int(jax.tree_util.tree_leaves(queries)[0].shape[0])
        n = bucket_size(q, self.buckets)
        t0 = _now()
        with _span("serve.request", Q=q, bucket=n):
            padded = _pad_queries(queries, n, copy_exact=self.donate,
                                  scratch=self._host_scratch)
            res = self._stats(self.params, padded)
            mean, var, quantiles = (np.asarray(x) for x in res)
        self._m_requests.inc()
        self._m_queries.inc(q)
        self._m_latency.observe((_now() - t0) * 1e3)
        self._m_util.set(q / n)
        return ServeResult(mean=mean[:q], var=var[:q],
                           quantiles=quantiles[:, :q])

    __call__ = serve

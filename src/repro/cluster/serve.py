"""Posterior-predictive serving from the sharded chain bank.

A converged :class:`~repro.cluster.executor.ClusterEngine` ensemble is a
device-resident cloud of posterior samples — exactly what the paper's
convergence-in-measure guarantee promises.  The practical payoff (as in
Chen et al.'s stale-gradient SG-MCMC) is Bayesian model averaging at
prediction time: :class:`ServeEngine` answers batched predictive queries
straight from the chain axis — ensemble-averaged forward passes, per-query
credible intervals/quantiles, and predictive variance — without ever
gathering the parameter bank to host.

Collective layout (``mesh=``): the bank stays sharded over ``chain_axis``
and the query batch is replicated; each shard vmaps the model forward over
its local chains, then only the per-chain *predictions* ``(C, Q, ...)`` —
a model-size-independent block — cross the shards via ``all_gather`` before
every shard reduces them to the final per-query statistics.  The reduction
runs on the gathered block with exactly the ops the single-device path
uses (sorted quantiles included), so sharded and unsharded statistics are
bitwise-identical — asserted in ``tests/test_serve.py``.  A psum-of-partial-
sums mean would save the gather but floats add non-associatively, which
would silently break that parity contract.

Request batching is shape-bucketed: query counts are padded up a bucket
ladder (powers of two by default) by edge-replicating the last query, so a
mixed request stream compiles **one trace per bucket** and the padded query
buffer — created fresh per request — is donated to the jitted call.

Since PR 9 the engine is also a request-level
:class:`~repro.cluster.api.Endpoint`: ``submit()`` enqueues individual
:class:`~repro.cluster.api.Request` queries and ``drain()`` batches
compatible ones back through the bucketed program above.  ``serve()`` is a
thin shim over that path and stays bitwise-identical to the pre-PR-9
batch-level API (pinned in ``tests/test_api.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.cluster.api import (
    FINISH_QUERY,
    BankEngine,
    Completion,
    HostScratch,
    Request,
)
from repro.obs.metrics import LATENCY_MS_BUCKETS, registry as _registry
from repro.obs.trace import now as _now, span as _span
from repro.utils import bucket_size

__all__ = [
    "HostScratch",  # moved to repro.cluster.api in PR 9; re-exported here
    "PredictFn",
    "ServeEngine",
    "ServeResult",
    "bucket_size",
    "predictive_stats",
]

PyTree = Any
#: per-chain forward: (single-chain params, queries (Q, ...)) -> preds (Q, ...)
PredictFn = Callable[[PyTree, PyTree], jax.Array]


class ServeResult(NamedTuple):
    """Per-query predictive statistics over the chain axis.

    ``mean``/``var`` are ``(Q, ...)`` (ensemble average and population
    variance of the per-chain predictions); ``quantiles`` is
    ``(len(qs), Q, ...)`` in the order the engine's ``quantiles`` were
    given — ``result.quantiles[0]``/``[-1]`` bracket the credible interval
    for the default ``(0.05, 0.5, 0.95)``.
    """

    mean: jax.Array
    var: jax.Array
    quantiles: jax.Array

    @property
    def std(self):
        """Posterior-predictive standard deviation, ``sqrt(var)`` in
        whichever array namespace ``var`` lives in."""
        if isinstance(self.var, np.ndarray):
            return np.sqrt(self.var)
        return jnp.sqrt(self.var)


def predictive_stats(preds: jax.Array, qs: jax.Array) -> ServeResult:
    """Reduce per-chain predictions ``(C, Q, ...)`` to per-query statistics.

    The single source of truth for the reduction: the sharded path calls it
    on the all-gathered prediction block, the single-device path on the
    vmapped output, so the two are bitwise-identical by construction.
    """
    mean = jnp.mean(preds, axis=0)
    var = jnp.mean(jnp.square(preds - mean), axis=0)
    quantiles = jnp.quantile(preds, qs, axis=0)
    return ServeResult(mean=mean, var=var, quantiles=quantiles)


# `bucket_size` is re-exported here (and from repro.cluster) for backwards
# compatibility; the ladder lives in repro.utils because the heterogeneous-
# minibatch schedule compiler applies the same one-trace-per-rung discipline
# to training batches.


def _pad_queries(queries: PyTree, n: int, *, copy_exact: bool,
                 scratch: HostScratch) -> PyTree:
    """Pad every leaf's leading (query) axis to ``n`` by edge-replicating the
    last query.  ``copy_exact`` shields an exact-bucket-size device array
    behind a copy so a donating engine never consumes the caller's buffer;
    a non-donating engine skips that copy on its hot path.

    Host (numpy) queries — the common serving entry point — are padded
    with numpy: unlike an eager ``jnp.concatenate``, that compiles nothing,
    so a stream of distinct request sizes stays at one XLA program per
    *bucket* instead of one pad program per *size*; the pad writes into the
    engine's per-rung ``scratch`` instead of allocating per request.
    """
    leaves, treedef = jax.tree_util.tree_flatten(queries)
    out = []
    for i, x in enumerate(leaves):
        if not isinstance(x, jax.Array):  # host query: numpy pad, no compile
            out.append(scratch.pad(np.asarray(x), n, key=i))
            continue
        extra = n - x.shape[0]
        if extra == 0:
            # only a donating engine needs to shield the caller's buffer
            out.append(x.copy() if copy_exact else x)
        else:
            out.append(jnp.concatenate(
                [x, jnp.broadcast_to(x[-1:], (extra,) + x.shape[1:])], axis=0))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class ServeEngine(BankEngine):
    """Batched posterior-predictive serving over a chain-stacked parameter
    bank.

    ``predict_fn(params, queries) -> preds`` is the *single-chain* forward
    (leading query axis in and out); ``params`` is the chain-stacked bank
    ``(C, ...)`` — a :class:`ClusterEngine` state's params, or anything
    :func:`~repro.checkpoint.restore_ensemble` produces.  With ``mesh=`` the
    bank is sharded over ``chain_axis`` and only per-chain predictions cross
    the shards (see module docstring).

    ``donate`` hands the padded query buffer to the jitted call.  Donation
    only pays off when a query leaf can alias a float statistic buffer; for
    dtypes that never can (e.g. int token batches) set ``donate=False`` to
    skip the exact-bucket shield copy and jax's unusable-donation warning.
    """

    predict_fn: PredictFn
    params: PyTree
    quantiles: Sequence[float] = (0.05, 0.5, 0.95)
    buckets: Optional[Sequence[int]] = None
    mesh: Any = None
    chain_axis: str = "data"
    donate: bool = True

    _FRONT_FIELD = "predict_fn"

    def __post_init__(self):
        self._init_bank("ServeEngine")
        reg = _registry()
        self._m_requests = reg.counter("serve.requests", "serve() calls")
        self._m_queries = reg.counter("serve.queries",
                                      "queries answered (pre-padding)")
        self._m_latency = reg.histogram(
            "serve.request_ms", LATENCY_MS_BUCKETS,
            "serve() wall time per request, result on host")
        self._m_util = reg.gauge(
            "serve.bucket_utilization",
            "last request's Q / padded bucket size")
        self._qs = jnp.asarray(self.quantiles, jnp.float32)
        self._shard_bank()
        self._stats = jax.jit(self._build_stats(),
                              donate_argnums=(1,) if self.donate else ())

    def _build_stats(self):
        forward = jax.vmap(self.predict_fn, in_axes=(0, None))
        ax = self.chain_axis

        def stats(reduce, params, queries):
            # python side effect: runs once per trace, never per call
            self._counters.trace("stats")
            return reduce(forward(params, queries))

        return self._wrap_bma(
            stats, in_specs=(P(ax), P()), out_specs=P(),
            reduce_full=lambda preds: predictive_stats(preds, self._qs))

    # -- streaming ------------------------------------------------------------
    def decoder(self, model, **kw) -> "Any":
        """Streaming entrypoint: a :class:`~repro.cluster.decode.DecodeEngine`
        over the *same* bank, mesh, and bucket ladder — single-shot
        predictive queries and multi-token BMA generation served from one
        restored checkpoint.  ``model`` is the
        :class:`~repro.models.transformer.Model` the bank parameterizes;
        extra ``kw`` (``max_seq``, ``fused``, ...) pass through.
        """
        from repro.cluster.decode import DecodeEngine

        kw.setdefault("buckets", self.buckets)
        kw.setdefault("mesh", self.mesh)
        kw.setdefault("chain_axis", self.chain_axis)
        return DecodeEngine(model=model, params=self.params, **kw)

    # -- request-level endpoint -----------------------------------------------
    def _validate_request(self, request: Request) -> None:
        if request.max_new_tokens:
            raise ValueError(
                "ServeEngine answers single-shot predictive queries; a "
                f"Request with max_new_tokens="
                f"{request.max_new_tokens} belongs on a decode engine")

    def _drain(self, requests):
        """Group pending single-query requests by structure (treedef +
        per-leaf trailing shape/dtype), stack each group into one batched
        :meth:`_serve_batch` call, and hand every request its row of the
        statistics back as a :class:`~repro.cluster.api.Completion` (in
        ``stats``, as a per-query :class:`ServeResult` view)."""
        groups: dict = {}
        prepped = []
        for r in requests:
            leaves, treedef = jax.tree_util.tree_flatten(r.tokens)
            arrs = [np.asarray(x) for x in leaves]
            sig = (treedef, tuple((a.shape, a.dtype.str) for a in arrs))
            groups.setdefault(sig, []).append((r, arrs))
            prepped.append(sig)
        out = {}
        for sig in dict.fromkeys(prepped):  # first-submission order
            rows = groups[sig]
            treedef = sig[0]
            stacked = [np.stack([arrs[i] for _, arrs in rows])
                       for i in range(len(rows[0][1]))]
            res = self._serve_batch(
                jax.tree_util.tree_unflatten(treedef, stacked))
            t_done = _now()
            for i, (r, _) in enumerate(rows):
                r.timing["finished"] = t_done
                out[r.request_id] = Completion(
                    request_id=r.request_id,
                    tokens=np.zeros((0,), np.int32), logits=None,
                    finish_reason=FINISH_QUERY, timing=r.timing,
                    stats=ServeResult(mean=res.mean[i], var=res.var[i],
                                      quantiles=res.quantiles[:, i]))
        return [out[r.request_id] for r in requests]

    # -- serving --------------------------------------------------------------
    def _serve_batch(self, queries: PyTree) -> ServeResult:
        """The batch-level program: pad one query batch to its bucket, run
        the traced-once-per-bucket jitted reduction, trim on host."""
        q = int(jax.tree_util.tree_leaves(queries)[0].shape[0])
        n = bucket_size(q, self.buckets)
        t0 = _now()
        with _span("serve.request", Q=q, bucket=n):
            padded = _pad_queries(queries, n, copy_exact=self.donate,
                                  scratch=self._host_scratch)
            res = self._stats(self.params, padded)
            mean, var, quantiles = (np.asarray(x) for x in res)
        self._m_requests.inc()
        self._m_queries.inc(q)
        self._m_latency.observe((_now() - t0) * 1e3)
        self._m_util.set(q / n)
        return ServeResult(mean=mean[:q], var=var[:q],
                           quantiles=quantiles[:, :q])

    def serve(self, queries: PyTree) -> ServeResult:
        """Answer one batched predictive request.

        ``queries`` leaves share a leading query axis ``Q``; the batch is
        split into per-query :class:`~repro.cluster.api.Request`\\ s,
        submitted, and drained — the drain stacks them straight back into
        one bucketed batch, so the result is bitwise-identical to the
        pre-PR-9 batch-level path.  Returns a :class:`ServeResult` of
        *host* (numpy) per-query statistics — this is the serving boundary,
        and trimming the padding on host keeps a stream of distinct request
        sizes from compiling one slice program per ``(bucket, Q)`` pair.
        """
        leaves, treedef = jax.tree_util.tree_flatten(queries)
        arrs = [np.asarray(x) for x in leaves]
        q = int(arrs[0].shape[0])
        ids = [self.submit(Request(tokens=jax.tree_util.tree_unflatten(
            treedef, [a[i] for a in arrs]))) for i in range(q)]
        by_id = {c.request_id: c for c in self.drain()}
        rows = [by_id[i].stats for i in ids]
        return ServeResult(
            mean=np.stack([r.mean for r in rows]),
            var=np.stack([r.var for r in rows]),
            quantiles=np.stack([r.quantiles for r in rows], axis=1))

    __call__ = serve

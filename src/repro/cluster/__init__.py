"""repro.cluster — device-parallel multi-chain async-SGLD execution.

The paper's P asynchronous workers, made executable on device: compiled
per-worker commit schedules (:mod:`~repro.cluster.schedule`), a vmapped
C-chain ensemble of the full sampler transform chain
(:mod:`~repro.cluster.ensemble`), the :class:`ClusterEngine` scan-chunk
executor that shards chains over a mesh's ``data`` axis
(:mod:`~repro.cluster.executor`), and the :class:`ServeEngine` that answers
posterior-predictive queries straight from the sharded chain bank
(:mod:`~repro.cluster.serve`).

Serving has a request-level front door (:mod:`~repro.cluster.api`):
:class:`Request`/:class:`Completion` + ``submit()``/``drain()`` shared by
every engine, and :class:`PagedDecodeEngine`
(:mod:`~repro.cluster.paged`) — continuous batching over a paged KV bank
with slot-level admission.

Faults are part of the contract (see :mod:`repro.faults`): chaos schedules
compile per-commit liveness masks, :class:`HealthState` carries the sticky
per-chain quarantine mask, and deadline-aware shedding degrades serving
instead of stalling it.
"""

from repro.cluster.api import (  # noqa: F401
    BankEngine,
    Completion,
    Endpoint,
    QueueFullError,
    Request,
)
from repro.cluster.ensemble import (  # noqa: F401
    chain_positions,
    diagnostics_recorder,
    ensemble_step,
    ensemble_w2,
    ess,
    healthy_chains,
    init_ensemble,
    split_rhat,
    w2_recorder,
    worker_keys,
)
from repro.cluster.decode import DecodeEngine, DecodeResult  # noqa: F401
from repro.cluster.executor import (  # noqa: F401
    BATCH_POLICIES,
    ClusterEngine,
    HealthState,
)
from repro.cluster.paged import PagedDecodeEngine, PageAllocator  # noqa: F401
from repro.cluster.serve import (  # noqa: F401
    HostScratch,
    ServeEngine,
    ServeResult,
    bucket_size,
    predictive_stats,
)
from repro.cluster.schedule import (  # noqa: F401
    StalenessError,
    WorkerSchedule,
    ensemble_async,
    stack_batch_info,
    stack_liveness,
    stack_schedules,
    stack_worker_info,
)

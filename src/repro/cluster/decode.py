"""Streaming Bayesian-model-averaged decoding from the sharded chain bank.

The paper's bet is that delayed-gradient SGLD buys wall-clock without
hurting convergence in measure; serving makes the same bet at inference
time.  A converged :class:`~repro.cluster.executor.ClusterEngine` bank is C
posterior samples of one transformer — the stale-chain ensemble of Chen et
al.'s SG-MCMC predictive — and :class:`DecodeEngine` streams multi-token
generations whose every token is drawn from the *Bayesian model average*
over the bank: per token, each chain runs one cached decode step, the
per-chain logits are reduced to the posterior-predictive token law
(:func:`~repro.models.predictive.bma_logits`), and the sampled/argmaxed
token feeds back into every chain's cache.

Hot-path discipline (the decode loop is the hottest per-token path in the
system):

- **KV-cache bank**: one per-chain decode cache per batch bucket rung,
  allocated once (``Model.init_cache_bank`` — every leaf gains the leading
  chain axis), donated to the jitted program and updated in place across
  serve steps.  No per-request cache allocation.  Rungs live in an LRU
  (capped at ``max_cache_rungs``): an adversarial mix of batch sizes evicts
  the coldest rung's bank instead of growing device memory without bound.
- **One trace per (bucket, max_new_tokens)**: prompts are padded up the
  shared bucket ladder in both batch and length (numpy scratch, reused per
  rung), the true ``prompt_len`` rides along as a traced scalar, and the
  whole prefill + ``lax.scan`` decode loop compiles exactly once per
  ``(B rung, T rung, max_new_tokens)`` triple.  No per-token dispatch from
  Python: the scan *is* the token loop.
- **Collective layout** (``mesh=``): the bank shards over ``chain_axis``;
  each shard vmaps the cached single-token forward over its local chains
  and only the ``(C, B, V)`` logit block crosses shards via ``all_gather``
  each token, after which every shard runs the identical replicated BMA
  reduce + argmax — so sharded and unsharded decode are bitwise-equal (the
  serve-module parity contract) and every shard feeds the same token back.
- **2-D banks** (``shard_params=True``): the chain axis composes with the
  repo's ``model``-axis tensor-parallel parameter sharding
  (:func:`~repro.models.common.partition_tree` with the chain axis
  prepended) under GSPMD, with the logit block constrained replicated
  before the same BMA reduce — a (chains x tensor-parallel) bank of large
  models streams without gathering parameters anywhere.  Tensor-parallel
  contractions psum over shards, so this path trades the bitwise guarantee
  for HBM headroom; the chain-sharded ``shard_map`` path keeps it.

Since PR 9 the engine is also a request-level
:class:`~repro.cluster.api.Endpoint`: ``submit()`` enqueues individual
prompt :class:`~repro.cluster.api.Request`\\ s and ``drain()`` stacks
compatible ones (same prompt length, budget, and key) back through the
bucketed batch program.  ``generate()`` is a thin shim over that path and
stays bitwise-identical to the pre-PR-9 batch-level API (pinned in
``tests/test_api.py``).  For slot-level continuous batching — admission
the moment any sequence finishes — see
:class:`~repro.cluster.paged.PagedDecodeEngine`.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.cluster.api import (
    FINISH_LENGTH,
    BankEngine,
    Completion,
    Request,
)
from repro.obs.metrics import LATENCY_MS_BUCKETS, registry as _registry
from repro.obs.trace import now as _now, span as _span
from repro.utils import bucket_size

PyTree = Any


class DecodeResult(NamedTuple):
    """One streamed generation: ``tokens`` is ``(B, max_new_tokens)`` int32
    on host; ``logits`` is the per-token BMA log-probability block
    ``(B, max_new_tokens, V)`` when the engine was built with
    ``return_logits=True``, else ``None``."""

    tokens: np.ndarray
    logits: Optional[np.ndarray]


@dataclass
class DecodeEngine(BankEngine):
    """Streaming multi-token BMA generation over a chain-stacked bank.

    ``model`` is the :class:`~repro.models.transformer.Model` (or anything
    with a ``.cfg``) the bank parameterizes — the engine rebuilds its own
    serving copy (``remat=False``, fused decode per ``fused=``); ``params``
    is the chain-stacked bank ``(C, ...)``.  ``generate(tokens, n)`` pads
    the prompt batch up the bucket ladder, prefills the rung's persistent
    KV-cache bank, and drives one scan-compiled decode loop; ``key=None``
    decodes greedily, a PRNG key samples from the BMA token law.
    ``max_cache_rungs`` caps how many batch rungs keep a resident KV bank
    (least-recently-used rung evicted beyond it).
    """

    model: Any
    params: PyTree
    max_seq: int = 256
    buckets: Optional[Sequence[int]] = None         # batch-size ladder
    prompt_buckets: Optional[Sequence[int]] = None  # prompt-length ladder
    mesh: Any = None
    chain_axis: str = "data"
    shard_params: bool = False
    fused: bool = False
    fused_interpret: Optional[bool] = None  # default: compiled only on TPU
    return_logits: bool = False
    max_cache_rungs: int = 8

    _FRONT_FIELD = "model"

    def __post_init__(self):
        from repro.models.transformer import Model

        self._init_bank("DecodeEngine")
        cfg = self.model.cfg if hasattr(self.model, "cfg") else self.model
        self._model = Model(cfg, mesh=None, remat=False,
                            decode_fused=self.fused,
                            decode_interpret=self.fused_interpret)
        self._model._require_stacked_attention("DecodeEngine")
        self._cache: OrderedDict = OrderedDict()  # B rung -> KV-cache bank
        reg = _registry()
        self._m_requests = reg.counter("decode.requests", "generate() calls")
        self._m_tokens = reg.counter("decode.tokens",
                                     "tokens generated (true batch rows)")
        self._m_token_ms = reg.histogram(
            "decode.per_token_ms", LATENCY_MS_BUCKETS,
            "request wall time / max_new_tokens (amortized; the decode "
            "loop is one fused scan)")
        self._m_batch_util = reg.gauge(
            "decode.batch_utilization", "last request's B / batch rung")
        self._m_bank_rungs = reg.gauge(
            "decode.bank_rungs", "KV-cache bank rungs resident")
        self._m_bank_evictions = reg.counter(
            "decode.bank_evictions",
            "KV-cache rungs dropped by the max_cache_rungs LRU cap")
        self._shard_bank()
        self._run = jax.jit(self._core, static_argnums=(0, 1),
                            donate_argnums=(3,))

    # -- the traced program ---------------------------------------------------
    def _core(self, max_new: int, greedy: bool, params, cache, tokens,
              prompt_len, key):
        # python side effect: runs once per (rung, max_new) trace
        self._counters.trace("decode")
        ax = self.chain_axis

        def body(reduce, params, cache, tokens, prompt_len, key):
            return self._stream(params, cache, tokens, prompt_len, key,
                                max_new, greedy, reduce=reduce)

        return self._wrap_bma(
            body, in_specs=(P(ax), P(ax), P(), P(), P()),
            out_specs=(P(), P(), P(ax)))(params, cache, tokens, prompt_len,
                                         key)

    def _stream(self, params, cache, tokens, prompt_len, key, max_new: int,
                greedy: bool, *, reduce):
        """Prefill the cache bank, then one ``lax.scan`` over the decode
        steps — traced exactly once per (bucket, max_new) pair."""
        model = self._model
        prefill = jax.vmap(model.prefill_cache, in_axes=(0, None, 0, None))
        last, cache = prefill(params, tokens, cache, prompt_len)  # (C, B, V)
        l0 = reduce(last)
        keys = jax.random.split(key, max_new)

        def select(logp, k):
            if greedy:
                return jnp.argmax(logp, axis=-1).astype(jnp.int32)
            return jax.random.categorical(k, logp, axis=-1).astype(jnp.int32)

        tok0 = select(l0, keys[0])  # (B,)
        decode = jax.vmap(model.serve_step, in_axes=(0, 0, None, None))
        want_logits = self.return_logits
        none = jnp.zeros((0,), jnp.float32)

        def step(carry, k_t):
            tok, pos, cache = carry
            per_chain, cache = decode(params, cache, tok[:, None], pos)
            logp = reduce(per_chain[:, :, 0])  # (B, V)
            nxt = select(logp, k_t)
            return (nxt, pos + 1, cache), (nxt, logp if want_logits else none)

        (_, _, cache), (toks, logps) = jax.lax.scan(
            step, (tok0, prompt_len, cache), keys[1:])
        tokens_out = jnp.concatenate([tok0[None], toks], axis=0).T
        if want_logits:
            logits_out = jnp.concatenate([l0[None], logps],
                                         axis=0).transpose(1, 0, 2)
        else:
            logits_out = none
        return tokens_out, logits_out, cache

    # -- KV-cache bank (LRU over batch rungs) ---------------------------------
    def _rung_cache(self, b_rung: int):
        cache = self._cache.pop(b_rung, None)
        if cache is None:
            cache = self._model.init_cache_bank(self.num_chains, b_rung,
                                                self.max_seq)
            if self.mesh is not None:
                cache = jax.device_put(
                    cache, NamedSharding(self.mesh, P(self.chain_axis)))
        return cache

    def _store_rung_cache(self, b_rung: int, cache) -> None:
        # pop-on-read + insert-on-write keeps the OrderedDict in recency
        # order, so the front is always the least-recently-used rung
        self._cache[b_rung] = cache
        while len(self._cache) > self.max_cache_rungs:
            self._cache.popitem(last=False)
            self._m_bank_evictions.inc()
        self._m_bank_rungs.set(float(len(self._cache)))

    # -- request-level endpoint -----------------------------------------------
    def _validate_request(self, request: Request) -> None:
        tokens = np.asarray(request.tokens)
        if tokens.ndim != 1:
            raise ValueError(
                f"a decode Request carries one 1-D prompt, got shape "
                f"{tokens.shape}")
        if request.max_new_tokens < 1:
            raise ValueError(
                f"need max_new_tokens >= 1, got {request.max_new_tokens}")
        t_rung = bucket_size(tokens.shape[0], self.prompt_buckets)
        if not self._model.cfg.sliding_window and \
                t_rung + request.max_new_tokens > self.max_seq:
            # under a sliding window the ring overwriting its oldest slot is
            # exactly the attention semantics; without one it would silently
            # drop real context from every remaining step
            raise ValueError(
                f"prompt rung {t_rung} + max_new_tokens "
                f"{request.max_new_tokens} overflows the {self.max_seq}-slot "
                "cache of a full-attention model; raise max_seq")
        request.tokens = tokens

    def _drain(self, requests):
        """Stack compatible pending prompts — same length, same budget, same
        sampling key — into batched :meth:`_generate_batch` calls (in first-
        submission order) and hand every request its row back as a
        :class:`~repro.cluster.api.Completion`."""
        groups: OrderedDict = OrderedDict()
        for r in requests:
            sig = (r.tokens.shape[0], int(r.max_new_tokens),
                   id(r.key) if r.key is not None else None)
            groups.setdefault(sig, []).append(r)
        out = {}
        for (_, max_new, _), rows in groups.items():
            batch = np.stack([r.tokens for r in rows])
            res = self._generate_batch(batch, max_new, rows[0].key)
            t_done = _now()
            for i, r in enumerate(rows):
                # batch engines deliver whole generations at drain: the
                # first token becomes host-visible when the batch does
                r.timing["first_token"] = r.timing["finished"] = t_done
                out[r.request_id] = Completion(
                    request_id=r.request_id, tokens=res.tokens[i],
                    logits=(res.logits[i] if res.logits is not None
                            else None),
                    finish_reason=FINISH_LENGTH, timing=r.timing)
        return [out[r.request_id] for r in requests]

    # -- serving --------------------------------------------------------------
    def _generate_batch(self, tokens: np.ndarray, max_new_tokens: int,
                        key: Optional[jax.Array]) -> DecodeResult:
        """The batch-level program: pad one (B, T) prompt batch up its rung
        pair, prefill the rung's persistent cache bank, run the scan-
        compiled decode loop, trim on host."""
        B, T = tokens.shape
        b_rung = bucket_size(B, self.buckets)
        t_rung = bucket_size(T, self.prompt_buckets)
        t_start = _now()
        with _span("decode.generate", B=B, T=T, b_rung=b_rung, t_rung=t_rung,
                   new_tokens=int(max_new_tokens), chains=self.num_chains):
            buf = self._scratch.get(("prompt", b_rung, t_rung),
                                    (b_rung, t_rung), np.int32)
            buf[:B, :T] = tokens
            buf[:B, T:] = tokens[:, -1:]  # right pad: causally invisible
            buf[B:] = buf[B - 1]          # edge-replicate padded batch rows
            cache = self._rung_cache(b_rung)
            greedy = key is None
            k = jnp.zeros((2,), jnp.uint32) if greedy else key
            toks, logps, cache = self._run(
                int(max_new_tokens), greedy, self.params, cache, buf,
                np.asarray(T, np.int32), k)
            self._store_rung_cache(b_rung, cache)  # donated in, reused next
            out = np.asarray(toks)[:B]  # blocks: the span sees real latency
        self._m_requests.inc()
        self._m_tokens.inc(B * int(max_new_tokens))
        self._m_token_ms.observe((_now() - t_start) * 1e3 / max_new_tokens)
        self._m_batch_util.set(B / b_rung)
        return DecodeResult(
            tokens=out,
            logits=np.asarray(logps)[:B] if self.return_logits else None)

    def generate(self, tokens, max_new_tokens: int,
                 key: Optional[jax.Array] = None) -> DecodeResult:
        """Stream ``max_new_tokens`` BMA tokens from a prompt batch.

        ``tokens`` is a host or device ``(B, T)`` int array (every prompt in
        a request shares T, as in :class:`ServeEngine`'s batched queries);
        mixed request streams bucket on both axes.  Greedy when ``key`` is
        None, else each token is sampled from the BMA predictive law.  The
        rows travel as individual :class:`~repro.cluster.api.Request`\\ s
        through ``submit()``/``drain()``, which stacks them straight back
        into one batch — bitwise-identical to the pre-PR-9 path.  Returns
        host arrays trimmed to the true batch.
        """
        tokens = np.asarray(tokens)
        if tokens.ndim != 2:
            raise ValueError(f"prompt batch must be (B, T), got {tokens.shape}")
        ids = [self.submit(Request(tokens=row,
                                   max_new_tokens=int(max_new_tokens),
                                   key=key))
               for row in tokens]
        by_id = {c.request_id: c for c in self.drain()}
        rows = [by_id[i] for i in ids]
        return DecodeResult(
            tokens=np.stack([c.tokens for c in rows]),
            logits=(np.stack([c.logits for c in rows])
                    if self.return_logits else None))

    __call__ = generate

"""Continuous batching over a paged KV bank — slot-level BMA serving.

:class:`~repro.cluster.decode.DecodeEngine` convoys: every sequence in a
``generate()`` batch shares one prompt length and one generation budget, so
a mixed request stream pays the *longest* request's latency on every row.
:class:`PagedDecodeEngine` breaks the convoy.  The bank's KV state becomes
one **shared block pool per chain** (:meth:`Model.init_paged_bank` —
``(C, L, n_pages, page_size, KV, hd)``) and every serving slot maps its
logical context into that pool through a per-slot **page table**, so

- sequences of wildly different lengths share HBM with no per-request
  reallocation (a slot holds pages, not a ``max_seq`` ring);
- a waiting prompt is prefilled **the moment any sequence finishes or is
  evicted** — admission is per slot, not per batch;
- the decode step stays *one* jitted program for the life of the engine:
  inactive slots keep stepping against the reserved **garbage page**
  (physical page 0) with their positions clamped to 0, so slot churn never
  changes a traced shape.

Scheduling.  ``submit()`` enqueues :class:`~repro.cluster.api.Request`\\ s;
``step()`` admits waiting requests into free slots (highest priority
first, FIFO within a priority), runs one ``decode_chunk``-step scanned
micro-batch over all slots, and completes whatever finished.  When every
slot is busy and a strictly-higher-priority request waits, the
lowest-priority active slot is **preempted**: its pages are freed, its
generated tokens discarded, and its request requeued — replay is
deterministic because sampling keys are folded per absolute position
(``fold_in(key, pos)``), not per call.

Parity contract.  The per-token math is the contiguous engine's, re-read
through a page table: prefill is the same bucket-padded ``forward``;
the step attention gathers pages in logical order so it is invariant to
physical page placement; the per-token ``(C, S, V)`` logit block crosses
the same :meth:`~repro.cluster.api.BankEngine._wrap_bma` collective
(all-gather + replicated :func:`~repro.models.predictive.bma_logits`).
On a single-sequence stream with matching ladders the tokens and logits
are **bitwise-equal** to :meth:`DecodeEngine.generate` (greedy), and the
fused Pallas page-table kernel is bitwise-equal to its jnp oracle —
pinned in ``tests/test_paged.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.cluster.api import (
    FINISH_DEADLINE,
    FINISH_LENGTH,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    BankEngine,
    Completion,
    Request,
)
from repro.obs.metrics import LATENCY_MS_BUCKETS, registry as _registry
from repro.obs.trace import now as _now, span as _span, tracer as _tracer
from repro.utils import bucket_size

PyTree = Any


class PageAllocator:
    """Free-list allocator over the physical pages of a paged KV pool.

    Page 0 is reserved as the garbage page inactive slots write into and is
    never handed out.  ``alloc(n)`` returns ``n`` page ids or ``None`` if
    the pool can't cover them (no partial allocation); ``free(pages)``
    returns them.  The scheduler sizes the pool so a free *slot* always
    implies enough free pages (``num_slots * pages_per_slot + 1``), making
    admission a slot decision — the allocator is the accounting that keeps
    that invariant checkable.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (garbage + 1), got {num_pages}")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))  # pop() -> ascending

    @property
    def free_pages(self) -> int:
        """Pages currently available (garbage page excluded)."""
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` physical page ids, or ``None`` if fewer than ``n`` free."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        """Return page ids to the pool (garbage page 0 is rejected)."""
        for p in pages:
            if not 0 < p < self.num_pages:
                raise ValueError(f"bad page id {p}")
        self._free.extend(pages)


@dataclass
class _Active:
    """Host-side bookkeeping for one occupied serving slot."""

    request: Request
    pages: List[int]
    tokens: List[int]
    logits: List[np.ndarray]
    seq: int  # admission sequence number (evict ties: youngest goes)


@dataclass
class PagedDecodeEngine(BankEngine):
    """Continuously-batched BMA generation over a paged KV bank.

    ``model``/``params`` are as in :class:`~repro.cluster.decode.
    DecodeEngine` (full-attention stacked transformers only — a sliding
    window would need per-slot ring pages).  ``num_slots`` sequences decode
    concurrently; each may hold up to ``max_seq / page_size`` pages of a
    pool sized so a free slot always implies enough free pages.  ``step()``
    pumps the scheduler once (admit -> one ``decode_chunk``-token scanned
    micro-batch -> complete/admit); ``submit()``/``drain()`` are the
    request-level :class:`~repro.cluster.api.Endpoint` surface.  Per-request
    ``key=None`` decodes that slot greedily; a key samples its tokens from
    the BMA law with position-folded subkeys (deterministic under replay).
    ``prompt_buckets`` is the prompt-length ladder: one prefill trace per
    rung, plus exactly one decode-step trace for the engine's lifetime.

    Degradation is part of the schedule: a request carrying ``deadline_ms``
    is **shed** (:data:`~repro.cluster.api.STATUS_SHED`, empty tokens) if
    its budget expires while it still waits, and **cut short**
    (:data:`~repro.cluster.api.STATUS_TIMEOUT`, the partial prefix) if it
    expires mid-decode — an overloaded engine answers late requests cheaply
    instead of convoying everything behind them.  ``max_waiting`` bounds the
    waiting queue (pending + scheduler backlog): ``submit()`` past it raises
    :class:`~repro.cluster.api.QueueFullError` instead of growing the queue
    without limit.
    """

    model: Any
    params: PyTree
    num_slots: int = 8
    page_size: int = 16
    max_seq: int = 256
    decode_chunk: int = 8
    prompt_buckets: Optional[Sequence[int]] = None  # prompt-length ladder
    mesh: Any = None
    chain_axis: str = "data"
    shard_params: bool = False
    fused: bool = False
    fused_interpret: Optional[bool] = None  # default: compiled only on TPU
    return_logits: bool = False
    max_waiting: Optional[int] = None  # submit() backpressure bound

    _FRONT_FIELD = "model"

    def __post_init__(self):
        from repro.models.transformer import Model

        self._init_bank("PagedDecodeEngine")
        cfg = self.model.cfg if hasattr(self.model, "cfg") else self.model
        self._model = Model(cfg, mesh=None, remat=False,
                            decode_fused=self.fused,
                            decode_interpret=self.fused_interpret)
        self._model._require_paged("PagedDecodeEngine")
        if self.max_seq % self.page_size:
            raise ValueError(
                f"max_seq={self.max_seq} must be a multiple of "
                f"page_size={self.page_size}")
        if self.decode_chunk < 1 or self.num_slots < 1:
            raise ValueError("need decode_chunk >= 1 and num_slots >= 1")
        self.pages_per_slot = self.max_seq // self.page_size
        self.num_pages = self.num_slots * self.pages_per_slot + 1
        self._allocator = PageAllocator(self.num_pages)
        self._shard_bank()
        self._pages = self._model.init_paged_bank(
            self.num_chains, self.num_pages, self.page_size)
        if self.mesh is not None:
            self._pages = jax.device_put(
                self._pages, NamedSharding(self.mesh, P(self.chain_axis)))
        S = self.num_slots
        self._tables = np.zeros((S, self.pages_per_slot), np.int32)
        self._positions = np.zeros((S,), np.int32)
        self._remaining = np.zeros((S,), np.int32)
        self._last_tok = np.zeros((S,), np.int32)
        self._keys = np.zeros((S, 2), np.uint32)
        self._greedy = np.ones((S,), bool)
        self._slots: List[Optional[_Active]] = [None] * S
        self._waiting: List[Request] = []
        self._seq = 0
        reg = _registry()
        self._m_requests = reg.counter("paged.requests", "requests completed")
        self._m_tokens = reg.counter("paged.tokens", "tokens generated")
        self._m_admissions = reg.counter("paged.admissions",
                                         "slot admissions (prefills)")
        self._m_evictions = reg.counter(
            "paged.evictions", "priority preemptions (request requeued)")
        self._m_occupancy = reg.gauge("paged.slot_occupancy",
                                      "active slots / num_slots")
        self._m_pages = reg.gauge(
            "paged.page_utilization",
            "allocated pages / pool (garbage page excluded)")
        self._m_ttft = reg.histogram(
            "paged.ttft_ms", LATENCY_MS_BUCKETS,
            "submit -> first token on host (emitted at admission prefill)")
        self._m_shed = reg.counter(
            "requests.shed", "requests dropped un-admitted: deadline expired "
            "while waiting")
        self._m_timeout = reg.counter(
            "requests.timeout",
            "requests cut short mid-decode: deadline expired in a slot")
        self._prefill_fn = jax.jit(self._prefill_core, donate_argnums=(1,))
        self._step_fn = jax.jit(self._step_core, donate_argnums=(1,))

    # -- traced programs ------------------------------------------------------
    def _prefill_core(self, params, pages, tokens, table, prompt_len, key,
                      greedy):
        # python side effect: runs once per prompt-length rung
        self._counters.trace("paged_prefill")
        ax = self.chain_axis

        def body(reduce, params, pages, tokens, table, prompt_len, key,
                 greedy):
            run = jax.vmap(self._model.paged_prefill,
                           in_axes=(0, None, 0, None, None))
            last, pages = run(params, tokens, pages, table, prompt_len)
            logp = reduce(last)[0]  # (C, 1, V) -> (V,)
            k = jax.random.fold_in(key, prompt_len)
            tok = jnp.where(greedy, jnp.argmax(logp, axis=-1),
                            jax.random.categorical(k, logp)).astype(jnp.int32)
            return tok, logp, pages

        return self._wrap_bma(
            body, in_specs=(P(ax), P(ax), P(), P(), P(), P(), P()),
            out_specs=(P(), P(), P(ax)))(params, pages, tokens, table,
                                         prompt_len, key, greedy)

    def _step_core(self, params, pages, tables, positions, remaining,
                   last_tok, keys, greedy):
        # python side effect: runs exactly once — slot churn never retraces
        self._counters.trace("paged_step")
        ax = self.chain_axis
        want_logits = self.return_logits

        def body(reduce, params, pages, tables, positions, remaining,
                 last_tok, keys, greedy):
            step = jax.vmap(self._model.paged_step,
                            in_axes=(0, 0, None, None, None))
            none = jnp.zeros((0,), jnp.float32)

            def micro(carry, _):
                pages, positions, remaining, last_tok = carry
                active = remaining > 0
                # inactive slots write position 0 of their zeroed table row:
                # the garbage page — real pages are never touched
                pos = jnp.where(active, positions, 0)
                per_chain, pages = step(params, pages, tables,
                                        last_tok[:, None], pos)
                logp = reduce(per_chain[:, :, 0])  # (S, V)
                kt = jax.vmap(jax.random.fold_in)(keys, pos + 1)
                sampled = jax.vmap(jax.random.categorical)(kt, logp)
                nxt = jnp.where(greedy, jnp.argmax(logp, axis=-1),
                                sampled).astype(jnp.int32)
                nxt = jnp.where(active, nxt, last_tok)
                carry = (pages, jnp.where(active, positions + 1, positions),
                         remaining - active.astype(jnp.int32), nxt)
                return carry, (jnp.where(active, nxt, -1),
                               logp if want_logits else none)

            (pages, _, _, _), (toks, logps) = jax.lax.scan(
                micro, (pages, positions, remaining, last_tok), None,
                length=self.decode_chunk)
            return pages, toks, logps

        return self._wrap_bma(
            body,
            in_specs=(P(ax), P(ax), P(), P(), P(), P(), P(), P()),
            out_specs=(P(ax), P(), P()))(params, pages, tables, positions,
                                         remaining, last_tok, keys, greedy)

    # -- request validation / queueing ----------------------------------------
    def _validate_request(self, request: Request) -> None:
        tokens = np.asarray(request.tokens)
        if tokens.ndim != 1:
            raise ValueError(
                f"a paged Request carries one 1-D prompt, got {tokens.shape}")
        if request.max_new_tokens < 1:
            raise ValueError(
                f"need max_new_tokens >= 1, got {request.max_new_tokens}")
        t_rung = bucket_size(tokens.shape[0], self.prompt_buckets)
        need = max(t_rung, tokens.shape[0] + request.max_new_tokens)
        if need > self.max_seq:
            raise ValueError(
                f"prompt rung {t_rung} + max_new_tokens "
                f"{request.max_new_tokens} overflows the {self.max_seq}-token "
                "slot capacity (num pages x page size); raise max_seq")
        request.tokens = tokens

    def _enqueue(self, requests: Sequence[Request]) -> None:
        for r in requests:
            if not hasattr(r, "_seq"):  # preserved across eviction requeues
                r._seq = self._seq
                self._seq += 1
        self._waiting.extend(requests)
        self._waiting.sort(key=lambda r: (-r.priority, r._seq))

    def _queue_depth(self) -> int:
        # max_waiting counts the whole backlog: unpumped + scheduler queue
        return len(self._pending) + len(self._waiting)

    # -- deadlines: shed the waiting, cut short the decoding -------------------
    @staticmethod
    def _expired(req: Request, now: float) -> bool:
        if req.deadline_ms is None:
            return False
        return now >= req.timing["submitted"] + req.deadline_ms * 1e-3

    def _shed_one(self, req: Request) -> Completion:
        req.timing["finished"] = _now()
        _tracer().record("paged.shed", req.timing["submitted"],
                         req.timing["finished"], request_id=req.request_id,
                         deadline_ms=req.deadline_ms)
        self._m_shed.inc()
        return Completion(
            request_id=req.request_id, tokens=np.zeros((0,), np.int32),
            logits=None, finish_reason=FINISH_DEADLINE, timing=req.timing,
            status=STATUS_SHED)

    def _shed_waiting(self, finished: List[Completion]) -> None:
        now = _now()
        expired = [r for r in self._waiting if self._expired(r, now)]
        if expired:
            self._waiting = [r for r in self._waiting
                             if not self._expired(r, now)]
            finished.extend(self._shed_one(r) for r in expired)

    def _expire_active(self, finished: List[Completion]) -> None:
        now = _now()
        for s, a in enumerate(self._slots):
            if a is not None and self._expired(a.request, now):
                self._m_timeout.inc()
                finished.append(self._finish(s, status=STATUS_TIMEOUT,
                                             reason=FINISH_DEADLINE))

    # -- scheduler: admission / eviction / completion --------------------------
    def _free_slot(self) -> Optional[int]:
        for s, a in enumerate(self._slots):
            if a is None:
                return s
        return None

    def _evict(self, s: int) -> None:
        """Preempt slot ``s``: free its pages, discard its tokens, requeue
        its request (position-folded keys make the replay identical)."""
        victim = self._slots[s]
        self._allocator.free(victim.pages)
        self._tables[s] = 0
        self._remaining[s] = 0
        self._slots[s] = None
        victim.request.timing["evictions"] = \
            victim.request.timing.get("evictions", 0) + 1
        self._m_evictions.inc()
        self._enqueue([victim.request])

    def _admit(self, finished: List[Completion]) -> None:
        while self._waiting:
            req = self._waiting[0]
            if self._expired(req, _now()):  # never prefill a dead request
                self._waiting.pop(0)
                finished.append(self._shed_one(req))
                continue
            s = self._free_slot()
            if s is None:
                active = [i for i, a in enumerate(self._slots)
                          if a is not None]
                victim = min(active, key=lambda i: (
                    self._slots[i].request.priority, -self._slots[i].seq))
                if self._slots[victim].request.priority >= req.priority:
                    return  # nothing strictly lower-priority to preempt
                self._evict(victim)
                continue
            self._waiting.pop(0)
            done = self._admit_one(s, req)
            if done is not None:  # max_new_tokens == 1: finished at prefill
                finished.append(done)

    def _admit_one(self, s: int, req: Request) -> Optional[Completion]:
        T = int(req.tokens.shape[0])
        t_rung = bucket_size(T, self.prompt_buckets)
        n_pages = -(-max(t_rung, T + req.max_new_tokens) // self.page_size)
        pages = self._allocator.alloc(n_pages)
        assert pages is not None, "free slot without free pages (pool bug)"
        t0 = _now()
        self._tables[s] = 0
        self._tables[s, :n_pages] = pages
        buf = self._scratch.get(("prompt", t_rung), (1, t_rung), np.int32)
        buf[0, :T] = req.tokens
        buf[0, T:] = req.tokens[-1]  # right pad: causally invisible
        greedy = req.key is None
        key = np.zeros((2,), np.uint32) if greedy else req.key
        tok0, logp0, self._pages = self._prefill_fn(
            self.params, self._pages, buf, self._tables[s],
            np.asarray(T, np.int32), key, np.asarray(greedy))
        tok0 = int(tok0)
        t1 = _now()
        req.timing.setdefault("admitted", t1)
        req.timing["first_token"] = t1  # TTFT: emitted at admission
        self._m_admissions.inc()
        self._m_ttft.observe((t1 - req.timing["submitted"]) * 1e3)
        _tracer().record("paged.admit", t0, t1, slot=s,
                         request_id=req.request_id, T=T, t_rung=t_rung,
                         pages=n_pages)
        active = _Active(request=req, pages=pages, tokens=[tok0],
                         logits=[np.asarray(logp0)] if self.return_logits
                         else [], seq=self._seq)
        self._seq += 1
        if req.max_new_tokens == 1:
            self._slots[s] = active
            return self._finish(s)
        self._slots[s] = active
        self._positions[s] = T       # tok0 is written here next micro-step
        self._remaining[s] = req.max_new_tokens - 1
        self._last_tok[s] = tok0
        self._keys[s] = key
        self._greedy[s] = greedy
        self._gauges()
        return None

    def _finish(self, s: int, *, status: str = STATUS_OK,
                reason: str = FINISH_LENGTH) -> Completion:
        a = self._slots[s]
        self._allocator.free(a.pages)
        self._tables[s] = 0
        self._remaining[s] = 0
        self._slots[s] = None
        r = a.request
        r.timing["finished"] = _now()
        _tracer().record("paged.request", r.timing["submitted"],
                         r.timing["finished"], slot=s,
                         request_id=r.request_id,
                         new_tokens=len(a.tokens),
                         evictions=r.timing.get("evictions", 0),
                         status=status)
        self._m_requests.inc()
        self._m_tokens.inc(len(a.tokens))
        self._gauges()
        return Completion(
            request_id=r.request_id,
            tokens=np.asarray(a.tokens, np.int32),
            logits=(np.stack(a.logits) if self.return_logits else None),
            finish_reason=reason, timing=r.timing, status=status)

    def _gauges(self) -> None:
        used = sum(a is not None for a in self._slots)
        self._m_occupancy.set(used / self.num_slots)
        self._m_pages.set(
            1.0 - self._allocator.free_pages / (self.num_pages - 1))

    @property
    def num_active(self) -> int:
        """Slots currently decoding a sequence."""
        return sum(a is not None for a in self._slots)

    @property
    def num_waiting(self) -> int:
        """Requests admitted to the scheduler but not yet in a slot
        (submitted-but-unpumped requests are in ``_pending`` until the next
        ``step()``/``drain()``)."""
        return len(self._waiting)

    # -- the pump --------------------------------------------------------------
    def step(self) -> List[Completion]:
        """One scheduler pump: admit waiting requests into free slots, run
        one ``decode_chunk``-token scanned micro-batch over every slot, and
        return whatever finished (freed slots are refilled immediately, so
        the next chunk decodes the newly admitted prompts too).  Requests
        past their ``deadline_ms`` are shed from the waiting queue (and cut
        short in their slots) before any device work is spent on them."""
        self._enqueue(self._pending)
        self._pending = []
        finished: List[Completion] = []
        self._shed_waiting(finished)
        self._expire_active(finished)
        self._admit(finished)
        if self.num_active:
            with _span("paged.decode_chunk", active=self.num_active,
                       chunk=self.decode_chunk):
                self._pages, toks, logps = self._step_fn(
                    self.params, self._pages, self._tables, self._positions,
                    self._remaining, self._last_tok, self._keys, self._greedy)
                toks = np.asarray(toks)  # (chunk, S): blocks for real latency
                logps = np.asarray(logps) if self.return_logits else None
            for s, a in enumerate(self._slots):
                if a is None:
                    continue
                n = min(self.decode_chunk, int(self._remaining[s]))
                a.tokens.extend(int(t) for t in toks[:n, s])
                if self.return_logits:
                    a.logits.extend(logps[t, s] for t in range(n))
                self._positions[s] += n
                self._remaining[s] -= n
                self._last_tok[s] = toks[n - 1, s]
                if self._remaining[s] == 0:
                    finished.append(self._finish(s))
            self._expire_active(finished)  # partial prefix beats a dead slot
        self._admit(finished)  # admission the moment a sequence finishes
        return finished

    def _drain(self, requests: Sequence[Request]) -> List[Completion]:
        self._enqueue(list(requests))
        done = {}
        while self._waiting or self.num_active:
            for c in self.step():
                done[c.request_id] = c
        ordered = [done.pop(r.request_id) for r in requests
                   if r.request_id in done]
        return ordered + list(done.values())

"""The request-level serving front door every engine shares.

PR 3 grew predictive serving and PR 5 streaming decode as *batch*-level
APIs: callers hand a whole query batch to :meth:`ServeEngine.serve` or a
whole prompt batch to :meth:`DecodeEngine.generate`, and every row in the
batch lives and dies together.  Continuous batching breaks that coupling —
a scheduler admits and retires *individual sequences* against shared device
state — so the unit of work has to become the single request.  This module
defines that unit:

- :class:`Request` — one sequence (or one predictive query): prompt tokens,
  a per-request generation budget, an optional per-request sampling key,
  and a scheduling priority;
- :class:`Completion` — its result: generated tokens, optional per-token
  BMA logits, a finish reason, and host-clock timing
  (submitted/admitted/first token/finished);
- :class:`Endpoint` — the shared ``submit()`` / ``drain()`` surface.
  :meth:`ServeEngine.serve` and :meth:`DecodeEngine.generate` are thin
  shims over it (kept bitwise-compatible — pinned in
  ``tests/test_api.py``), and
  :class:`~repro.cluster.paged.PagedDecodeEngine` consumes it natively
  with slot-level admission;
- :class:`BankEngine` — the constructor/plumbing base every chain-bank
  engine shares: one ``from_checkpoint`` / ``from_cluster`` signature, one
  mesh-divisibility check and bank-sharding layout, one
  :class:`HostScratch` + instrument-counter setup, and the
  gather-then-replicated-:func:`~repro.models.predictive.bma_logits`
  collective wrapper the decode engines pin their sharded == unsharded
  bitwise contract on.
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.instrument import Counters as _Counters, counters as _counters
from repro.models.predictive import bma_logits
from repro.obs.metrics import registry as _registry
from repro.obs.trace import now as _now
from repro.samplers.base import SamplerState
from repro.utils import SHARD_MAP_CHECK_KW, shard_map

PyTree = Any

#: finish reasons a :class:`Completion` can carry
FINISH_LENGTH = "length"      # generated its full max_new_tokens budget
FINISH_QUERY = "query"        # predictive query: answered in one shot
FINISH_DEADLINE = "deadline"  # deadline expired (shed or cut short)

#: delivery status a :class:`Completion` can carry
STATUS_OK = "ok"            # full result
STATUS_TIMEOUT = "timeout"  # deadline hit mid-decode: partial tokens
STATUS_SHED = "shed"        # deadline hit before admission: no tokens

_REQUEST_IDS = itertools.count(1)


class QueueFullError(RuntimeError):
    """Backpressure: the engine's waiting queue is at ``max_waiting`` —
    the caller must drain (or step) before submitting more work."""


@dataclass
class Request:
    """One unit of serving work.

    ``tokens`` is a 1-D prompt token array for decode engines, or one query
    (any pytree row) for predictive engines.  ``max_new_tokens`` is this
    request's *own* generation budget — requests with different budgets
    share a continuous batch without convoying (0 = predictive query).
    ``key`` is the per-request sampling key (``None`` = greedy; batch-shim
    engines share one key across the rows of a legacy batched call, the
    paged scheduler folds it per emitted position so an evicted-and-
    replayed request resamples identically).  Higher ``priority`` admits
    first and may preempt lower-priority running slots.  ``request_id`` is
    stamped by :meth:`Endpoint.submit`.

    ``deadline_ms`` (optional) is a host-clock latency budget measured from
    submission: the paged scheduler sheds the request
    (:data:`STATUS_SHED`) if it expires while still waiting, and cuts it
    short with partial tokens (:data:`STATUS_TIMEOUT`) if it expires while
    decoding.  ``None`` — the default — never expires.
    """

    tokens: Any
    max_new_tokens: int = 0
    key: Optional[jax.Array] = None
    priority: int = 0
    request_id: Optional[int] = None
    timing: dict = field(default_factory=dict)
    deadline_ms: Optional[float] = None


@dataclass
class Completion:
    """The finished result of one :class:`Request`.

    ``tokens`` is the generated ``(n,)`` int32 host array (empty for
    predictive queries); ``logits`` the per-token BMA log-probability block
    ``(n, V)`` when the engine returns logits, else ``None``;
    ``finish_reason`` one of :data:`FINISH_LENGTH` / :data:`FINISH_QUERY`;
    ``timing`` host-clock seconds (:func:`repro.obs.trace.now`) for
    ``submitted`` / ``admitted`` / ``first_token`` / ``finished`` plus an
    ``evictions`` count under the preempting scheduler — ``first_token``
    is when the first generated token became *available on host* (batch
    engines deliver at drain, so it equals ``finished`` there; the paged
    scheduler emits it at admission prefill).  ``stats`` carries the
    per-query :class:`~repro.cluster.serve.ServeResult` row on predictive
    endpoints.  ``status`` is the delivery outcome: :data:`STATUS_OK`
    (full result), :data:`STATUS_TIMEOUT` (deadline hit mid-decode —
    ``tokens`` holds the partial prefix), or :data:`STATUS_SHED`
    (deadline hit before admission — ``tokens`` is empty).
    """

    request_id: int
    tokens: np.ndarray
    logits: Optional[np.ndarray]
    finish_reason: str
    timing: dict
    stats: Optional[Any] = None
    status: str = STATUS_OK


class HostScratch:
    """Reusable host-side pad buffers, one per (bucket rung, leaf).

    Padding a request up its bucket rung is shape-varying glue that must
    stay in numpy on the serving hot path — but a fresh ``np.concatenate``
    per request still allocates (and touches) a buffer every call.  This
    keeps one scratch array per ``(rung, leaf key, trailing shape, dtype)``
    and rewrites it in place, so a steady-state request stream performs
    **zero** per-request allocations on the padding path (``allocs`` stops
    growing once every rung has been seen — asserted by the serve/decode
    benches).  Reuse is safe because ``jit`` copies host arrays to device
    synchronously at dispatch.

    Every buffer creation is reported to ``counters``
    (a :class:`repro.analysis.instrument.Counters` handle) when one is
    given, so an :func:`~repro.analysis.instrument.instrument` region around
    a warm request stream sees zero pad-alloc events.
    """

    def __init__(self, counters: Optional[_Counters] = None):
        self._bufs: dict = {}
        self.allocs = 0  # scratch-buffer creations, NOT per-request work
        self._counters = counters

    def get(self, key, shape, dtype) -> np.ndarray:
        """The scratch buffer for ``key`` (caller fills it)."""
        k = (key, tuple(shape), np.dtype(dtype).str)
        buf = self._bufs.get(k)
        if buf is None:
            buf = np.empty(shape, dtype)
            self._bufs[k] = buf
            self.allocs += 1
            if self._counters is not None:
                self._counters.pad_alloc()
        return buf

    def pad(self, x: np.ndarray, n: int, key=0) -> np.ndarray:
        """``x`` with its leading axis padded to ``n`` by edge-replicating
        the last row, written into the reused scratch."""
        q = x.shape[0]
        if q == n:
            return x  # jit transfers host arrays; caller's buffer intact
        buf = self.get(("pad", key), (n,) + x.shape[1:], x.dtype)
        buf[:q] = x
        buf[q:] = x[-1:]
        return buf


class Endpoint:
    """The ``submit()`` / ``drain()`` surface every serving engine exposes.

    ``submit`` enqueues one :class:`Request` and returns its id; ``drain``
    runs everything pending to completion and returns the
    :class:`Completion` list.  Batch engines group pending requests back
    into their legacy batched programs (bitwise-identical to direct batch
    calls); the paged scheduler interleaves them at slot granularity.
    Subclasses implement ``_drain(requests)``.
    """

    def submit(self, request: Request) -> int:
        """Enqueue one request; returns its stamped ``request_id``.

        Engines with a ``max_waiting`` bound reject submissions once the
        waiting queue is full — :class:`QueueFullError`, counted under
        ``requests.rejected`` — instead of growing it without limit."""
        limit = getattr(self, "max_waiting", None)
        if limit is not None and self._queue_depth() >= limit:
            _registry().counter(
                "requests.rejected",
                "submissions refused by max_waiting backpressure").inc()
            raise QueueFullError(
                f"waiting queue holds {self._queue_depth()} requests "
                f"(max_waiting={limit}); drain() or step() before "
                "submitting more")
        if request.request_id is None:
            request.request_id = next(_REQUEST_IDS)
        request.timing.setdefault("submitted", _now())
        self._validate_request(request)
        self._pending.append(request)
        return request.request_id

    def drain(self) -> list:
        """Run every pending request to completion; returns Completions.

        Always calls through to the engine's ``_drain`` — engines with
        internal scheduler state (waiting queues, occupied slots) finish
        in-flight work even when nothing new is pending."""
        reqs, self._pending = list(self._pending), []
        return self._drain(reqs)

    def _queue_depth(self) -> int:
        """Requests counted against ``max_waiting`` (engines with internal
        waiting queues — the paged scheduler — add theirs)."""
        return len(self._pending)

    def _validate_request(self, request: Request) -> None:
        del request  # engines override with their admission checks

    def _drain(self, requests: list) -> list:
        raise NotImplementedError


class BankEngine(Endpoint):
    """Shared plumbing for engines serving a chain-stacked parameter bank.

    Concrete engines (:class:`~repro.cluster.serve.ServeEngine`,
    :class:`~repro.cluster.decode.DecodeEngine`,
    :class:`~repro.cluster.paged.PagedDecodeEngine`) are dataclasses with
    ``params`` / ``mesh`` / ``chain_axis`` fields; this base owns what they
    all repeat: bank validation + chain counting + scratch/counter setup
    (:meth:`_init_bank`), the mesh-divisibility check and bank sharding
    layout (:meth:`_shard_bank`), the gather-then-replicated BMA collective
    wrapper (:meth:`_wrap_bma`), and one constructor signature
    (:meth:`from_checkpoint` / :meth:`from_cluster`) — the migration table
    lives in ``docs/SERVING.md``.
    """

    #: the dataclass field the positional constructor argument binds to
    #: (``predict_fn`` for predictive engines, ``model`` for decode engines)
    _FRONT_FIELD = "model"

    # -- shared __post_init__ plumbing ---------------------------------------
    def _init_bank(self, label: str) -> None:
        """Validate the bank, count chains, sort bucket ladders, and wire
        the instrument counters + host pad scratch + request queue."""
        leaves = jax.tree_util.tree_leaves(self.params)
        if not leaves:
            raise ValueError("params bank is empty")
        self.num_chains = int(leaves[0].shape[0])
        for name in ("buckets", "prompt_buckets"):
            ladder = getattr(self, name, None)
            if ladder is not None:
                setattr(self, name, sorted(int(b) for b in ladder))
        self._counters = _counters(label)
        self._scratch = HostScratch(self._counters)
        self._host_scratch = self._scratch  # legacy ServeEngine attr name
        self._pending: list = []

    def _shard_bank(self) -> None:
        """Check chain divisibility over the mesh and device_put the bank
        into its sharded layout (no-op without a mesh)."""
        if self.mesh is None:
            return
        n_shards = self.mesh.shape[self.chain_axis]
        if self.num_chains % n_shards:
            raise ValueError(
                f"num_chains={self.num_chains} must be divisible by mesh "
                f"axis {self.chain_axis!r} (size {n_shards})")
        self.params = jax.device_put(self.params, self._bank_shardings())

    def _bank_shardings(self):
        """Per-leaf NamedShardings for the params bank: chain axis over
        ``chain_axis``; with ``shard_params`` the single-chain tensor-
        parallel specs (``partition_tree``) compose behind it (2-D)."""
        if not getattr(self, "shard_params", False):
            s = NamedSharding(self.mesh, P(self.chain_axis))
            return jax.tree_util.tree_map(lambda _: s, self.params)
        from repro.models.common import partition_tree

        cfg = self._model.cfg
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), self.params)
        specs = partition_tree(like, cfg.param_sharding,
                               model_size=self.mesh.shape.get("model"),
                               cfg=cfg)
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, P(self.chain_axis, *s)), specs,
            is_leaf=lambda s: isinstance(s, P))

    def _wrap_bma(self, body, in_specs, out_specs, reduce_full=bma_logits):
        """Wrap ``body(reduce, *args)`` under the engine's collective layout.

        ``reduce`` maps the per-chain block (logits ``(C, B, V)`` on decode
        engines, predictions ``(C, Q, ...)`` on predictive ones) to the
        replicated ensemble law: plain ``reduce_full`` (the BMA reduce by
        default) unsharded; an ``all_gather`` of the model-size-independent
        block then the *identical* replicated reduce under the chain-sharded
        ``shard_map`` — so sharded and unsharded serving are bitwise-equal;
        a replication ``with_sharding_constraint`` then the same reduce
        under GSPMD when ``shard_params`` (2-D banks trade the bitwise
        guarantee for HBM headroom).  ``in_specs`` / ``out_specs`` are the
        shard_map specs (``P(ax)`` on chain-stacked args, ``P()`` on
        replicated ones); they are ignored on the unsharded and GSPMD paths.
        """
        if self.mesh is None:
            return functools.partial(body, reduce_full)
        if getattr(self, "shard_params", False):
            rep = NamedSharding(self.mesh, P())

            def reduce(per_chain):  # pin gather-then-reduce under GSPMD
                gathered = jax.lax.with_sharding_constraint(per_chain, rep)
                return reduce_full(gathered)

            return functools.partial(body, reduce)
        ax = self.chain_axis

        def sharded_reduce(local):  # (C/shards, B, ...) -> replicated
            full = jax.lax.all_gather(local, ax, axis=0, tiled=True)
            return reduce_full(full)

        return shard_map(functools.partial(body, sharded_reduce),
                         mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, **SHARD_MAP_CHECK_KW)

    # -- shared observability views ------------------------------------------
    @property
    def num_traces(self) -> int:
        """Jit traces so far (one per shape rung) — a thin view over the
        engine's :mod:`repro.analysis.instrument` counters."""
        return self._counters.traces

    @property
    def num_host_pad_allocs(self) -> int:
        """Host scratch-buffer creations so far — one per (bucket rung,
        leaf), NOT one per request; the serve/decode benches assert this
        stops growing once the stream's rungs have all been seen."""
        return self._counters.pad_allocs

    # -- unified constructors -------------------------------------------------
    @classmethod
    def from_cluster(cls, state: SamplerState | PyTree, front=None, **kw):
        """Serve directly from a (possibly still sharded) ClusterEngine
        state — or any chain-stacked params pytree.  ``front`` is the
        engine's front argument (``model`` for decode engines,
        ``predict_fn`` for predictive ones); both may also be passed by
        keyword.

        A :class:`~repro.cluster.executor.HealthState` (or any state
        carrying a ``health`` mask) serves **degraded**: quarantined chains
        are dropped from the bank and the BMA averages the survivors, so a
        partially-poisoned ensemble keeps answering instead of serving NaN
        logits.  An all-quarantined bank raises."""
        params = getattr(state, "params", state)
        health = getattr(state, "health", None)
        if health is not None:
            h = np.asarray(health)
            if not h.any():
                raise ValueError(
                    "every chain is quarantined — no healthy bank to serve")
            if not h.all():
                keep = np.flatnonzero(h)
                params = jax.tree_util.tree_map(lambda x: x[keep], params)
                _registry().gauge(
                    "chains.unhealthy",
                    "chains currently quarantined").set(float(
                        h.size - keep.size))
        if front is not None:
            kw.setdefault(cls._FRONT_FIELD, front)
        return cls(params=params, **kw)

    @classmethod
    def from_checkpoint(cls, path: str, like: PyTree = None, front=None, *,
                        num_chains: Optional[int] = None, **kw):
        """Restore a bank saved by :meth:`ClusterEngine.save_ensemble` (or
        broadcast a single-model checkpoint to ``num_chains``) and serve it.

        One signature for every engine: ``(path, like, model_or_predict_fn,
        ...)`` where ``like`` is the *single-chain* params structure and the
        third argument is the engine's front argument (``model`` /
        ``predict_fn``), also accepted by keyword.  The legacy
        ``DecodeEngine.from_checkpoint(path, model, like)`` positional order
        is detected (a model/config in the ``like`` seat) and swapped, so
        pre-PR-9 call sites keep working — see the migration table in
        ``docs/SERVING.md``.
        """
        if _looks_like_model(like) and not _looks_like_model(front):
            like, front = front, like  # legacy (path, model, like) order
        if front is not None:
            kw.setdefault(cls._FRONT_FIELD, front)
        from repro.checkpoint import restore_ensemble

        params = restore_ensemble(path, like, num_chains=num_chains)
        return cls(params=params, **kw)


def _looks_like_model(x) -> bool:
    """A Model (has .cfg) or a raw config (has .d_model) — never a params
    pytree or a predict_fn."""
    return hasattr(x, "cfg") or hasattr(x, "d_model")

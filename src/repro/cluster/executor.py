"""ClusterEngine: the device-parallel multi-chain async-SGLD executor.

Same contract as :class:`repro.train.engine.Engine` — jitted ``lax.scan``
chunks, donated carry, host hooks between chunks, a flat retrace counter —
but the carry is a C-chain :func:`~repro.cluster.ensemble.init_ensemble`
state and each scan step advances the whole population through the vmapped
transform chain.

Delays are *endogenous*: the scan input is the schedule's per-chain
``read_versions`` and the jitted body derives staleness as
``server_version - read_version`` from the carried commit counter, so the
device executes the worker schedule instead of consuming a staleness
side-channel.  With ``mesh=`` the chunk body runs under the repo's
``shard_map`` compat shim with chains split over the ``data`` axis — pure
SPMD, no cross-chain communication, so per-chain trajectories are identical
sharded or not.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.cluster.ensemble import ensemble_step, init_ensemble
from repro.cluster.schedule import WorkerSchedule, stack_schedules
from repro.core.delay import validate_staleness
from repro.samplers.base import Sampler, SamplerState
from repro.train.engine import Hook, drive_chunks
from repro.utils import SHARD_MAP_CHECK_KW, shard_map

PyTree = Any
BatchFn = Callable[[jax.Array], PyTree]  # key -> one chain's batch (pure jax)

#: accepted `schedule=` forms for :meth:`ClusterEngine.run`
ScheduleLike = Any  # WorkerSchedule | Sequence[WorkerSchedule] | np.ndarray | None


@dataclass
class ClusterEngine:
    """Scan-chunked executor for a C-chain async-SGLD ensemble.

    ``batch_fn(key) -> batch`` (optional) generates an *independent*
    minibatch per (step, chain) key on device; explicit ``batches`` passed to
    :meth:`run` are broadcast to every chain unless ``per_chain_batches=True``
    (then their second axis is the chain axis).  ``mesh`` shards the chain
    axis over ``chain_axis`` (``num_chains`` must be divisible by that mesh
    axis size).
    """

    sampler: Sampler
    num_chains: int
    chunk_size: int = 50
    hooks: Sequence[Hook] = ()
    donate: bool = True
    collect_aux: bool = False
    batch_fn: Optional[BatchFn] = None
    per_chain_batches: bool = False
    mesh: Any = None
    chain_axis: str = "data"

    num_traces: int = field(default=0, init=False)  # jit retrace counter

    def __post_init__(self):
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.num_chains < 1:
            raise ValueError(f"num_chains must be >= 1, got {self.num_chains}")
        if self.mesh is not None:
            n_shards = self.mesh.shape[self.chain_axis]
            if self.num_chains % n_shards:
                raise ValueError(
                    f"num_chains={self.num_chains} must be divisible by mesh "
                    f"axis {self.chain_axis!r} (size {n_shards})")
        # one jitted chunk per batch layout; only the layouts actually run
        # get traced/compiled (the counter they bump is shared)
        self._chunk_shared = self._build_chunk(batch_axis=None)
        self._chunk_per_chain = self._build_chunk(batch_axis=0)
        self._make_batches = (jax.jit(jax.vmap(jax.vmap(self.batch_fn)))
                              if self.batch_fn is not None else None)

    def _build_chunk(self, batch_axis: Optional[int]):
        """Jitted scan over one chunk; ``batch_axis=0`` vmaps the batch over
        the chain axis, ``None`` broadcasts one batch to every chain."""

        def chunk(state, batches, read_versions):
            self.num_traces += 1  # python side effect: counts traces
            step_fn = ensemble_step(self.sampler, batch_axis=batch_axis)

            def body(s, inp):
                batch, rv = inp
                delay = s.step.astype(jnp.int32) - rv  # endogenous staleness
                s, aux = step_fn(s, batch, delay)
                return s, (aux if self.collect_aux else None)

            return jax.lax.scan(body, state, (batches, read_versions))

        if self.mesh is not None:
            ax = self.chain_axis
            batch_spec = P(None, ax) if batch_axis == 0 else P()
            chunk = shard_map(chunk, mesh=self.mesh,
                              in_specs=(P(ax), batch_spec, P(None, ax)),
                              out_specs=(P(ax), P(None, ax)),
                              **SHARD_MAP_CHECK_KW)
        return jax.jit(chunk, donate_argnums=(0,) if self.donate else ())

    # -- init -----------------------------------------------------------------
    def init(self, params: PyTree, key: jax.Array, *,
             jitter: float = 0.0) -> SamplerState:
        """C-chain ensemble state; chain ``c``'s key is ``split(key, C)[c]``."""
        state = init_ensemble(self.sampler, params, key,
                              num_chains=self.num_chains, jitter=jitter)
        if self.mesh is not None:
            sharding = jax.sharding.NamedSharding(self.mesh, P(self.chain_axis))
            state = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, sharding), state)
        return state

    # -- state export ---------------------------------------------------------
    def save_ensemble(self, state: SamplerState, path: str) -> None:
        """Export the chain bank: the chain-stacked params in the ensemble
        layout :func:`~repro.checkpoint.restore_ensemble` (and therefore
        :meth:`~repro.cluster.serve.ServeEngine.from_checkpoint`) restores,
        with the newest per-chain commit counter as the checkpoint step."""
        from repro.checkpoint import save_checkpoint

        save_checkpoint(path, state.params,
                        step=int(np.max(np.asarray(state.step))))

    # -- schedule normalization ------------------------------------------------
    def _compile_schedule(self, schedule: ScheduleLike, steps: int):
        """-> (read_versions (steps, C) int32, commit_times (steps, C) | None)."""
        c = self.num_chains
        if schedule is None:
            k = np.arange(steps, dtype=np.int32)[:, None]  # fresh reads, tau=0
            return np.tile(k, (1, c)), None
        raw_delays = isinstance(schedule, (np.ndarray, jnp.ndarray))
        if raw_delays:
            arr = np.asarray(schedule)
            if arr.ndim == 1:
                schedule = WorkerSchedule.from_delays(arr)
            elif arr.ndim == 2:
                schedule = [WorkerSchedule.from_delays(arr[:, i])
                            for i in range(arr.shape[1])]
            else:
                raise ValueError("delay array must be (steps,) or (steps, C)")
        scheds = ([schedule] * c if isinstance(schedule, WorkerSchedule)
                  else list(schedule))
        if len(scheds) != c:
            raise ValueError(f"got {len(scheds)} per-chain schedules for "
                             f"{c} chains")
        rv, times = stack_schedules(scheds, steps=steps)
        # raw delay arrays carry no wall-clock information; don't present
        # from_delays' synthetic arange times as simulated commit times
        return rv, (None if raw_delays else times)

    # -- host driver ----------------------------------------------------------
    def run(self, state: SamplerState, *, steps: int,
            schedule: ScheduleLike = None,
            batches: Optional[PyTree] = None,
            key: Optional[jax.Array] = None):
        """Advance every chain ``steps`` commits under ``schedule``.

        ``schedule`` may be one :class:`WorkerSchedule` (broadcast), a
        sequence of C per-chain schedules, a raw delay ndarray
        (``(steps,)`` or ``(steps, C)``), or ``None`` (synchronous, tau=0).
        Returns ``(state, aux)`` with aux stacked ``(steps, C, ...)`` when
        ``collect_aux`` (plus ``commit_times`` threaded into hook aux when
        the schedule carries them).
        """
        read_versions, commit_times = self._compile_schedule(schedule, steps)
        max_delay = int((np.arange(steps, dtype=np.int64)[:, None]
                         - read_versions).max(initial=0))
        validate_staleness(max_delay, state.inner, context="schedule")
        # schedule versions are relative to this run's first commit; rebase
        # onto the state's commit counter so continuation runs keep the
        # endogenous staleness (step - read_version) equal to the schedule's
        # tau_k instead of silently clamping at the ring depth.
        read_versions = jnp.asarray(
            read_versions + np.asarray(state.step)[None, :], jnp.int32)

        # explicit batches follow the per_chain_batches contract; generated
        # ones always carry a chain axis (one key per (step, chain))
        per_chain = (self.per_chain_batches if batches is not None
                     else self._make_batches is not None)
        run_chunk = self._chunk_per_chain if per_chain else self._chunk_shared

        def gen_batches(key, n):
            key, sub = jax.random.split(key)
            chunk_keys = jax.random.split(sub, n * self.num_chains)
            chunk_keys = chunk_keys.reshape(
                (n, self.num_chains) + chunk_keys.shape[1:])
            return key, self._make_batches(chunk_keys)

        return drive_chunks(
            run_chunk, state, steps=steps, chunk_size=self.chunk_size,
            hooks=self.hooks, collect_aux=self.collect_aux,
            extra=read_versions, batches=batches,
            gen_batches=gen_batches if self._make_batches is not None else None,
            key=key, commit_times=commit_times)

"""ClusterEngine: the device-parallel multi-chain async-SGLD executor.

Same contract as :class:`repro.train.engine.Engine` — jitted ``lax.scan``
chunks, donated carry, host hooks between chunks, a flat retrace counter —
but the carry is a C-chain :func:`~repro.cluster.ensemble.init_ensemble`
state and each scan step advances the whole population through the vmapped
transform chain.

Delays are *endogenous*: the scan input is the schedule's per-chain
``read_versions`` and the jitted body derives staleness as
``server_version - read_version`` from the carried commit counter, so the
device executes the worker schedule instead of consuming a staleness
side-channel.  With ``mesh=`` the chunk body runs under the repo's
``shard_map`` compat shim with chains split over the ``data`` axis — pure
SPMD, no cross-chain communication, so per-chain trajectories are identical
sharded or not.

Batch sizes are part of the schedule: under ``batch_policy="inverse-speed"``
(or ``"explicit"``) every commit carries its own minibatch size and data
offset, and the scan body gathers a *bucket-padded* window from the ``data``
stream — each chunk pads to the bucket-ladder rung of its largest commit,
so a mixed-size schedule compiles **one trace per rung**, never one per
size (the discipline :class:`~repro.cluster.serve.ServeEngine` applies to
query batches).  The mask (:class:`~repro.samplers.transforms.MaskedBatch`)
keeps padding rows out of the gradient average.  The default
``batch_policy="fixed"`` is the legacy fixed-shape path, bit-identical to
the pre-heterogeneous executor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis.instrument import counters as _counters
from repro.cluster.ensemble import ensemble_step, init_ensemble
from repro.cluster.schedule import (
    WorkerSchedule,
    stack_batch_info,
    stack_schedules,
    stack_worker_info,
)
from repro.core.delay import validate_staleness
from repro.core.delay_model import BATCH_POLICIES
from repro.obs.metrics import STALENESS_BUCKETS, registry as _registry
from repro.samplers.base import Sampler, SamplerState
from repro.samplers.transforms import MaskedBatch
from repro.train.engine import Hook, drive_chunks
from repro.utils import SHARD_MAP_CHECK_KW, bucket_size, shard_map

PyTree = Any
BatchFn = Callable[[jax.Array], PyTree]  # key -> one chain's batch (pure jax)

#: accepted `schedule=` forms for :meth:`ClusterEngine.run`
ScheduleLike = Any  # WorkerSchedule | Sequence[WorkerSchedule] | np.ndarray | None


@dataclass
class ClusterEngine:
    """Scan-chunked executor for a C-chain async-SGLD ensemble.

    ``batch_fn(key) -> batch`` (optional) generates an *independent*
    minibatch per (step, chain) key on device; explicit ``batches`` passed to
    :meth:`run` are broadcast to every chain unless ``per_chain_batches=True``
    (then their second axis is the chain axis).  ``mesh`` shards the chain
    axis over ``chain_axis`` (``num_chains`` must be divisible by that mesh
    axis size).

    ``batch_policy`` selects how commits consume data:

    - ``"fixed"`` (default): one fixed-shape minibatch per commit — the
      legacy contract, bit-identical to the pre-heterogeneous executor.
    - ``"inverse-speed"``: per-commit sizes come from the schedule's
      ``batch_sizes`` (compiled from a
      :meth:`WorkerModel.batch_sizes <repro.core.delay_model.WorkerModel>`
      policy: slow workers amortize staleness over large batches); commits
      consume bucket-padded masked windows of the ``data=`` stream passed to
      :meth:`run`.
    - ``"explicit"``: like inverse-speed, but sizes come from the
      ``batch_sizes=`` array passed to :meth:`run` (snapped up the
      ``buckets`` ladder).

    The sampler must use the per-example masked-oracle contract for the
    non-fixed policies (``samplers.sgld(..., base_batch=...)`` or a chain
    containing :func:`~repro.samplers.transforms.masked_gradients`).

    ``worker_rng`` derives each commit's noise key from
    ``(chain key, worker_id, worker-local slot)`` instead of the carried
    sequential split, making every worker's noise stream reproducible
    independently of commit order (see
    :func:`~repro.cluster.ensemble.worker_keys`).
    """

    sampler: Sampler
    num_chains: int
    chunk_size: int = 50
    hooks: Sequence[Hook] = ()
    donate: bool = True
    collect_aux: bool = False
    batch_fn: Optional[BatchFn] = None
    per_chain_batches: bool = False
    mesh: Any = None
    chain_axis: str = "data"
    batch_policy: str = "fixed"
    buckets: Optional[Sequence[int]] = None
    worker_rng: bool = False

    def __post_init__(self):
        self._counters = _counters("ClusterEngine")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.num_chains < 1:
            raise ValueError(f"num_chains must be >= 1, got {self.num_chains}")
        if self.batch_policy not in BATCH_POLICIES:
            raise ValueError(f"unknown batch_policy {self.batch_policy!r} "
                             f"(choose from {BATCH_POLICIES})")
        if self.batch_policy != "fixed" and self.batch_fn is not None:
            raise ValueError(
                "batch_fn generates fixed-shape minibatches; heterogeneous "
                "batch policies consume a `data=` stream passed to run()")
        if self.mesh is not None:
            n_shards = self.mesh.shape[self.chain_axis]
            if self.num_chains % n_shards:
                raise ValueError(
                    f"num_chains={self.num_chains} must be divisible by mesh "
                    f"axis {self.chain_axis!r} (size {n_shards})")
        # one jitted chunk per batch layout; only the layouts actually run
        # get traced/compiled (the counter they bump is shared)
        self._chunk_shared = self._build_chunk(batch_axis=None)
        self._chunk_per_chain = self._build_chunk(batch_axis=0)
        self._masked_chunks: dict = {}  # pad width -> jitted masked chunk
        self._make_batches = (jax.jit(jax.vmap(jax.vmap(self.batch_fn)))
                              if self.batch_fn is not None else None)
        reg = _registry()
        self._m_staleness = reg.histogram(
            "cluster.staleness", STALENESS_BUCKETS,
            "per-commit staleness tau = version - read_version")
        self._m_commits = reg.counter(
            "cluster.commits", "commits executed (steps x chains)")
        self._m_grad_evals = reg.counter(
            "cluster.grad_evals",
            "per-example gradient evaluations (non-fixed batch policies)")
        self._m_max_stale = reg.gauge(
            "cluster.max_staleness", "largest tau in the newest schedule")

    @property
    def num_traces(self) -> int:
        """Jit traces so far (one per chunk layout / bucket rung) — a thin
        view over the engine's :mod:`repro.analysis.instrument` counters."""
        return self._counters.traces

    def _step_fn(self, batch_axis: Optional[int]):
        return ensemble_step(self.sampler, batch_axis=batch_axis,
                             worker_rng=self.worker_rng)

    def _step_args(self, s, batch, delay, ex):
        if self.worker_rng:
            return (s, batch, delay, ex["wid"], ex["slot"])
        return (s, batch, delay)

    def _build_chunk(self, batch_axis: Optional[int]):
        """Jitted scan over one chunk; ``batch_axis=0`` vmaps the batch over
        the chain axis, ``None`` broadcasts one batch to every chain."""

        def chunk(state, batches, extra):
            # python side effect: runs once per trace, never per call
            self._counters.trace(f"chunk[batch_axis={batch_axis}]")
            step_fn = self._step_fn(batch_axis)

            def body(s, inp):
                batch, ex = inp
                delay = s.step.astype(jnp.int32) - ex["rv"]  # endogenous
                s, aux = step_fn(*self._step_args(s, batch, delay, ex))
                return s, (aux if self.collect_aux else None)

            return jax.lax.scan(body, state, (batches, extra))

        if self.mesh is not None:
            ax = self.chain_axis
            batch_spec = P(None, ax) if batch_axis == 0 else P()
            chunk = shard_map(chunk, mesh=self.mesh,
                              in_specs=(P(ax), batch_spec, P(None, ax)),
                              out_specs=(P(ax), P(None, ax)),
                              **SHARD_MAP_CHECK_KW)
        return jax.jit(chunk, donate_argnums=(0,) if self.donate else ())

    def _build_masked_chunk(self, pad: int):
        """Jitted scan whose per-step batch is a bucket-padded masked window
        of the data stream: ``pad`` is the chunk's ladder rung (static —
        one trace per rung), ``extra`` carries per-(step, chain) data
        offsets and real sizes, and the gather wraps modulo the stream
        length so offsets never index out of bounds."""

        def chunk(state, data, extra):
            # python side effect: runs once per trace, never per call
            self._counters.trace(f"masked_chunk[pad={pad}]")
            step_fn = self._step_fn(0)
            n_data = jax.tree_util.tree_leaves(data)[0].shape[0]

            def window(off):  # () int32 -> (pad, ...) rows, wrapped
                idx = jax.lax.rem(off + jnp.arange(pad, dtype=jnp.int32),
                                  n_data)
                return jax.tree_util.tree_map(
                    lambda x: jnp.take(x, idx, axis=0), data)

            def body(s, ex):
                batch = MaskedBatch(data=jax.vmap(window)(ex["off"]),
                                    size=ex["size"])
                delay = s.step.astype(jnp.int32) - ex["rv"]  # endogenous
                s, aux = step_fn(*self._step_args(s, batch, delay, ex))
                return s, (aux if self.collect_aux else None)

            return jax.lax.scan(body, state, extra)

        if self.mesh is not None:
            ax = self.chain_axis
            chunk = shard_map(chunk, mesh=self.mesh,
                              in_specs=(P(ax), P(), P(None, ax)),
                              out_specs=(P(ax), P(None, ax)),
                              **SHARD_MAP_CHECK_KW)
        return jax.jit(chunk, donate_argnums=(0,) if self.donate else ())

    def _run_masked_chunk(self, state, data, extra, pad: int):
        fn = self._masked_chunks.get(pad)
        if fn is None:
            fn = self._masked_chunks[pad] = self._build_masked_chunk(pad)
        return fn(state, data, extra)

    # -- init -----------------------------------------------------------------
    def init(self, params: PyTree, key: jax.Array, *,
             jitter: float = 0.0) -> SamplerState:
        """C-chain ensemble state; chain ``c``'s key is ``split(key, C)[c]``."""
        state = init_ensemble(self.sampler, params, key,
                              num_chains=self.num_chains, jitter=jitter)
        if self.mesh is not None:
            sharding = jax.sharding.NamedSharding(self.mesh, P(self.chain_axis))
            state = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, sharding), state)
        return state

    # -- state export ---------------------------------------------------------
    def save_ensemble(self, state: SamplerState, path: str) -> None:
        """Export the chain bank: the chain-stacked params in the ensemble
        layout :func:`~repro.checkpoint.restore_ensemble` (and therefore
        :meth:`~repro.cluster.serve.ServeEngine.from_checkpoint`) restores,
        with the newest per-chain commit counter as the checkpoint step."""
        from repro.checkpoint import save_checkpoint

        save_checkpoint(path, state.params,
                        step=int(np.max(np.asarray(state.step))))

    # -- schedule normalization ------------------------------------------------
    def _compile_schedule(self, schedule: ScheduleLike, steps: int):
        """-> (extra dict of (steps, C) host arrays, commit_times | None,
        batch_info (sizes, offsets) | None).

        ``extra`` always carries ``rv`` (read versions); ``wid``/``slot``
        (worker attribution) join it under ``worker_rng``.
        """
        c = self.num_chains
        raw_delays = isinstance(schedule, (np.ndarray, jnp.ndarray))
        if schedule is None:
            scheds = [WorkerSchedule.sync(steps)] * c
        elif raw_delays:
            arr = np.asarray(schedule)
            if arr.ndim == 1:
                scheds = [WorkerSchedule.from_delays(arr)] * c
            elif arr.ndim == 2:
                scheds = [WorkerSchedule.from_delays(arr[:, i])
                          for i in range(arr.shape[1])]
            else:
                raise ValueError("delay array must be (steps,) or (steps, C)")
        else:
            scheds = ([schedule] * c if isinstance(schedule, WorkerSchedule)
                      else list(schedule))
        if len(scheds) != c:
            raise ValueError(f"got {len(scheds)} per-chain schedules for "
                             f"{c} chains")
        rv, times = stack_schedules(scheds, steps=steps)
        extra = {"rv": rv}
        if self.worker_rng:
            wid, slot = stack_worker_info(scheds, steps)
            extra["wid"], extra["slot"] = wid, slot
        # synthetic schedules (sync default, raw delay arrays) carry no
        # wall-clock information; don't present arange times as simulated
        times = None if (schedule is None or raw_delays) else times
        return extra, times, stack_batch_info(scheds, steps)

    def _compile_batch_plan(self, batch_info, batch_sizes, steps: int):
        """-> ((steps, C) int32 sizes, (steps, C) int64 offsets) for the
        masked path, honoring the engine's batch policy."""
        if self.batch_policy == "explicit":
            if batch_sizes is None:
                raise ValueError(
                    'batch_policy="explicit" needs batch_sizes= '
                    "((steps,) or (steps, C)) passed to run()")
            sizes = np.asarray(batch_sizes, np.int64)
            if sizes.ndim == 0:
                sizes = np.full((steps,), int(sizes), np.int64)
            if sizes.ndim == 1:
                sizes = np.tile(sizes[:, None], (1, self.num_chains))
            if sizes.shape[0] < steps:
                raise ValueError(f"batch_sizes has {sizes.shape[0]} entries, "
                                 f"need {steps}")
            sizes = sizes[:steps]
            snap = np.vectorize(lambda b: bucket_size(int(b), self.buckets))
            sizes = snap(sizes).astype(np.int32)
            offs = np.zeros_like(sizes, dtype=np.int64)
            np.cumsum(sizes[:-1].astype(np.int64), axis=0, out=offs[1:])
            return sizes, offs
        # inverse-speed: the schedule is the plan, offsets included
        if batch_info is None:
            raise ValueError(
                'batch_policy="inverse-speed" needs schedules carrying '
                'batch_sizes (ensemble_async(..., '
                'batch_policy="inverse-speed") or '
                "WorkerSchedule.with_batch_sizes)")
        return batch_info

    # -- host driver ----------------------------------------------------------
    def run(self, state: SamplerState, *, steps: int,
            schedule: ScheduleLike = None,
            batches: Optional[PyTree] = None,
            key: Optional[jax.Array] = None,
            data: Optional[PyTree] = None,
            batch_sizes: Optional[np.ndarray] = None):
        """Advance every chain ``steps`` commits under ``schedule``.

        ``schedule`` may be one :class:`WorkerSchedule` (broadcast), a
        sequence of C per-chain schedules, a raw delay ndarray
        (``(steps,)`` or ``(steps, C)``), or ``None`` (synchronous, tau=0).
        Returns ``(state, aux)`` with aux stacked ``(steps, C, ...)`` when
        ``collect_aux`` (plus ``commit_times`` threaded into hook aux when
        the schedule carries them).

        Under a non-fixed ``batch_policy``, ``data=`` is the shared example
        stream (pytree, leading axis = rows): commit ``k`` of chain ``c``
        consumes rows ``[offset, offset + size)`` — offsets wrap modulo the
        stream length, and restart at 0 on every :meth:`run` call — as a
        bucket-padded :class:`~repro.samplers.transforms.MaskedBatch`, and
        cumulative ``grad_evals`` are threaded into the hook aux next to
        ``commit_time``.
        """
        extra, commit_times, batch_info = self._compile_schedule(schedule,
                                                                 steps)
        staleness = (np.arange(steps, dtype=np.int64)[:, None] - extra["rv"])
        max_delay = int(staleness.max(initial=0))
        validate_staleness(max_delay, state.inner, context="schedule")
        self._m_staleness.observe_many(staleness.ravel())
        self._m_commits.inc(staleness.size)
        self._m_max_stale.set(float(max_delay))
        # schedule versions are relative to this run's first commit; rebase
        # onto the state's commit counter so continuation runs keep the
        # endogenous staleness (step - read_version) equal to the schedule's
        # tau_k instead of silently clamping at the ring depth.
        extra["rv"] = jnp.asarray(
            extra["rv"] + np.asarray(state.step)[None, :], jnp.int32)
        if self.worker_rng:
            # worker slots are schedule-relative too; rebase them the same
            # way so a continuation run folds fresh (wid, slot) pairs into
            # the noise keys instead of replaying the previous run's draws
            # (the carried chain key is deliberately untouched in this mode)
            extra["slot"] = jnp.asarray(
                extra["slot"] + np.asarray(state.step)[None, :], jnp.int32)

        if self.batch_policy != "fixed":
            if data is None:
                raise ValueError(f"batch_policy={self.batch_policy!r} needs "
                                 "a data= example stream passed to run()")
            if batches is not None:
                raise ValueError("pass either data= (heterogeneous masked "
                                 "windows) or batches=, not both")
            sizes, offs = self._compile_batch_plan(batch_info, batch_sizes,
                                                   steps)
            n_data = int(jax.tree_util.tree_leaves(data)[0].shape[0])
            extra["size"] = sizes
            extra["off"] = (offs % n_data).astype(np.int32)
            evals = np.cumsum(sizes.astype(np.int64), axis=0)
            self._m_grad_evals.inc(int(sizes.sum()))

            def chunk_info(done: int, n: int):
                rung = bucket_size(int(sizes[done:done + n].max()),
                                   self.buckets)
                return (rung,)

            return drive_chunks(
                self._run_masked_chunk, state, steps=steps,
                chunk_size=self.chunk_size, hooks=self.hooks,
                collect_aux=self.collect_aux, extra=extra, batches=data,
                slice_batches=False, chunk_info=chunk_info,
                commit_times=commit_times, host_aux={"grad_evals": evals})

        # explicit batches follow the per_chain_batches contract; generated
        # ones always carry a chain axis (one key per (step, chain))
        per_chain = (self.per_chain_batches if batches is not None
                     else self._make_batches is not None)
        run_chunk = self._chunk_per_chain if per_chain else self._chunk_shared

        def gen_batches(key, n):
            key, sub = jax.random.split(key)
            chunk_keys = jax.random.split(sub, n * self.num_chains)
            chunk_keys = chunk_keys.reshape(
                (n, self.num_chains) + chunk_keys.shape[1:])
            return key, self._make_batches(chunk_keys)

        return drive_chunks(
            run_chunk, state, steps=steps, chunk_size=self.chunk_size,
            hooks=self.hooks, collect_aux=self.collect_aux,
            extra=extra, batches=batches,
            gen_batches=gen_batches if self._make_batches is not None else None,
            key=key, commit_times=commit_times)

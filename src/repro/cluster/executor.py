"""ClusterEngine: the device-parallel multi-chain async-SGLD executor.

Same contract as :class:`repro.train.engine.Engine` — jitted ``lax.scan``
chunks, donated carry, host hooks between chunks, a flat retrace counter —
but the carry is a C-chain :func:`~repro.cluster.ensemble.init_ensemble`
state and each scan step advances the whole population through the vmapped
transform chain.

Delays are *endogenous*: the scan input is the schedule's per-chain
``read_versions`` and the jitted body derives staleness as
``server_version - read_version`` from the carried commit counter, so the
device executes the worker schedule instead of consuming a staleness
side-channel.  With ``mesh=`` the chunk body runs under the repo's
``shard_map`` compat shim with chains split over the ``data`` axis — pure
SPMD, no cross-chain communication, so per-chain trajectories are identical
sharded or not.

Batch sizes are part of the schedule: under ``batch_policy="inverse-speed"``
(or ``"explicit"``) every commit carries its own minibatch size and data
offset, and the scan body gathers a *bucket-padded* window from the ``data``
stream — each chunk pads to the bucket-ladder rung of its largest commit,
so a mixed-size schedule compiles **one trace per rung**, never one per
size (the discipline :class:`~repro.cluster.serve.ServeEngine` applies to
query batches).  The mask (:class:`~repro.samplers.transforms.MaskedBatch`)
keeps padding rows out of the gradient average.  The default
``batch_policy="fixed"`` is the legacy fixed-shape path, bit-identical to
the pre-heterogeneous executor.

Faults are first-class: a chaos schedule's per-commit liveness mask turns a
crashed worker's in-flight commit into a masked no-op inside the same scan
(same one-trace-per-rung contract); ``health_check=True`` carries a sticky
per-chain health mask through the scan (a NaN/Inf iterate quarantines the
chain on device, no retrace) with quarantined chains respawned from healthy
donors at chunk boundaries; and ``run(checkpoint_path=...)`` +
:meth:`ClusterEngine.resume` give preemption-tolerant restarts that stitch
bitwise against an uninterrupted run.  Every fault knob is opt-in and
structural: a zero-fault configuration threads no extra scan inputs and
compiles the exact pre-fault program.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis.instrument import counters as _counters
from repro.cluster.ensemble import ensemble_step, init_ensemble
from repro.cluster.schedule import (
    WorkerSchedule,
    stack_batch_info,
    stack_liveness,
    stack_schedules,
    stack_worker_info,
)
from repro.core.delay import validate_staleness
from repro.core.delay_model import BATCH_POLICIES
from repro.obs.metrics import STALENESS_BUCKETS, registry as _registry
from repro.obs.trace import span as _span
from repro.samplers.base import Sampler, SamplerState
from repro.samplers.transforms import MaskedBatch
from repro.train.engine import Hook, drive_chunks
from repro.utils import SHARD_MAP_CHECK_KW, bucket_size, shard_map

PyTree = Any
BatchFn = Callable[[jax.Array], PyTree]  # key -> one chain's batch (pure jax)

#: accepted `schedule=` forms for :meth:`ClusterEngine.run`
ScheduleLike = Any  # WorkerSchedule | Sequence[WorkerSchedule] | np.ndarray | None

#: fold_in tag minting a respawned chain's fresh noise stream from the
#: quarantined chain's (frozen) key — deterministic, so a resumed run
#: respawns identically to an uninterrupted one ("RES\x01")
_RESPAWN_TAG = 0x5245_5301


class HealthState(NamedTuple):
    """Scan carry under ``health_check``: the ensemble state plus the sticky
    per-chain health mask (``True`` = healthy, flips ``False`` forever —
    until respawn — once a chain's iterate goes NaN/Inf).

    Delegating properties keep the :class:`~repro.samplers.base.SamplerState`
    surface (``params``/``step``/``key``/``inner``), so hooks, recorders and
    ``save_ensemble`` work on either carry unchanged.
    """

    state: SamplerState
    health: jax.Array  # (C,) bool

    @property
    def params(self):
        """Chain-stacked iterate (delegates to the wrapped state)."""
        return self.state.params

    @property
    def step(self):
        """Per-chain commit counters (delegates to the wrapped state)."""
        return self.state.step

    @property
    def key(self):
        """Per-chain PRNG keys (delegates to the wrapped state)."""
        return self.state.key

    @property
    def inner(self):
        """Per-transform chain state (delegates to the wrapped state)."""
        return self.state.inner


def _chain_select(keep: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-chain ``jnp.where`` over chain-stacked pytrees: rows of chains
    with ``keep=False`` retain their old value (the masked no-op commit)."""
    def sel(n, o):
        mask = keep.reshape(keep.shape + (1,) * (n.ndim - 1))
        return jnp.where(mask, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def _finite_chains(params: PyTree) -> jax.Array:
    """(C,) bool: which chains' iterates are all-finite (float leaves)."""
    leaves = jax.tree_util.tree_leaves(params)
    c = leaves[0].shape[0]
    ok = jnp.ones((c,), bool)
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            ok &= jnp.all(jnp.isfinite(leaf.reshape(c, -1)), axis=1)
    return ok


def _poison_chains(bad: jax.Array, params: PyTree) -> PyTree:
    """NaN the float leaves of chains with ``bad=True`` (fault injection)."""
    def nanify(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        mask = bad.reshape(bad.shape + (1,) * (x.ndim - 1))
        return jnp.where(mask, jnp.asarray(jnp.nan, x.dtype), x)
    return jax.tree_util.tree_map(nanify, params)


@dataclass
class ClusterEngine:
    """Scan-chunked executor for a C-chain async-SGLD ensemble.

    ``batch_fn(key) -> batch`` (optional) generates an *independent*
    minibatch per (step, chain) key on device; explicit ``batches`` passed to
    :meth:`run` are broadcast to every chain unless ``per_chain_batches=True``
    (then their second axis is the chain axis).  ``mesh`` shards the chain
    axis over ``chain_axis`` (``num_chains`` must be divisible by that mesh
    axis size).

    ``batch_policy`` selects how commits consume data:

    - ``"fixed"`` (default): one fixed-shape minibatch per commit — the
      legacy contract, bit-identical to the pre-heterogeneous executor.
    - ``"inverse-speed"``: per-commit sizes come from the schedule's
      ``batch_sizes`` (compiled from a
      :meth:`WorkerModel.batch_sizes <repro.core.delay_model.WorkerModel>`
      policy: slow workers amortize staleness over large batches); commits
      consume bucket-padded masked windows of the ``data=`` stream passed to
      :meth:`run`.
    - ``"explicit"``: like inverse-speed, but sizes come from the
      ``batch_sizes=`` array passed to :meth:`run` (snapped up the
      ``buckets`` ladder).

    The sampler must use the per-example masked-oracle contract for the
    non-fixed policies (``samplers.sgld(..., base_batch=...)`` or a chain
    containing :func:`~repro.samplers.transforms.masked_gradients`).

    ``worker_rng`` derives each commit's noise key from
    ``(chain key, worker_id, worker-local slot)`` instead of the carried
    sequential split, making every worker's noise stream reproducible
    independently of commit order (see
    :func:`~repro.cluster.ensemble.worker_keys`).

    ``health_check=True`` threads a sticky per-chain health mask through the
    scan (:class:`HealthState` carry): a chain whose iterate goes NaN/Inf is
    quarantined *on device* — its subsequent commits become masked no-ops —
    and, with ``respawn=True``, is recloned from a healthy donor chain with
    a fresh ``fold_in`` noise key at the next chunk boundary.  Both default
    off; a zero-fault configuration compiles the exact pre-fault program.
    """

    sampler: Sampler
    num_chains: int
    chunk_size: int = 50
    hooks: Sequence[Hook] = ()
    donate: bool = True
    collect_aux: bool = False
    batch_fn: Optional[BatchFn] = None
    per_chain_batches: bool = False
    mesh: Any = None
    chain_axis: str = "data"
    batch_policy: str = "fixed"
    buckets: Optional[Sequence[int]] = None
    worker_rng: bool = False
    health_check: bool = False
    respawn: bool = True

    def __post_init__(self):
        self._counters = _counters("ClusterEngine")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.num_chains < 1:
            raise ValueError(f"num_chains must be >= 1, got {self.num_chains}")
        if self.batch_policy not in BATCH_POLICIES:
            raise ValueError(f"unknown batch_policy {self.batch_policy!r} "
                             f"(choose from {BATCH_POLICIES})")
        if self.batch_policy != "fixed" and self.batch_fn is not None:
            raise ValueError(
                "batch_fn generates fixed-shape minibatches; heterogeneous "
                "batch policies consume a `data=` stream passed to run()")
        if self.mesh is not None:
            n_shards = self.mesh.shape[self.chain_axis]
            if self.num_chains % n_shards:
                raise ValueError(
                    f"num_chains={self.num_chains} must be divisible by mesh "
                    f"axis {self.chain_axis!r} (size {n_shards})")
        # one jitted chunk per batch layout; only the layouts actually run
        # get traced/compiled (the counter they bump is shared)
        self._chunk_shared = self._build_chunk(batch_axis=None)
        self._chunk_per_chain = self._build_chunk(batch_axis=0)
        self._masked_chunks: dict = {}  # pad width -> jitted masked chunk
        self._make_batches = (jax.jit(jax.vmap(jax.vmap(self.batch_fn)))
                              if self.batch_fn is not None else None)
        reg = _registry()
        self._m_staleness = reg.histogram(
            "cluster.staleness", STALENESS_BUCKETS,
            "per-commit staleness tau = version - read_version")
        self._m_commits = reg.counter(
            "cluster.commits", "commits executed (steps x chains)")
        self._m_grad_evals = reg.counter(
            "cluster.grad_evals",
            "per-example gradient evaluations (non-fixed batch policies)")
        self._m_max_stale = reg.gauge(
            "cluster.max_staleness", "largest tau in the newest schedule")
        self._m_faults = reg.counter(
            "faults.injected",
            "fault events injected (lost commits + NaN poisons)")
        self._m_quarantined = reg.counter(
            "chains.quarantined",
            "chains newly quarantined by the sticky health mask")
        self._m_respawned = reg.counter(
            "chains.respawned",
            "quarantined chains respawned from a healthy donor")
        self._m_unhealthy = reg.gauge(
            "chains.unhealthy", "chains currently quarantined")

    @property
    def num_traces(self) -> int:
        """Jit traces so far (one per chunk layout / bucket rung) — a thin
        view over the engine's :mod:`repro.analysis.instrument` counters."""
        return self._counters.traces

    def _step_fn(self, batch_axis: Optional[int]):
        return ensemble_step(self.sampler, batch_axis=batch_axis,
                             worker_rng=self.worker_rng)

    def _step_args(self, s, batch, delay, ex):
        if self.worker_rng:
            return (s, batch, delay, ex["wid"], ex["slot"])
        return (s, batch, delay)

    def _advance(self, step_fn, carry, batch, ex):
        """One population commit with the fault/health guards.

        The guards are *structural*: ``"alive"``/``"poison"`` membership in
        ``ex`` and the carry's :class:`HealthState`-ness are trace-time
        facts, so a zero-fault run traces the exact pre-fault body.  The
        commit counter always advances — a masked no-op still burns its
        version slot, keeping the endogenous ``step - read_version``
        staleness aligned with the schedule's all-commit numbering.
        """
        if isinstance(carry, HealthState):
            s, health = carry.state, carry.health
        else:
            s, health = carry, None
        delay = s.step.astype(jnp.int32) - ex["rv"]  # endogenous
        s_new, aux = step_fn(*self._step_args(s, batch, delay, ex))
        if "poison" in ex:
            s_new = s_new._replace(
                params=_poison_chains(ex["poison"], s_new.params))
        keep = None
        if health is not None:
            health = health & _finite_chains(s_new.params)  # sticky flip
            keep = health
        if "alive" in ex:
            keep = ex["alive"] if keep is None else keep & ex["alive"]
        if keep is not None:
            s_new = SamplerState(
                params=_chain_select(keep, s_new.params, s.params),
                step=s_new.step,
                key=_chain_select(keep, s_new.key, s.key),
                inner=_chain_select(keep, s_new.inner, s.inner))
        out = s_new if health is None else HealthState(s_new, health)
        return out, (aux if self.collect_aux else None)

    def _build_chunk(self, batch_axis: Optional[int]):
        """Jitted scan over one chunk; ``batch_axis=0`` vmaps the batch over
        the chain axis, ``None`` broadcasts one batch to every chain."""

        def chunk(state, batches, extra):
            # python side effect: runs once per trace, never per call
            self._counters.trace(f"chunk[batch_axis={batch_axis}]")
            step_fn = self._step_fn(batch_axis)

            def body(s, inp):
                batch, ex = inp
                return self._advance(step_fn, s, batch, ex)

            return jax.lax.scan(body, state, (batches, extra))

        if self.mesh is not None:
            ax = self.chain_axis
            batch_spec = P(None, ax) if batch_axis == 0 else P()
            chunk = shard_map(chunk, mesh=self.mesh,
                              in_specs=(P(ax), batch_spec, P(None, ax)),
                              out_specs=(P(ax), P(None, ax)),
                              **SHARD_MAP_CHECK_KW)
        return jax.jit(chunk, donate_argnums=(0,) if self.donate else ())

    def _build_masked_chunk(self, pad: int):
        """Jitted scan whose per-step batch is a bucket-padded masked window
        of the data stream: ``pad`` is the chunk's ladder rung (static —
        one trace per rung), ``extra`` carries per-(step, chain) data
        offsets and real sizes, and the gather wraps modulo the stream
        length so offsets never index out of bounds."""

        def chunk(state, data, extra):
            # python side effect: runs once per trace, never per call
            self._counters.trace(f"masked_chunk[pad={pad}]")
            step_fn = self._step_fn(0)
            n_data = jax.tree_util.tree_leaves(data)[0].shape[0]

            def window(off):  # () int32 -> (pad, ...) rows, wrapped
                idx = jax.lax.rem(off + jnp.arange(pad, dtype=jnp.int32),
                                  n_data)
                return jax.tree_util.tree_map(
                    lambda x: jnp.take(x, idx, axis=0), data)

            def body(s, ex):
                batch = MaskedBatch(data=jax.vmap(window)(ex["off"]),
                                    size=ex["size"])
                return self._advance(step_fn, s, batch, ex)

            return jax.lax.scan(body, state, extra)

        if self.mesh is not None:
            ax = self.chain_axis
            chunk = shard_map(chunk, mesh=self.mesh,
                              in_specs=(P(ax), P(), P(None, ax)),
                              out_specs=(P(ax), P(None, ax)),
                              **SHARD_MAP_CHECK_KW)
        return jax.jit(chunk, donate_argnums=(0,) if self.donate else ())

    def _run_masked_chunk(self, state, data, extra, pad: int):
        fn = self._masked_chunks.get(pad)
        if fn is None:
            fn = self._masked_chunks[pad] = self._build_masked_chunk(pad)
        return fn(state, data, extra)

    # -- init -----------------------------------------------------------------
    def init(self, params: PyTree, key: jax.Array, *,
             jitter: float = 0.0) -> SamplerState:
        """C-chain ensemble state; chain ``c``'s key is ``split(key, C)[c]``."""
        state = init_ensemble(self.sampler, params, key,
                              num_chains=self.num_chains, jitter=jitter)
        if self.mesh is not None:
            sharding = jax.sharding.NamedSharding(self.mesh, P(self.chain_axis))
            state = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, sharding), state)
        return state

    # -- state export ---------------------------------------------------------
    def save_ensemble(self, state: SamplerState, path: str) -> None:
        """Export the chain bank: the chain-stacked params in the ensemble
        layout :func:`~repro.checkpoint.restore_ensemble` (and therefore
        :meth:`~repro.cluster.serve.ServeEngine.from_checkpoint`) restores,
        with the newest per-chain commit counter as the checkpoint step."""
        from repro.checkpoint import save_checkpoint

        save_checkpoint(path, state.params,
                        step=int(np.max(np.asarray(state.step))))

    # -- schedule normalization ------------------------------------------------
    def _compile_schedule(self, schedule: ScheduleLike, steps: int):
        """-> (extra dict of (steps, C) host arrays, commit_times | None,
        batch_info (sizes, offsets) | None).

        ``extra`` always carries ``rv`` (read versions); ``wid``/``slot``
        (worker attribution) join it under ``worker_rng``, and ``alive``
        (commit liveness) joins it only when a chaos schedule actually lost
        a commit — fault-free schedules compile the pre-fault program.
        """
        c = self.num_chains
        raw_delays = isinstance(schedule, (np.ndarray, jnp.ndarray))
        if schedule is None:
            scheds = [WorkerSchedule.sync(steps)] * c
        elif raw_delays:
            arr = np.asarray(schedule)
            if arr.ndim == 1:
                scheds = [WorkerSchedule.from_delays(arr)] * c
            elif arr.ndim == 2:
                scheds = [WorkerSchedule.from_delays(arr[:, i])
                          for i in range(arr.shape[1])]
            else:
                raise ValueError("delay array must be (steps,) or (steps, C)")
        else:
            scheds = ([schedule] * c if isinstance(schedule, WorkerSchedule)
                      else list(schedule))
        if len(scheds) != c:
            raise ValueError(f"got {len(scheds)} per-chain schedules for "
                             f"{c} chains")
        rv, times = stack_schedules(scheds, steps=steps)
        extra = {"rv": rv}
        if self.worker_rng:
            wid, slot = stack_worker_info(scheds, steps)
            extra["wid"], extra["slot"] = wid, slot
        live = stack_liveness(scheds, steps)
        if live is not None:
            extra["alive"] = live
        # synthetic schedules (sync default, raw delay arrays) carry no
        # wall-clock information; don't present arange times as simulated
        times = None if (schedule is None or raw_delays) else times
        return extra, times, stack_batch_info(scheds, steps)

    def _compile_batch_plan(self, batch_info, batch_sizes, steps: int):
        """-> ((steps, C) int32 sizes, (steps, C) int64 offsets) for the
        masked path, honoring the engine's batch policy."""
        if self.batch_policy == "explicit":
            if batch_sizes is None:
                raise ValueError(
                    'batch_policy="explicit" needs batch_sizes= '
                    "((steps,) or (steps, C)) passed to run()")
            sizes = np.asarray(batch_sizes, np.int64)
            if sizes.ndim == 0:
                sizes = np.full((steps,), int(sizes), np.int64)
            if sizes.ndim == 1:
                sizes = np.tile(sizes[:, None], (1, self.num_chains))
            if sizes.shape[0] < steps:
                raise ValueError(f"batch_sizes has {sizes.shape[0]} entries, "
                                 f"need {steps}")
            sizes = sizes[:steps]
            snap = np.vectorize(lambda b: bucket_size(int(b), self.buckets))
            sizes = snap(sizes).astype(np.int32)
            offs = np.zeros_like(sizes, dtype=np.int64)
            np.cumsum(sizes[:-1].astype(np.int64), axis=0, out=offs[1:])
            return sizes, offs
        # inverse-speed: the schedule is the plan, offsets included
        if batch_info is None:
            raise ValueError(
                'batch_policy="inverse-speed" needs schedules carrying '
                'batch_sizes (ensemble_async(..., '
                'batch_policy="inverse-speed") or '
                "WorkerSchedule.with_batch_sizes)")
        return batch_info

    # -- fault tolerance -------------------------------------------------------
    @staticmethod
    def _put_like(arr, like):
        """Device-put a host array with ``like``'s sharding (identity
        placement when ``like`` carries none)."""
        if isinstance(like, jax.Array):
            return jax.device_put(jnp.asarray(arr), like.sharding)
        return jnp.asarray(arr)

    def _as_carry(self, state):
        """Wrap ``state`` into the carry :meth:`run` scans: a
        :class:`HealthState` (all-healthy) under ``health_check``."""
        if not self.health_check or isinstance(state, HealthState):
            return state
        health = jnp.ones((self.num_chains,), bool)
        if self.mesh is not None:
            health = jax.device_put(health, jax.sharding.NamedSharding(
                self.mesh, P(self.chain_axis)))
        return HealthState(state, health)

    def _heal(self, carry: HealthState, prev_health) -> HealthState:
        """Chunk-boundary quarantine bookkeeping and (optional) respawn.

        Quarantined chains are recloned from healthy donors (round-robin):
        donor params/inner replace the sick chain's, and the sick chain's
        *frozen* key is ``fold_in``-minted into a fresh noise stream — all
        a deterministic function of the carried state, so a resumed run
        respawns identically to an uninterrupted one.
        """
        health = np.asarray(carry.health)
        sick = np.flatnonzero(~health)
        newly = int((~health & prev_health[0]).sum())
        prev_health[0] = health
        if newly:
            self._m_quarantined.inc(newly)
        self._m_unhealthy.set(float(sick.size))
        if sick.size == 0 or not self.respawn:
            return carry
        donors = np.flatnonzero(health)
        if donors.size == 0:
            return carry  # total loss — nothing healthy left to clone
        donor = donors[np.arange(sick.size) % donors.size]
        state = carry.state

        def clone(leaf):
            a = np.array(leaf)
            a[sick] = a[donor]
            return self._put_like(a, leaf)

        with _span("faults.respawn", chains=[int(i) for i in sick],
                   donors=[int(i) for i in donor]):
            keys = np.array(state.key)
            fresh = jax.vmap(
                lambda k: jax.random.fold_in(k, _RESPAWN_TAG))(
                    jnp.asarray(keys[sick]))
            keys[sick] = np.asarray(fresh)
            healed = SamplerState(
                params=jax.tree_util.tree_map(clone, state.params),
                step=state.step,  # commit counters tick in lockstep
                key=self._put_like(keys, state.key),
                inner=jax.tree_util.tree_map(clone, state.inner))
            health = self._put_like(np.ones_like(health), carry.health)
        self._m_respawned.inc(int(sick.size))
        prev_health[0] = np.asarray(health)
        return HealthState(healed, health)

    def _save_run_checkpoint(self, path: str, carry, done: int,
                             base: np.ndarray) -> None:
        from repro.checkpoint import save_checkpoint

        save_checkpoint(path, {"carry": carry, "manifest": {
            "done": np.asarray(done, np.int64),
            "base": np.asarray(base, np.int64)}}, step=int(done))

    def _load_run_checkpoint(self, path: str, state):
        from repro.checkpoint import restore_checkpoint

        template = self._as_carry(state)
        like = {"carry": template, "manifest": {
            "done": np.zeros((), np.int64),
            "base": np.zeros((self.num_chains,), np.int64)}}
        tree = restore_checkpoint(path, like)
        carry = jax.tree_util.tree_map(
            lambda t, x: self._put_like(x, t), template, tree["carry"])
        return (carry, int(tree["manifest"]["done"]),
                np.asarray(tree["manifest"]["base"]))

    # -- host driver ----------------------------------------------------------
    def run(self, state: SamplerState, *, steps: int,
            schedule: ScheduleLike = None,
            batches: Optional[PyTree] = None,
            key: Optional[jax.Array] = None,
            data: Optional[PyTree] = None,
            batch_sizes: Optional[np.ndarray] = None,
            poison: Optional[np.ndarray] = None,
            checkpoint_path: Optional[str] = None,
            checkpoint_every: Optional[int] = None):
        """Advance every chain ``steps`` commits under ``schedule``.

        ``schedule`` may be one :class:`WorkerSchedule` (broadcast), a
        sequence of C per-chain schedules, a raw delay ndarray
        (``(steps,)`` or ``(steps, C)``), or ``None`` (synchronous, tau=0).
        Returns ``(state, aux)`` with aux stacked ``(steps, C, ...)`` when
        ``collect_aux`` (plus ``commit_times`` threaded into hook aux when
        the schedule carries them).

        Under a non-fixed ``batch_policy``, ``data=`` is the shared example
        stream (pytree, leading axis = rows): commit ``k`` of chain ``c``
        consumes rows ``[offset, offset + size)`` — offsets wrap modulo the
        stream length, and restart at 0 on every :meth:`run` call — as a
        bucket-padded :class:`~repro.samplers.transforms.MaskedBatch`, and
        cumulative ``grad_evals`` are threaded into the hook aux next to
        ``commit_time``.

        Fault knobs (all opt-in, all structurally invisible when unused):

        - chaos schedules carrying an ``alive`` mask execute lost commits
          as masked no-ops (the version slot still burns);
        - ``poison`` — a ``(steps, C)`` bool mask NaN'ing chain iterates at
          chosen commits (deterministic fault injection for tests/bench);
        - ``checkpoint_path`` — write an atomic resumable checkpoint (carry
          + manifest) at every chunk boundary, or every ``checkpoint_every``
          commits; :meth:`resume` stitches bitwise from the newest one.
        """
        return self._run(state, steps=steps, schedule=schedule,
                         batches=batches, key=key, data=data,
                         batch_sizes=batch_sizes, poison=poison,
                         checkpoint_path=checkpoint_path,
                         checkpoint_every=checkpoint_every,
                         start=0, base_steps=None)

    def resume(self, checkpoint_path: str, state: SamplerState, *,
               steps: int, **kw):
        """Continue an interrupted ``run(checkpoint_path=...)`` bitwise.

        ``state`` is the same *initial* ensemble state the interrupted run
        started from (it supplies the carry's structure and shardings); the
        remaining args must repeat the interrupted call.  A missing
        checkpoint file starts the run from scratch (writing checkpoints to
        the same path); a truncated or bit-flipped one raises
        :class:`~repro.checkpoint.CorruptCheckpointError` loudly.  Returns
        ``(state, aux)`` where aux covers only the commits actually run.
        """
        if not os.path.exists(checkpoint_path):
            return self.run(state, steps=steps,
                            checkpoint_path=checkpoint_path, **kw)
        carry, done, base = self._load_run_checkpoint(checkpoint_path, state)
        if done >= steps:
            return carry, None
        return self._run(carry, steps=steps, start=done, base_steps=base,
                         checkpoint_path=checkpoint_path, **kw)

    def _run(self, state, *, steps, schedule=None, batches=None, key=None,
             data=None, batch_sizes=None, poison=None, checkpoint_path=None,
             checkpoint_every=None, start=0, base_steps=None):
        extra, commit_times, batch_info = self._compile_schedule(schedule,
                                                                 steps)
        staleness = (np.arange(steps, dtype=np.int64)[:, None] - extra["rv"])
        max_delay = int(staleness.max(initial=0))
        validate_staleness(max_delay, state.inner, context="schedule")
        self._m_staleness.observe_many(staleness.ravel())
        self._m_commits.inc(staleness.size)
        self._m_max_stale.set(float(max_delay))
        if poison is not None:
            pz = np.asarray(poison, bool)
            if pz.shape != (steps, self.num_chains):
                raise ValueError(
                    f"poison must be (steps, C) = ({steps}, "
                    f"{self.num_chains}), got {pz.shape}")
            if pz.any():
                extra["poison"] = pz
        n_faults = ((int((~extra["alive"]).sum()) if "alive" in extra else 0)
                    + (int(extra["poison"].sum()) if "poison" in extra else 0))
        if n_faults:
            self._m_faults.inc(n_faults)
        # schedule versions are relative to the run's first commit; rebase
        # onto the *initial* commit counter (the carried one on a fresh run,
        # the manifest's on a resume) so continuation runs keep the
        # endogenous staleness (step - read_version) equal to the schedule's
        # tau_k instead of silently clamping at the ring depth.
        base = np.asarray(state.step if base_steps is None else base_steps)
        extra["rv"] = jnp.asarray(extra["rv"] + base[None, :], jnp.int32)
        if self.worker_rng:
            # worker slots are schedule-relative too; rebase them the same
            # way so a continuation run folds fresh (wid, slot) pairs into
            # the noise keys instead of replaying the previous run's draws
            # (the carried chain key is deliberately untouched in this mode)
            extra["slot"] = jnp.asarray(
                extra["slot"] + base[None, :], jnp.int32)

        carry = self._as_carry(state)
        use_health = isinstance(carry, HealthState)
        chunk_post = None
        if use_health or checkpoint_path is not None:
            prev_health = [np.asarray(carry.health) if use_health else None]
            last_saved = [start]

            def chunk_post(done: int, st):
                if use_health:
                    st = self._heal(st, prev_health)
                if checkpoint_path is not None:
                    absolute = start + done
                    if (checkpoint_every is None
                            or absolute - last_saved[0] >= checkpoint_every
                            or absolute >= steps):
                        self._save_run_checkpoint(checkpoint_path, st,
                                                  absolute, base)
                        last_saved[0] = absolute
                return st

        if self.batch_policy != "fixed":
            if data is None:
                raise ValueError(f"batch_policy={self.batch_policy!r} needs "
                                 "a data= example stream passed to run()")
            if batches is not None:
                raise ValueError("pass either data= (heterogeneous masked "
                                 "windows) or batches=, not both")
            sizes, offs = self._compile_batch_plan(batch_info, batch_sizes,
                                                   steps)
            n_data = int(jax.tree_util.tree_leaves(data)[0].shape[0])
            extra["size"] = sizes
            extra["off"] = (offs % n_data).astype(np.int32)
            evals = np.cumsum(sizes.astype(np.int64), axis=0)
            self._m_grad_evals.inc(int(sizes.sum()))
            if start:
                # resume: drop the commits already executed.  Checkpoints
                # land on chunk boundaries, so the remaining chunk grid (and
                # with it every bucket rung choice) matches the
                # uninterrupted run's — a precondition for bitwise stitching.
                extra = jax.tree_util.tree_map(lambda x: x[start:], extra)
                sizes = sizes[start:]
                evals = evals[start:]
                if commit_times is not None:
                    commit_times = commit_times[start:]

            def chunk_info(done: int, n: int):
                rung = bucket_size(int(sizes[done:done + n].max()),
                                   self.buckets)
                return (rung,)

            return drive_chunks(
                self._run_masked_chunk, carry, steps=steps - start,
                chunk_size=self.chunk_size, hooks=self.hooks,
                collect_aux=self.collect_aux, extra=extra, batches=data,
                slice_batches=False, chunk_info=chunk_info,
                commit_times=commit_times, host_aux={"grad_evals": evals},
                chunk_post=chunk_post)

        # explicit batches follow the per_chain_batches contract; generated
        # ones always carry a chain axis (one key per (step, chain))
        per_chain = (self.per_chain_batches if batches is not None
                     else self._make_batches is not None)
        run_chunk = self._chunk_per_chain if per_chain else self._chunk_shared

        def gen_batches(key, n):
            key, sub = jax.random.split(key)
            chunk_keys = jax.random.split(sub, n * self.num_chains)
            chunk_keys = chunk_keys.reshape(
                (n, self.num_chains) + chunk_keys.shape[1:])
            return key, self._make_batches(chunk_keys)

        if start:
            extra = jax.tree_util.tree_map(lambda x: x[start:], extra)
            if commit_times is not None:
                commit_times = commit_times[start:]
            if batches is not None:
                batches = jax.tree_util.tree_map(lambda x: x[start:], batches)
            if self._make_batches is not None and key is not None:
                # fast-forward the batch key stream: one split was consumed
                # per completed chunk (checkpoints land on chunk boundaries)
                for _ in range(start // self.chunk_size):
                    key, _ = jax.random.split(key)

        return drive_chunks(
            run_chunk, carry, steps=steps - start,
            chunk_size=self.chunk_size,
            hooks=self.hooks, collect_aux=self.collect_aux,
            extra=extra, batches=batches,
            gen_batches=gen_batches if self._make_batches is not None else None,
            key=key, commit_times=commit_times, chunk_post=chunk_post)

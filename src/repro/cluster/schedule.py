"""Executable worker schedules: the compiled form of a :class:`DelayTrace`.

A :class:`~repro.core.delay_model.DelayTrace` records *realized staleness*
``tau_k`` per commit — an exogenous host-side artifact.  A
:class:`WorkerSchedule` re-expresses the same simulated execution as the
thing the paper's P workers actually do: commit ``k`` was produced by worker
``worker_ids[k]`` which *read* the shared iterate at server version
``read_versions[k] = k - tau_k`` and committed at wall-clock
``commit_times[k]``.

The executor feeds ``read_versions`` to the device; the jitted step derives
staleness *endogenously* as ``version_now - read_version`` (the server's
commit counter is the scan carry), so delays are a consequence of the
schedule rather than a side-channel input.  Because ``version_now == k`` in
trace order, the derived staleness reproduces ``trace.delays`` exactly —
which is what keeps the ensemble bit-compatible with the single-chain
:class:`~repro.train.engine.Engine`.

``stack_schedules`` batches C independent per-chain schedules into the
``(steps, C)`` arrays the vmapped ensemble scans over; ``ensemble_async``
builds them straight from a :class:`~repro.core.delay_model.WorkerModel`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.delay import StalenessError  # noqa: F401  (re-exported)
from repro.core.delay import check_staleness_fits
from repro.core.delay_model import DelayTrace, WorkerModel, simulate_async


@dataclass(frozen=True)
class WorkerSchedule:
    """One chain's compiled commit schedule (trace order = commit order)."""

    read_versions: np.ndarray  # (num_commits,) int32: server version each read saw
    worker_ids: np.ndarray     # (num_commits,) int32: which worker committed
    commit_times: np.ndarray   # (num_commits,) float64: simulated wall clock
    num_workers: int

    def __post_init__(self):
        k = np.arange(len(self.read_versions))
        if np.any(self.read_versions < 0) or np.any(self.read_versions > k):
            raise ValueError("read_versions must satisfy 0 <= v_read[k] <= k")

    def __len__(self) -> int:
        return int(self.read_versions.shape[0])

    @property
    def delays(self) -> np.ndarray:
        """Realized staleness tau_k = k - read_version[k] (host view)."""
        return (np.arange(len(self), dtype=np.int64)
                - self.read_versions).astype(np.int32)

    @property
    def max_delay(self) -> int:
        return int(self.delays.max(initial=0))

    @classmethod
    def from_trace(cls, trace: DelayTrace) -> "WorkerSchedule":
        k = np.arange(len(trace.delays), dtype=np.int64)
        return cls(read_versions=(k - trace.delays).astype(np.int32),
                   worker_ids=np.asarray(trace.worker_ids, np.int32),
                   commit_times=np.asarray(trace.commit_times, np.float64),
                   num_workers=trace.num_workers)

    @classmethod
    def from_delays(cls, delays: np.ndarray,
                    commit_times: np.ndarray | None = None) -> "WorkerSchedule":
        delays = np.asarray(delays, np.int64)
        k = np.arange(len(delays), dtype=np.int64)
        times = (np.arange(1, len(delays) + 1, dtype=np.float64)
                 if commit_times is None else np.asarray(commit_times, np.float64))
        return cls(read_versions=(k - delays).astype(np.int32),
                   worker_ids=np.zeros(len(delays), np.int32),
                   commit_times=times, num_workers=1)

    @classmethod
    def sync(cls, num_commits: int) -> "WorkerSchedule":
        """Barrier baseline: every read is fresh (tau = 0)."""
        return cls.from_delays(np.zeros(num_commits, np.int32))

    def validate_ring(self, depth: int, context: str = "") -> None:
        """Raise unless every read the schedule demands fits in the ring."""
        check_staleness_fits(self.max_delay, depth, context or "schedule")

    def to_trace(self) -> DelayTrace:
        return DelayTrace(delays=self.delays, commit_times=self.commit_times,
                          worker_ids=self.worker_ids,
                          num_workers=self.num_workers)


def stack_schedules(schedules: Sequence[WorkerSchedule],
                    steps: int | None = None):
    """Batch C per-chain schedules into ``(steps, C)`` arrays.

    Returns ``(read_versions, commit_times)`` with the step axis leading, the
    layout the executor's ``lax.scan`` consumes directly.  With ``steps``
    each schedule is trimmed to its first ``steps`` commits (every schedule
    must cover that many); without it the schedules must share one length.
    """
    if steps is None:
        lengths = {len(s) for s in schedules}
        if len(lengths) != 1:
            raise ValueError("chains must share a commit count, got lengths "
                             f"{sorted(lengths)} (or pass steps= to trim)")
        steps = lengths.pop()
    short = min(len(s) for s in schedules)
    if short < steps:
        raise ValueError(f"schedule covers {short} commits, need {steps}")
    rv = np.stack([s.read_versions[:steps] for s in schedules], axis=1)
    times = np.stack([s.commit_times[:steps] for s in schedules], axis=1)
    return rv.astype(np.int32), times


def ensemble_async(model: WorkerModel, num_commits: int, num_chains: int,
                   seed: int = 0) -> list[WorkerSchedule]:
    """C independent async executions of the same worker pool (chain c gets
    its own event-driven simulation seeded ``seed + c``)."""
    return [WorkerSchedule.from_trace(simulate_async(model, num_commits,
                                                     seed=seed + c))
            for c in range(num_chains)]

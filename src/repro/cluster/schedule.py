"""Executable worker schedules: the compiled form of a :class:`DelayTrace`.

A :class:`~repro.core.delay_model.DelayTrace` records *realized staleness*
``tau_k`` per commit — an exogenous host-side artifact.  A
:class:`WorkerSchedule` re-expresses the same simulated execution as the
thing the paper's P workers actually do: commit ``k`` was produced by worker
``worker_ids[k]`` which *read* the shared iterate at server version
``read_versions[k] = k - tau_k`` and committed at wall-clock
``commit_times[k]``.

The executor feeds ``read_versions`` to the device; the jitted step derives
staleness *endogenously* as ``version_now - read_version`` (the server's
commit counter is the scan carry), so delays are a consequence of the
schedule rather than a side-channel input.  Because ``version_now == k`` in
trace order, the derived staleness reproduces ``trace.delays`` exactly —
which is what keeps the ensemble bit-compatible with the single-chain
:class:`~repro.train.engine.Engine`.

``stack_schedules`` batches C independent per-chain schedules into the
``(steps, C)`` arrays the vmapped ensemble scans over; ``ensemble_async``
builds them straight from a :class:`~repro.core.delay_model.WorkerModel`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.delay import StalenessError  # noqa: F401  (re-exported)
from repro.core.delay import check_staleness_fits
from repro.core.delay_model import DelayTrace, WorkerModel, simulate_async
from repro.utils import bucket_size


@dataclass(frozen=True)
class WorkerSchedule:
    """One chain's compiled commit schedule (trace order = commit order).

    ``batch_sizes`` (optional) is the bucketed per-commit minibatch size —
    how much data the committing worker averaged its delayed gradient over.
    The compiled form also carries :attr:`data_offsets`: commit ``k``
    consumes rows ``[offset_k, offset_k + batch_sizes[k])`` of the chain's
    data stream, so the executor's padded windowed gather needs no host
    bookkeeping.

    ``alive`` (optional) is the per-commit liveness mask from a chaos
    schedule (see :class:`~repro.core.delay_model.FaultPlan`): ``False``
    commits are crashed workers' lost updates, which the executor executes
    as masked no-ops.  ``None`` — the fault-free contract — keeps every
    downstream code path bitwise identical to pre-fault behavior.
    """

    read_versions: np.ndarray  # (num_commits,) int32: server version each read saw
    worker_ids: np.ndarray     # (num_commits,) int32: which worker committed
    commit_times: np.ndarray   # (num_commits,) float64: simulated wall clock
    num_workers: int
    batch_sizes: np.ndarray | None = None  # (num_commits,) int32 per commit
    alive: np.ndarray | None = None        # (num_commits,) bool, False = lost

    def __post_init__(self):
        k = np.arange(len(self.read_versions))
        if np.any(self.read_versions < 0) or np.any(self.read_versions > k):
            raise ValueError("read_versions must satisfy 0 <= v_read[k] <= k")
        if self.batch_sizes is not None:
            sizes = np.asarray(self.batch_sizes, np.int32)
            if sizes.shape != self.read_versions.shape:
                raise ValueError(
                    f"batch_sizes shape {sizes.shape} must match "
                    f"read_versions shape {self.read_versions.shape}")
            if np.any(sizes < 1):
                raise ValueError("batch_sizes must be >= 1 per commit")
            object.__setattr__(self, "batch_sizes", sizes)
        if self.alive is not None:
            live = np.asarray(self.alive, bool)
            if live.shape != self.read_versions.shape:
                raise ValueError(
                    f"alive shape {live.shape} must match read_versions "
                    f"shape {self.read_versions.shape}")
            object.__setattr__(self, "alive", live)

    def __len__(self) -> int:
        return int(self.read_versions.shape[0])

    @property
    def delays(self) -> np.ndarray:
        """Realized staleness tau_k = k - read_version[k] (host view)."""
        return (np.arange(len(self), dtype=np.int64)
                - self.read_versions).astype(np.int32)

    @property
    def max_delay(self) -> int:
        """Largest realized staleness in the schedule (0 when empty) — the
        floor on the ring depth any executor needs to replay it."""
        return int(self.delays.max(initial=0))

    @property
    def data_offsets(self) -> np.ndarray | None:
        """Per-commit start row in the chain's data stream: the exclusive
        cumulative sum of ``batch_sizes`` (``None`` without sizes)."""
        if self.batch_sizes is None:
            return None
        offs = np.zeros(len(self), np.int64)
        np.cumsum(self.batch_sizes[:-1], out=offs[1:])
        return offs

    @property
    def worker_slots(self) -> np.ndarray:
        """Worker-local commit index: commit ``k`` is the ``slots[k]``-th
        commit of worker ``worker_ids[k]``.  The pair ``(worker_id, slot)``
        identifies a commit independently of global commit order — the key
        the per-worker RNG attribution folds into the noise stream."""
        slots = np.zeros(len(self), np.int32)
        counts: dict[int, int] = {}
        for k, w in enumerate(np.asarray(self.worker_ids)):
            slots[k] = counts.get(int(w), 0)
            counts[int(w)] = slots[k] + 1
        return slots

    @property
    def num_lost(self) -> int:
        """Commits lost to crashes (0 for a fault-free schedule)."""
        return 0 if self.alive is None else int((~self.alive).sum())

    @property
    def grad_evals(self) -> np.ndarray:
        """Cumulative gradient evaluations after each commit (inclusive) —
        the equal-compute axis for comparing batch policies."""
        if self.batch_sizes is None:
            return np.arange(1, len(self) + 1, dtype=np.int64)
        return np.cumsum(self.batch_sizes.astype(np.int64))

    @classmethod
    def from_trace(cls, trace: DelayTrace) -> "WorkerSchedule":
        """Build a schedule from a simulator :class:`DelayTrace`, turning
        its per-commit delays back into absolute read versions."""
        k = np.arange(len(trace.delays), dtype=np.int64)
        return cls(read_versions=(k - trace.delays).astype(np.int32),
                   worker_ids=np.asarray(trace.worker_ids, np.int32),
                   commit_times=np.asarray(trace.commit_times, np.float64),
                   num_workers=trace.num_workers,
                   batch_sizes=trace.batch_sizes,
                   alive=trace.alive)

    @classmethod
    def from_delays(cls, delays: np.ndarray,
                    commit_times: np.ndarray | None = None) -> "WorkerSchedule":
        """Single-worker schedule realizing the given per-commit delays;
        commit times default to unit spacing when not supplied."""
        delays = np.asarray(delays, np.int64)
        k = np.arange(len(delays), dtype=np.int64)
        times = (np.arange(1, len(delays) + 1, dtype=np.float64)
                 if commit_times is None else np.asarray(commit_times, np.float64))
        return cls(read_versions=(k - delays).astype(np.int32),
                   worker_ids=np.zeros(len(delays), np.int32),
                   commit_times=times, num_workers=1)

    @classmethod
    def sync(cls, num_commits: int) -> "WorkerSchedule":
        """Barrier baseline: every read is fresh (tau = 0)."""
        return cls.from_delays(np.zeros(num_commits, np.int32))

    def validate_ring(self, depth: int, context: str = "") -> None:
        """Raise unless every read the schedule demands fits in the ring."""
        check_staleness_fits(self.max_delay, depth, context or "schedule")

    def to_trace(self) -> DelayTrace:
        """Inverse of :meth:`from_trace`: export the schedule as a
        :class:`DelayTrace` for the simulator/diagnostics tooling."""
        return DelayTrace(delays=self.delays, commit_times=self.commit_times,
                          worker_ids=self.worker_ids,
                          num_workers=self.num_workers,
                          batch_sizes=self.batch_sizes,
                          alive=self.alive)

    def with_batch_sizes(self, batch_sizes: np.ndarray,
                         buckets: Sequence[int] | None = None
                         ) -> "WorkerSchedule":
        """The same schedule with explicit per-commit batch sizes, snapped up
        the bucket ladder (powers of two, or an explicit ``buckets``
        contract) so the executor compiles one trace per rung."""
        sizes = np.asarray(batch_sizes, np.int64)
        if sizes.ndim == 0:
            sizes = np.full(len(self), int(sizes))
        snapped = np.array([bucket_size(int(b), buckets) for b in sizes],
                           np.int32)
        return WorkerSchedule(
            read_versions=self.read_versions, worker_ids=self.worker_ids,
            commit_times=self.commit_times, num_workers=self.num_workers,
            batch_sizes=snapped, alive=self.alive)


def stack_schedules(schedules: Sequence[WorkerSchedule],
                    steps: int | None = None):
    """Batch C per-chain schedules into ``(steps, C)`` arrays.

    Returns ``(read_versions, commit_times)`` with the step axis leading, the
    layout the executor's ``lax.scan`` consumes directly.  With ``steps``
    each schedule is trimmed to its first ``steps`` commits (every schedule
    must cover that many); without it the schedules must share one length.
    """
    if steps is None:
        lengths = {len(s) for s in schedules}
        if len(lengths) != 1:
            raise ValueError("chains must share a commit count, got lengths "
                             f"{sorted(lengths)} (or pass steps= to trim)")
        steps = lengths.pop()
    short = min(len(s) for s in schedules)
    if short < steps:
        raise ValueError(f"schedule covers {short} commits, need {steps}")
    rv = np.stack([s.read_versions[:steps] for s in schedules], axis=1)
    times = np.stack([s.commit_times[:steps] for s in schedules], axis=1)
    return rv.astype(np.int32), times


def stack_batch_info(schedules: Sequence[WorkerSchedule], steps: int):
    """Batch the per-chain minibatch plans into ``(steps, C)`` arrays.

    Returns ``(batch_sizes int32, data_offsets int64)`` with the step axis
    leading, or ``None`` when no schedule carries sizes; a mix of sized and
    size-less schedules is a contract violation and raises.
    """
    have = [s.batch_sizes is not None for s in schedules]
    if not any(have):
        return None
    if not all(have):
        raise ValueError("either every chain's schedule carries batch_sizes "
                         "or none does — got a mix")
    sizes = np.stack([s.batch_sizes[:steps] for s in schedules], axis=1)
    offs = np.stack([s.data_offsets[:steps] for s in schedules], axis=1)
    return sizes.astype(np.int32), offs.astype(np.int64)


def stack_worker_info(schedules: Sequence[WorkerSchedule], steps: int):
    """Batch per-chain worker attribution into ``(steps, C)`` int32 arrays:
    ``(worker_ids, worker_slots)`` — the inputs the executor folds into
    per-commit noise keys under ``worker_rng=True``."""
    wid = np.stack([s.worker_ids[:steps] for s in schedules], axis=1)
    slot = np.stack([s.worker_slots[:steps] for s in schedules], axis=1)
    return wid.astype(np.int32), slot.astype(np.int32)


def stack_liveness(schedules: Sequence[WorkerSchedule],
                   steps: int) -> np.ndarray | None:
    """Batch per-chain liveness into a ``(steps, C)`` bool mask.

    Chains without an ``alive`` mask broadcast to all-True (their commits
    all landed).  Returns ``None`` when no commit in the window was lost —
    including the case where every schedule is fault-free — so the executor
    only threads a liveness input (and only changes its compiled program)
    when a fault actually realized.
    """
    if all(s.alive is None for s in schedules):
        return None
    live = np.stack(
        [np.ones(steps, bool) if s.alive is None else s.alive[:steps]
         for s in schedules], axis=1)
    return None if live.all() else live


def ensemble_async(model: WorkerModel, num_commits: int, num_chains: int,
                   seed: int = 0, *, batch_policy: str = "fixed",
                   base_batch: int = 1, buckets=None) -> list[WorkerSchedule]:
    """C independent async executions of the same worker pool (chain c gets
    its own event-driven simulation seeded ``seed + c``).  ``batch_policy``
    / ``base_batch`` / ``buckets`` couple per-commit batch sizes to the
    drawn compute times (see :func:`~repro.core.delay_model.simulate_async`).
    """
    return [WorkerSchedule.from_trace(
                simulate_async(model, num_commits, seed=seed + c,
                               batch_policy=batch_policy,
                               base_batch=base_batch, buckets=buckets))
            for c in range(num_chains)]

"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm-2-12b lineage].

Assigned: 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b (scaled per assignment)",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    block_pattern=("attn_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG)

"""internvl2-1b — VLM: InternViT + Qwen2-0.5B-style LM [arXiv:2404.16821].

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
Per the assignment carve-out, the vision frontend (InternViT + MLP projector)
is a STUB: ``input_specs`` supplies precomputed patch embeddings (256 tokens)
prepended to the text stream; we implement the language decoder.
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,  # Qwen2 LM backbone uses QKV bias
    frontend="vision",
    num_frontend_tokens=256,
    block_pattern=("attn_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG, num_frontend_tokens=16)

"""kimi-k2-1t-a32b — trillion-parameter MoE [arXiv:2501.kimi2 paper-table].

Assigned: 61L d_model=7168 64H (GQA kv=8) d_ff=2048(per expert)
vocab=163840, MoE 384 experts top-8 (+1 shared, DeepSeek-V3 lineage).
At 1T total parameters this arch *requires* 2-D parameter sharding
(``fsdp_tp``): experts over the model axis and d_ff over the data axis —
single-pod HBM accounting is reported in EXPERIMENTS.md §Dry-run.
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2 (paper table)",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=112,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    param_sharding="fsdp_tp",
    block_pattern=("attn_moe",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG, head_dim=64, num_heads=4, num_kv_heads=2)

"""minicpm-2b — dense llama-like with WSD schedule [arXiv:2404.06395].

Assigned: 40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.
MiniCPM's signature is the Warmup-Stable-Decay schedule (composed with the
SGLD gamma ceiling in train.py) and depth-scaled residuals (scale_depth=1.4).
"""

import math

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    residual_scale=1.4 / math.sqrt(40),
    tie_embeddings=True,
    block_pattern=("attn_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG, num_kv_heads=4)

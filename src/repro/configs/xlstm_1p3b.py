"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

Assigned: 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.  d_ff=0 means the
blocks carry their own up/down projections (mLSTM pre-up-projection factor 2,
sLSTM post-block gated FFN 4/3) — no separate transformer MLP.  Pattern is
xLSTM[7:1]: one sLSTM block per 8 layers, rest mLSTM (48 = 6 periods).
Attention-free: natively sub-quadratic, runs long_500k as-is.
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    block_pattern=("mlstm",) * 7 + ("slstm",),
)


def reduced() -> ArchConfig:
    return _reduce_common(
        CONFIG,
        num_heads=2, num_kv_heads=2, head_dim=128, d_ff=0,
        block_pattern=("mlstm", "slstm"),
    )

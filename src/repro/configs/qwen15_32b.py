"""qwen1.5-32b — dense MHA decoder with QKV bias [hf:Qwen/Qwen1.5 family].

Assigned: 64L d_model=5120 40H (MHA kv=40) d_ff=27392 vocab=152064, QKV bias.
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B (family card; 32B table row per assignment)",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    block_pattern=("attn_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG, num_kv_heads=4)

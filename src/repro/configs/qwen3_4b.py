"""qwen3-4b — dense GQA with per-head QK-norm [hf:Qwen/Qwen3-8B family card].

Assigned: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, qk_norm.
Qwen3 uses head_dim=128 (decoupled from d_model/num_heads).
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG)

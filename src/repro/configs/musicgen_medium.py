"""musicgen-medium — decoder-only LM over EnCodec tokens [arXiv:2306.05284].

Assigned: 48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
Per the assignment carve-out the EnCodec conv codec is a STUB —
``input_specs`` provides precomputed frame embeddings; the 4-codebook delay
interleave is collapsed to a single token stream (noted in DESIGN.md §4).
MusicGen's transformer uses GELU MLPs.
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    frontend="audio",
    num_frontend_tokens=64,
    block_pattern=("attn_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG, num_kv_heads=4, num_frontend_tokens=8)

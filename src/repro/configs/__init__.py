from repro.configs.base import (  # noqa: F401
    ALIASES,
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    ShapeConfig,
    all_archs,
    get_arch,
    get_reduced,
    get_shape,
)

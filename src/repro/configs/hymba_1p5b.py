"""hymba-1.5b — hybrid parallel attention + Mamba heads [arXiv:2411.13676].

Assigned: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Hymba runs attention and SSM heads *in parallel inside each block*; most
layers use sliding-window attention (we window all layers, keeping the
backbone fully sub-quadratic — deviation noted in DESIGN.md §4).
"""

from repro.configs.base import ArchConfig, _reduce_common

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm_state=16,
    sliding_window=1024,
    block_pattern=("hymba_mlp",),
)


def reduced() -> ArchConfig:
    return _reduce_common(CONFIG, num_heads=4, num_kv_heads=2, head_dim=64)

"""Config system: architecture + input-shape dataclasses and the registry.

Every assigned architecture gets one module in ``repro/configs/`` defining an
``ArchConfig`` with the exact assigned hyper-parameters (source cited) plus a
``reduced()`` variant for CPU smoke tests.  Select with ``--arch <id>``.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Optional

ARCH_IDS = [
    "hymba_1p5b",
    "minicpm_2b",
    "internvl2_1b",
    "kimi_k2_1t_a32b",
    "phi35_moe_42b_a6p6b",
    "xlstm_1p3b",
    "qwen3_4b",
    "stablelm_12b",
    "qwen15_32b",
    "musicgen_medium",
]

# canonical dashed ids (CLI) -> module names
ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "minicpm-2b": "minicpm_2b",
    "internvl2-1b": "internvl2_1b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6p6b",
    "xlstm-1.3b": "xlstm_1p3b",
    "qwen3-4b": "qwen3_4b",
    "stablelm-12b": "stablelm_12b",
    "qwen1.5-32b": "qwen15_32b",
    "musicgen-medium": "musicgen_medium",
}


@dataclass(frozen=True)
class ArchConfig:
    """Architecture hyper-parameters (transformer backbone)."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    source: str                    # citation for the config numbers
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // num_heads

    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # static window if set

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    router_aux_coef: float = 0.01

    # SSM (mamba-style heads: hymba) / xLSTM
    ssm_state: int = 0
    ssm_conv: int = 4
    block_pattern: tuple = ("attn_mlp",)  # cycled over layers

    # misc
    act: str = "silu"
    residual_scale: float = 1.0     # MiniCPM depth-scaled residuals
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    frontend: Optional[str] = None  # None | "vision" | "audio"
    num_frontend_tokens: int = 0    # prepended stub-embedding positions
    dtype: str = "bfloat16"

    # distribution
    param_sharding: str = "tp"      # "tp" | "fsdp_tp" (2-D for trillion-scale)

    # ---- beyond-paper performance switches (§Perf hillclimb; default off =
    # paper-faithful baseline) -------------------------------------------------
    opt_attn_head_shard: bool = False  # shard q-heads / replicate kv: no
                                       # GSPMD resharding inside flash loops
    opt_window_slice: bool = False     # sliding-window flash reads only the
                                       # in-window k/v chunks (dyn. slice)
    opt_unroll_layers: bool = False    # python-loop layers instead of scan
                                       # (FSDP: per-layer slice gathers)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0 or self.num_kv_heads == 0

    # -- derived -------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Exact parameter count via eval_shape of the real init (cached)."""
        if not hasattr(self, "_pcount"):
            import jax  # local: keep configs importable without device init
            import numpy as np
            from repro.models.transformer import init_params

            shapes = jax.eval_shape(lambda k: init_params(k, self),
                                    jax.ShapeDtypeStruct((2,), "uint32"))
            n = sum(int(np.prod(x.shape))
                    for x in jax.tree_util.tree_leaves(shapes))
            object.__setattr__(self, "_pcount", n)
        return self._pcount

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts)."""
        full = self.param_count()
        if self.num_experts == 0:
            return full
        d = self.d_model
        expert_p = 3 * d * self.d_ff
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if self.block_pattern[i % len(self.block_pattern)] == "attn_moe")
        inactive = ((self.num_experts - self.experts_per_token)
                    * expert_p * n_moe_layers)
        return int(full - inactive)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input shape x step kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"
    num_microbatches: int = 1


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train", num_microbatches=4),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def get_arch(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_archs() -> list[ArchConfig]:
    return [get_arch(a) for a in ARCH_IDS]


def _reduce_common(cfg: ArchConfig, **over) -> ArchConfig:
    """Shared recipe for CPU smoke variants: 2 layers, d_model<=512, <=4 experts."""
    kw = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2,
                  num_shared_experts=min(cfg.num_shared_experts, 1))
    kw.update(over)
    return replace(cfg, name=cfg.name + "-reduced", **kw)

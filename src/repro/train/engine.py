"""Unified training driver: one jitted, scan-chunked engine for every host loop.

Replaces the three hand-rolled per-step Python loops (``train/loop.py``,
``launch/train.py``, the examples) with a single ``Engine``:

- the inner loop is ``lax.scan`` over a chunk of pre-generated (batch, delay)
  pairs, jitted once with ``donate_argnums`` so the sampler state is updated
  in place — one dispatch per *chunk* instead of one per step;
- delays enter as device ``int32`` arrays, so distinct delay values never
  retrace (``engine.num_traces`` stays at the number of distinct chunk
  lengths — asserted in tests);
- host-side concerns (logging, checkpointing, metric collection) are
  pluggable hooks that run between chunks.

    engine = Engine(sampler, batch_fn=..., hooks=[log_hook(every=10)])
    state, metrics = engine.run(state, steps=1000, delays=trace.delays)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.instrument import counters as _counters
from repro.obs.metrics import registry as _registry
from repro.obs.trace import span as _span
from repro.samplers.base import Sampler, SamplerState

PyTree = Any
BatchFn = Callable[[jax.Array], PyTree]  # key -> one batch (pure jax)
#: hook(step_end, state, chunk_aux) -> None; chunk_aux is the stacked aux
#: pytree for the chunk just finished (device arrays; index [-1] is newest).
Hook = Callable[[int, SamplerState, Any], None]


def log_hook(every: int = 10, log_fn: Callable[[str], None] = print,
             key: str = "loss") -> Hook:
    """Print ``key`` from the newest aux every ``every`` steps (chunk-aligned).

    Every line also lands in the :mod:`repro.obs.metrics` registry — a
    ``train.log_lines`` counter and a ``train.last_<key>`` gauge holding the
    newest logged scalar — so dashboards read the same value the console
    shows.  The printed format is unchanged (and pinned by tests).
    """
    import time

    reg = _registry()
    lines = reg.counter("train.log_lines", "log_hook lines emitted")
    newest = reg.gauge(f"train.last_{key}", "newest logged aux scalar")
    t0 = time.time()
    last = [-every]

    def hook(step_end: int, _state: SamplerState, aux) -> None:
        if aux is None or step_end - last[0] < every:
            return
        if isinstance(aux, dict) and key not in aux:
            return  # e.g. only threaded commit times, nothing to log
        last[0] = step_end
        val = aux[key] if isinstance(aux, dict) else aux
        leaf = jax.tree_util.tree_leaves(val)
        if not leaf:
            return
        scalar = float(np.asarray(leaf[0])[-1])
        lines.inc()
        newest.set(scalar)
        log_fn(f"step {step_end - 1:5d} {key} {scalar:8.4f} "
               f"({time.time() - t0:6.1f}s)")

    return hook


def checkpoint_hook(path: str, every: int = 100) -> Hook:
    """Save ``state.params`` to ``path`` every ``every`` steps.

    The returned hook carries a ``flush`` attribute the engine calls after
    the last chunk, so the final state is saved even when ``steps`` is not a
    multiple of ``every``.
    """
    from repro.checkpoint import save_checkpoint

    last = [0]

    def hook(step_end: int, state: SamplerState, _aux) -> None:
        if step_end - last[0] < every:
            return
        last[0] = step_end
        save_checkpoint(path, state.params, step=step_end)

    def flush(step_end: int, state: SamplerState) -> None:
        if step_end > last[0]:
            last[0] = step_end
            save_checkpoint(path, state.params, step=step_end)

    hook.flush = flush
    return hook


def merge_host_aux(aux, host_rows: dict):
    """Thread chunk-aligned host-side arrays (commit times, cumulative grad
    evals, ...) into the chunk's aux dict (shared by Engine and
    ClusterEngine)."""
    if aux is None:
        return dict(host_rows)
    if isinstance(aux, dict):
        return {**aux, **host_rows}
    return {"aux": aux, **host_rows}


def flush_hooks(hooks: Sequence[Hook], step_end: int,
                state: SamplerState) -> None:
    """After the final chunk, give every hook with a ``flush`` attribute a
    chance to act on the terminal state (e.g. save the last checkpoint)."""
    for hook in hooks:
        flush = getattr(hook, "flush", None)
        if flush is not None:
            flush(step_end, state)


def drive_chunks(run_chunk, state: SamplerState, *, steps: int,
                 chunk_size: int, hooks: Sequence[Hook], collect_aux: bool,
                 extra, batches: Optional[PyTree] = None,
                 gen_batches=None, key: Optional[jax.Array] = None,
                 commit_times=None, host_aux: Optional[dict] = None,
                 slice_batches: bool = True, chunk_info=None,
                 chunk_post=None):
    """The host chunk loop shared by :class:`Engine` and
    :class:`~repro.cluster.executor.ClusterEngine`.

    ``run_chunk(state, batches, extra, *static) -> (state, aux)`` is the
    jitted scan; ``extra`` is the per-step device input (array or pytree of
    arrays with leading axis ``steps``) sliced alongside the batches
    (delays for Engine, read versions / batch plans for ClusterEngine).
    Provide stacked ``batches`` or ``gen_batches(key, n) -> (key,
    chunk_batches)`` plus ``key``; ``slice_batches=False`` hands ``batches``
    to every chunk whole (a data *stream* the scan body indexes itself, as
    the heterogeneous-batch executor does).  ``commit_times`` (host, leading
    axis ``steps``) and any ``host_aux`` arrays are sliced per chunk and
    merged into its aux; ``chunk_info(done, n)`` may return extra *static*
    args for ``run_chunk`` (e.g. the chunk's padded bucket width).  Hooks
    run between chunks and are flushed at the end.  ``chunk_post(done,
    state) -> state`` (optional) runs *after* the chunk's hooks and may
    replace the carry — the seam the cluster executor uses for chain
    respawn and periodic fault-tolerant checkpoints; hooks therefore see
    each chunk's raw outcome (quarantines included) before it heals.
    """
    if batches is None and gen_batches is None:
        batches = jnp.zeros((steps, 1))  # batchless oracles (potentials)
    if batches is None and key is None:
        raise ValueError("generating batches from batch_fn needs `key`")
    if batches is not None and slice_batches:
        n_batches = jax.tree_util.tree_leaves(batches)[0].shape[0]
        if n_batches < steps:  # dynamic_slice would silently clamp+reuse
            raise ValueError(f"batches has {n_batches} entries, need {steps}")
    host_rows = dict(host_aux or {})
    if commit_times is not None:
        host_rows["commit_time"] = commit_times

    aux_chunks = []
    done = 0
    while done < steps:
        n = min(chunk_size, steps - done)
        # host-side chunk span (null ctx when tracing is disabled): covers
        # batch slicing, the jitted dispatch, and the hooks — device
        # execution is async, so hooks that pull values sync inside it
        with _span("engine.chunk", start=done, size=n):
            if batches is None:
                key, chunk_batches = gen_batches(key, n)
            elif slice_batches:
                chunk_batches = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, done, n),
                    batches)
            else:
                chunk_batches = batches
            chunk_extra = jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, done, n), extra)
            static = chunk_info(done, n) if chunk_info is not None else ()
            state, aux = run_chunk(state, chunk_batches, chunk_extra, *static)
            done += n
            if host_rows:
                aux = merge_host_aux(aux, {k: np.asarray(v[done - n:done])
                                           for k, v in host_rows.items()})
            if collect_aux:
                aux_chunks.append(aux)
            for hook in hooks:
                hook(done, state, aux)
            if chunk_post is not None:
                state = chunk_post(done, state)
    flush_hooks(hooks, done, state)

    if not aux_chunks:
        return state, None
    stacked = jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0),
        *aux_chunks)
    return state, stacked


@dataclass
class Engine:
    """Scan-chunked SGLD training driver over a composable sampler.

    ``batch_fn(key) -> batch`` must be pure-jax (it is vmapped over a chunk
    of keys on device); pass ``batches=`` to ``run`` instead for
    pre-generated data.  ``chunk_size`` trades host control granularity
    (hooks, logging) against dispatch overhead.

    Transform state (the SVRG anchor, the SGHMC momentum buffer, delay
    rings) lives in ``state.inner`` and is threaded through the scanned,
    donated carry — so chunk boundaries are invisible to the samplers:
    an anchor refresh scheduled mid-chunk or across a boundary produces
    bit-identical trajectories either way (pinned by ``tests/test_zoo.py``).
    """

    sampler: Sampler
    batch_fn: Optional[BatchFn] = None
    chunk_size: int = 50
    hooks: Sequence[Hook] = ()
    donate: bool = True
    collect_aux: bool = True

    def __post_init__(self):
        self._counters = _counters("Engine")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        donate = (0,) if self.donate else ()
        self._run_chunk = jax.jit(self._chunk_body, donate_argnums=donate)
        self._make_batches = (jax.jit(jax.vmap(self.batch_fn))
                              if self.batch_fn is not None else None)

    @property
    def num_traces(self) -> int:
        """Jit traces so far (one per distinct chunk length) — a thin view
        over the engine's :mod:`repro.analysis.instrument` counters."""
        return self._counters.traces

    # -- jitted chunk ---------------------------------------------------------
    def _chunk_body(self, state: SamplerState, batches, delays):
        # python side effect: runs once per trace, never per call
        self._counters.trace("chunk")

        def body(s, inp):
            batch, d = inp
            s, aux = self.sampler.step(s, batch, d)
            return s, (aux if self.collect_aux else None)

        return jax.lax.scan(body, state, (batches, delays))

    # -- host driver ----------------------------------------------------------
    def run(self, state: SamplerState, *, steps: int,
            batches: Optional[PyTree] = None,
            delays: Optional[np.ndarray] = None,
            key: Optional[jax.Array] = None):
        """Advance ``steps`` commits.  Returns ``(state, aux)`` where aux is
        the per-step aux pytree stacked over all steps (or ``None``).

        Provide either stacked ``batches`` (leading axis ``steps``) or a
        ``batch_fn`` at construction plus ``key`` here to generate each
        chunk's batches on device.  ``delays`` may also be a
        :class:`~repro.core.delay_model.DelayTrace`; its ``commit_times``
        are then threaded into the hook/return aux under ``"commit_time"``
        so wall-clock-axis plots need no side channel.
        """
        from repro.core.delay import validate_staleness
        from repro.core.delay_model import DelayTrace

        commit_times = None
        if isinstance(delays, DelayTrace):
            commit_times = delays.commit_times
            delays = delays.delays
        delays = (jnp.zeros((steps,), jnp.int32) if delays is None
                  else jnp.asarray(delays, jnp.int32))
        if delays.shape[0] < steps:
            raise ValueError(f"delays has {delays.shape[0]} entries, "
                             f"need {steps}")
        validate_staleness(int(np.max(np.asarray(delays[:steps]), initial=0)),
                           state.inner, context="trace")

        def gen_batches(key, n):
            key, sub = jax.random.split(key)
            return key, self._make_batches(jax.random.split(sub, n))

        return drive_chunks(
            self._run_chunk, state, steps=steps, chunk_size=self.chunk_size,
            hooks=self.hooks, collect_aux=self.collect_aux, extra=delays,
            batches=batches,
            gen_batches=gen_batches if self._make_batches is not None else None,
            key=key, commit_times=commit_times)

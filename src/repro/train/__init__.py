from repro.train.loop import make_grad_fn, make_train_step, train_loop  # noqa: F401

from repro.train.engine import Engine, checkpoint_hook, log_hook  # noqa: F401
from repro.train.loop import make_grad_fn, make_train_step, train_loop  # noqa: F401

"""Training-loop substrate: microbatched gradients + async-SGLD samplers.

``make_grad_fn`` builds the gradient oracle the SGLD sampler consumes:
value_and_grad of the model loss, with optional gradient accumulation over
microbatches (lax.scan) so the big shapes fit HBM.  ``make_train_step``
wires it into a ``repro.samplers`` preset (any mode: sync / consistent /
inconsistent / pipeline), and ``train_loop`` drives it through the unified
scan-chunked :class:`repro.train.engine.Engine`.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import samplers
from repro.core.sgld import SGLDConfig
from repro.models.transformer import Model, loss_fn
from repro.train.engine import Engine, log_hook
from repro.utils import tree_add_scaled, tree_zeros_like

PyTree = Any


def _split_microbatch(batch: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_grad_fn(model: Model, num_microbatches: int = 1):
    """grad_fn(params, batch) -> (grads, metrics) for the SGLD sampler."""

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)
        metrics = dict(metrics, loss=loss)
        return grads, metrics

    if num_microbatches <= 1:
        return single

    def accumulated(params, batch):
        micro = _split_microbatch(batch, num_microbatches)

        def body(carry, mb):
            g_acc, m_acc = carry
            g, m = single(params, mb)
            g_acc = tree_add_scaled(g_acc, g, 1.0 / num_microbatches)
            m_acc = jax.tree_util.tree_map(
                lambda a, b: a + b / num_microbatches, m_acc, m)
            return (g_acc, m_acc), None

        g0 = tree_zeros_like(params)
        m0 = {"ce": jnp.float32(0), "aux": jnp.float32(0), "loss": jnp.float32(0)}
        (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
        return grads, metrics

    return accumulated


def make_train_step(model: Model, sgld_cfg: SGLDConfig, num_microbatches: int = 1,
                    *, fused: bool = False, interpret: bool = True):
    """Returns (sampler, step_fn); step_fn(state, batch, delay) -> (state, metrics)."""
    grad_fn = make_grad_fn(model, num_microbatches)
    sampler = samplers.from_config(sgld_cfg, grad_fn, has_aux=True,
                                   fused=fused, interpret=interpret)

    def step_fn(state, batch, delay=0):
        return sampler.step(state, batch, delay)

    return sampler, step_fn


def train_loop(model: Model, params: PyTree, sgld_cfg: SGLDConfig,
               batch_fn: Callable[[jax.Array], PyTree], steps: int,
               key: jax.Array, delays=None, log_every: int = 10,
               log_fn=print, num_microbatches: int = 1, chunk_size: int = 0):
    """Train through the unified Engine: one jitted scan per chunk, delays as
    device arrays (no per-delay-value retraces), logging via hook.

    Returns ``(state, history)`` with history = [(step, loss), ...] at the
    ``log_every`` cadence, as the old per-step loop did.
    """
    sampler, _ = make_train_step(model, sgld_cfg, num_microbatches)
    key, init_key = jax.random.split(key)
    state = sampler.init(params, init_key)
    engine = Engine(sampler, batch_fn=batch_fn,
                    chunk_size=chunk_size or max(1, log_every),
                    hooks=[log_hook(every=log_every, log_fn=log_fn)])
    state, aux = engine.run(state, steps=steps, delays=delays, key=key)
    losses = aux["loss"]
    idx = sorted(set(range(0, steps, log_every)) | {steps - 1})
    history = [(k, float(losses[k])) for k in idx]
    return state, history

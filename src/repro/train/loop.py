"""Training loop: microbatched gradients + async-SGLD update.

``make_grad_fn`` builds the gradient oracle the SGLD sampler consumes:
value_and_grad of the model loss, with optional gradient accumulation over
microbatches (lax.scan) so the big shapes fit HBM.  ``make_train_step``
wires it into the paper's sampler (any mode: sync / consistent /
inconsistent / pipeline), and ``train_loop`` is the host-side driver used by
the examples and the end-to-end driver.
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.sgld import SGLDConfig, SGLDSampler
from repro.models.transformer import Model, loss_fn
from repro.utils import tree_add_scaled, tree_scale, tree_zeros_like

PyTree = Any


def _split_microbatch(batch: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_grad_fn(model: Model, num_microbatches: int = 1):
    """grad_fn(params, batch) -> (grads, metrics) for the SGLD sampler."""

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)
        metrics = dict(metrics, loss=loss)
        return grads, metrics

    if num_microbatches <= 1:
        return single

    def accumulated(params, batch):
        micro = _split_microbatch(batch, num_microbatches)

        def body(carry, mb):
            g_acc, m_acc = carry
            g, m = single(params, mb)
            g_acc = tree_add_scaled(g_acc, g, 1.0 / num_microbatches)
            m_acc = jax.tree_util.tree_map(
                lambda a, b: a + b / num_microbatches, m_acc, m)
            return (g_acc, m_acc), None

        g0 = tree_zeros_like(params)
        m0 = {"ce": jnp.float32(0), "aux": jnp.float32(0), "loss": jnp.float32(0)}
        (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
        return grads, metrics

    return accumulated


def make_train_step(model: Model, sgld_cfg: SGLDConfig, num_microbatches: int = 1):
    """Returns (sampler, step_fn); step_fn(state, batch, delay) -> (state, metrics)."""
    grad_fn = make_grad_fn(model, num_microbatches)
    sampler = SGLDSampler(sgld_cfg, grad_fn, has_aux=True)

    def step_fn(state, batch, delay=0):
        return sampler.step(state, batch, delay)

    return sampler, step_fn


def train_loop(model: Model, params: PyTree, sgld_cfg: SGLDConfig,
               batch_fn: Callable[[jax.Array], PyTree], steps: int,
               key: jax.Array, delays=None, log_every: int = 10,
               log_fn=print):
    """Host driver: jitted step, host-side batches/delays, simple logging."""
    sampler, step_fn = make_train_step(model, sgld_cfg)
    state = sampler.init(params, key)
    jstep = jax.jit(step_fn)
    t0 = time.time()
    history = []
    for k in range(steps):
        key, bk = jax.random.split(key)
        batch = batch_fn(bk)
        d = int(delays[k]) if delays is not None else 0
        state, metrics = jstep(state, batch, d)
        if k % log_every == 0 or k == steps - 1:
            loss = float(metrics["loss"])
            history.append((k, loss))
            log_fn(f"step {k:5d} loss {loss:8.4f} "
                   f"({time.time() - t0:6.1f}s)")
    return state, history

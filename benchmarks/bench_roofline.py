"""Roofline table: read the dry-run JSONs and emit §Roofline rows."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_all(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows=None) -> list[dict]:
    rows = rows if rows is not None else load_all()
    out = []
    for r in rows:
        roof = r["roofline"]
        out.append({
            "bench": "roofline",
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "mode": r["mode"],
            "t_compute_ms": round(roof["t_compute"] * 1e3, 3),
            "t_memory_ms": round(roof["t_memory"] * 1e3, 3),
            "t_collective_ms": round(roof["t_collective"] * 1e3, 3),
            "dominant": roof["dominant"],
            "useful_ratio": round(roof["useful_ratio"], 3),
            "hbm_args_gib": round(r["memory"].get(
                "argument_size_in_bytes", 0) / 2**30, 2),
            "hbm_temp_gib": round(r["memory"].get(
                "temp_size_in_bytes", 0) / 2**30, 2),
        })
    return out


# benchmarks.run calls main(fast=...); this bench has a single scale
def main(fast=True):  # noqa: ARG001
    return table()


if __name__ == "__main__":
    for r in table():
        print(r)

"""Kernel microbenchmarks: fused langevin_update / delay_gather.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock numbers are reported for the pure-jnp REFERENCE path (what a
TPU-less user gets), plus the HBM-traffic model for the kernel vs the
unfused XLA graph — the quantity the fusion actually improves on TPU:

  unfused: RNG writes noise (W), update reads x, g, noise + writes x' = 5N
  fused:   reads x, g + writes x' = 3N    (-40% traffic)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.sgld import apply_update, langevin_noise
from repro.kernels.ref import langevin_update_ref, delay_gather_ref


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(n: int = 1 << 20):
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))
    seed = jnp.array([1, 2], jnp.uint32)

    # unfused XLA path (jax.random.normal + update)
    @jax.jit
    def unfused(x, g, key):
        noise = langevin_noise(key, {"p": x}, jnp.float32(0.05), jnp.float32)
        return apply_update({"p": x}, {"p": g}, jnp.float32(0.01), noise)["p"]

    us_unfused = _time(unfused, x, g, jax.random.PRNGKey(2))

    # fused-math reference (same threefry math the Pallas kernel runs)
    rows2d = n // 1024
    x2 = x.reshape(rows2d, 1024)
    g2 = g.reshape(rows2d, 1024)
    fused_ref = jax.jit(lambda x, g: langevin_update_ref(x, g, seed, 0.01, 0.05))
    us_fused = _time(fused_ref, x2, g2)

    itemsize = 4
    rows.append({"bench": "kernel_langevin", "n": n,
                 "us_unfused_xla": round(us_unfused, 1),
                 "us_fused_ref": round(us_fused, 1),
                 "traffic_unfused_bytes": 5 * n * itemsize,
                 "traffic_fused_bytes": 3 * n * itemsize,
                 "traffic_saving": "40%"})

    depth = 5
    h = jax.random.normal(jax.random.PRNGKey(3), (depth, n))
    slots = jax.random.randint(jax.random.PRNGKey(4), (n,), 0, depth)
    us_gather = _time(jax.jit(delay_gather_ref), h, slots)
    rows.append({"bench": "kernel_delay_gather", "n": n, "depth": depth,
                 "us_ref": round(us_gather, 1),
                 "traffic_kernel_bytes": (depth + 2) * n * itemsize})
    return rows


def main(fast=True):
    return run(n=(1 << 18) if fast else (1 << 22))


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Figures 5-8 / 11-12 / 16-17: RICA under async SGLD (M2 model)."""

from __future__ import annotations

import json
import os
import time

from repro.experiments import run_rica_experiment

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "repro")


def run(P_list=(2, 4, 8), nus=(0.01, 1e-4), steps=600, save=True):
    rows = []
    for nu in nus:
        for P in P_list:
            t0 = time.time()
            res = run_rica_experiment(P=P, nu=nu, steps=steps)
            wall = time.time() - t0
            for mode, c in res.items():
                rows.append({
                    "bench": "rica", "P": P, "nu": nu, "mode": mode,
                    "final_obj": float(c.objective[-1]),
                    "final_dist": float(c.dist_to_opt[-1]),
                    "speedup": float(c.speedup),
                    "us_per_call": wall / steps * 1e6,
                })
            if save:
                os.makedirs(OUT, exist_ok=True)
                payload = {m: {"iters": c.iters.tolist(),
                               "objective": c.objective.tolist(),
                               "dist": c.dist_to_opt.tolist(),
                               "times": c.times.tolist(),
                               "speedup": c.speedup}
                           for m, c in res.items()}
                with open(os.path.join(
                        OUT, f"rica_P{P}_nu{nu}.json"), "w") as f:
                    json.dump(payload, f)
    return rows


def main(fast=True):
    P_list = (4,) if fast else (2, 4, 8)
    nus = (0.01,) if fast else (0.01, 1e-4)
    steps = 200 if fast else 800
    return run(P_list, nus, steps, save=not fast)


if __name__ == "__main__":
    for r in run():
        print(r)

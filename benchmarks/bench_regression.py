"""Paper Figures 1-4 / 9-15: regression convergence + speedup per scheme."""

from __future__ import annotations

import json
import os
import time


from repro.experiments import run_regression_experiment

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "repro")


def run(P_list=(18, 36, 72), nus=(0.1, 1.0), steps=4000, save=True):
    rows = []
    for nu in nus:
        for P in P_list:
            t0 = time.time()
            res = run_regression_experiment(P=P, nu=nu, steps=steps)
            wall = time.time() - t0
            for mode, c in res.items():
                rows.append({
                    "bench": "regression", "P": P, "nu": nu, "mode": mode,
                    "final_w2": float(c.w2[-1]),
                    "best_w2": float(c.w2.min()),
                    "speedup": float(c.speedup),
                    "us_per_call": wall / steps * 1e6,
                })
            if save:
                os.makedirs(OUT, exist_ok=True)
                payload = {m: {"iters": c.iters.tolist(),
                               "w2": c.w2.tolist(),
                               "times": c.times.tolist(),
                               "speedup": c.speedup}
                           for m, c in res.items()}
                with open(os.path.join(
                        OUT, f"regression_P{P}_nu{nu}.json"), "w") as f:
                    json.dump(payload, f)
    return rows


def main(fast=True):
    P_list = (18,) if fast else (18, 36, 72)
    nus = (0.1,) if fast else (0.1, 1.0)
    steps = 1500 if fast else 6000
    return run(P_list, nus, steps, save=not fast)


if __name__ == "__main__":
    for r in run():
        print(r)

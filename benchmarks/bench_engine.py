"""Engine scan-chunking vs the old per-step Python loop.

Same sampler, same potential as the regression reproduction
(``bench_regression``): the per-step loop pays one jit dispatch + host
round-trip per commit, the Engine pays one per ``chunk`` commits.  Reports
us/step for both and the speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import samplers
from repro.core import PolyRegression
from repro.train.engine import Engine


def _build(seed: int = 0, batch: int = 256, tau: int = 8):
    reg = PolyRegression.make(jax.random.PRNGKey(seed), nu_std=0.1)

    def grad(p, key):
        return jax.grad(reg.value)(p, reg.sample_batch(key, batch))

    sampler = samplers.sgld("consistent", grad, gamma=2e-4, sigma=1e-3,
                            tau=tau)
    return reg, sampler


def _timed(fn, *args):
    out = fn(*args)         # warm-up / compile
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    t0 = time.time()
    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    return out, time.time() - t0


def run(steps: int = 2000, chunk: int = 200, seed: int = 0):
    reg, sampler = _build(seed)
    mu, _, _ = reg.posterior_moments(sigma=1e-3)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), steps)
    delays = jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(seed + 2), (steps,), 0, 8))

    # old-style host loop: one jitted dispatch per step
    jstep = jax.jit(sampler.step)

    def python_loop():
        state = sampler.init(mu + 1.0, jax.random.PRNGKey(seed + 3))
        for k in range(steps):
            state, _ = jstep(state, keys[k], delays[k])
        return state.params

    # unified Engine: lax.scan chunks, donated state
    engine = Engine(sampler, chunk_size=chunk, collect_aux=False)

    def engine_run():
        state = sampler.init(mu + 1.0, jax.random.PRNGKey(seed + 3))
        state, _ = engine.run(state, steps=steps, batches=keys, delays=delays)
        return state.params

    p_loop, t_loop = _timed(python_loop)
    p_eng, t_eng = _timed(engine_run)
    drift = float(jnp.abs(p_loop - p_eng).max())
    return t_loop, t_eng, drift


def main(fast: bool = True):
    steps = 1000 if fast else 5000
    t_loop, t_eng, drift = run(steps=steps, chunk=steps // 10)
    return [{
        "bench": "engine", "mode": "consistent", "steps": steps,
        "us_per_call": t_eng / steps * 1e6,
        "loop_us_per_call": round(t_loop / steps * 1e6, 1),
        "speedup_vs_loop": round(t_loop / t_eng, 2),
        "max_param_drift": drift,
    }]


if __name__ == "__main__":
    for r in main(fast=True):
        print(r)

"""Wall-clock speedup model (paper sub-figures b): async vs barrier-sync
throughput under the M1 (NUMA CPU) and M2 (GPU MPS) worker models."""

from __future__ import annotations

from repro.core import WorkerModel, simulate_async, simulate_sync, speedup_vs_sync


def run(seed=0):
    rows = []
    settings = [
        ("M1-numa", dict(cv=0.3, heterogeneity=0.2, update_cost=0.05),
         (18, 36, 72)),
        ("M2-mps", dict(cv=0.15, heterogeneity=0.05, update_cost=0.15),
         (2, 4, 8)),
    ]
    for name, kw, Ps in settings:
        for P in Ps:
            wm = WorkerModel(num_workers=P, seed=seed, **kw)
            tr_a = simulate_async(wm, 400 * P, seed=seed)
            tr_s = simulate_sync(wm, 400, seed=seed)
            rows.append({
                "bench": "speedup", "platform": name, "P": P,
                "speedup": round(speedup_vs_sync(tr_a, tr_s), 3),
                "mean_delay": round(tr_a.mean_delay, 2),
                "max_delay": int(tr_a.max_delay),
            })
    return rows


# benchmarks.run calls main(fast=...); this bench has a single scale
def main(fast=True):  # noqa: ARG001
    return run()


if __name__ == "__main__":
    for r in run():
        print(r)

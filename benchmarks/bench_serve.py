"""Posterior-predictive serving from the chain bank: queries/sec and latency
percentiles vs. chain count and shard count.

A :class:`~repro.cluster.serve.ServeEngine` answers a mixed stream of
batched predictive requests (request sizes drawn from a ladder, so the
shape buckets are genuinely exercised) against a PolyRegression posterior
bank drawn in closed form — this benchmarks the *serving* path, not
training.  Each row reports end-to-end queries/sec, request latency
percentiles, and the trace count (must stay at one per shape bucket or the
run fails).  The shard sweep runs on whatever devices exist; CI forces 8
host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``python benchmarks/bench_serve.py [--smoke] [--out BENCH_serve.json]``
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import instrument
from repro.cluster import ServeEngine, bucket_size
from repro.core import PolyRegression
from repro.models import regression_predict
from repro.obs import registry

SIGMA = 1e-3


def _bank(reg: PolyRegression, chains: int, seed: int) -> jnp.ndarray:
    """Chain-stacked params drawn from the closed-form Gibbs posterior
    N(mu, sigma * Sigma) — a converged bank without paying for training."""
    mu, cov, _ = reg.posterior_moments(sigma=SIGMA)
    chol = np.linalg.cholesky(np.asarray(cov, np.float64))
    eps = np.random.default_rng(seed).standard_normal((chains, reg.d))
    return jnp.asarray(np.asarray(mu) + eps @ chol.T, jnp.float32)


def _measure(engine: ServeEngine, *, requests: int, max_queries: int,
             seed: int) -> dict:
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_queries + 1, size=requests)
    # host-resident requests, as a serving front end would hand them over
    stream = [rng.uniform(-1.0, 1.0, int(n)).astype(np.float32)
              for n in sizes]
    buckets = sorted({bucket_size(int(n)) for n in sizes})
    for n in buckets:  # compile every bucket off the clock
        jax.block_until_ready(engine(np.zeros(n, np.float32)).mean)
        engine(np.ones(max(n - 1, 1), np.float32))  # warm the pad scratch too

    lat = []
    t_all = time.time()
    # any trace or pad alloc inside this block is a stream-path regression;
    # the report's stream_flags() feed the row fields check_bench gates on
    with instrument() as rep:
        for q in stream:
            t0 = time.time()
            jax.block_until_ready(engine(q).mean)
            lat.append(time.time() - t0)
    total_s = time.time() - t_all
    lat_ms = np.asarray(lat) * 1e3
    p50, p90, p99 = (float(np.percentile(lat_ms, p)) for p in (50, 90, 99))
    return {
        "chains": engine.num_chains,
        "shards": (engine.mesh.shape[engine.chain_axis]
                   if engine.mesh is not None else 1),
        "requests": requests,
        "queries": int(sizes.sum()),
        "buckets": len(buckets),
        "traces": engine.num_traces,
        # host padding must reuse the per-rung scratch: zero allocations
        # (device or host) per request once the rungs are warm
        **rep.stream_flags(),
        "qps": round(float(sizes.sum()) / total_s, 1),
        "requests_per_s": round(requests / total_s, 1),
        "p50_ms": round(p50, 3),
        "p90_ms": round(p90, 3),
        "p99_ms": round(p99, 3),
    }


def run(chain_sweep=(8, 64, 256), shard_sweep=(2, 4, 8), requests: int = 200,
        max_queries: int = 64, seed: int = 0) -> dict:
    reg = PolyRegression.make(jax.random.PRNGKey(seed))
    predict = regression_predict(reg)
    rows = []
    for chains in chain_sweep:
        eng = ServeEngine(predict_fn=predict, params=_bank(reg, chains, seed))
        rows.append(_measure(eng, requests=requests, max_queries=max_queries,
                             seed=seed + 1))
    chains = max(chain_sweep)
    n_dev = len(jax.devices())
    for shards in shard_sweep:
        if shards > n_dev or chains % shards:
            continue
        mesh = jax.make_mesh((shards,), ("data",),
                             devices=jax.devices()[:shards])
        eng = ServeEngine(predict_fn=predict,
                          params=_bank(reg, chains, seed), mesh=mesh)
        rows.append(_measure(eng, requests=requests, max_queries=max_queries,
                             seed=seed + 1))
    return {
        "config": {"chain_sweep": list(chain_sweep), "requests": requests,
                   "max_queries": max_queries, "seed": seed,
                   "devices": n_dev, "sigma": SIGMA},
        "rows": rows,
    }


def _row(result: dict) -> dict:
    """CSV row for benchmarks.run: the largest unsharded configuration."""
    best = [r for r in result["rows"] if r["shards"] == 1][-1]
    return {
        "bench": "serve", "us_per_call": round(1e6 / best["qps"], 1),
        "chains": best["chains"], "qps": best["qps"],
        "p50_ms": best["p50_ms"], "p99_ms": best["p99_ms"],
        "traces": best["traces"],
    }


SMOKE_KW = dict(chain_sweep=(8, 32), shard_sweep=(2, 4, 8), requests=60,
                max_queries=32)


def main(fast: bool = True):
    return [_row(run(**(SMOKE_KW if fast else {})))]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (8/32 chains, 60 requests)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    result = run(**(SMOKE_KW if args.smoke else {}))
    stem = args.out[:-5] if args.out.endswith(".json") else args.out
    registry().write_snapshot(f"{stem}.metrics.json")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(_row(result)))
    for r in result["rows"]:
        print(f"  chains={r['chains']:4d} shards={r['shards']} "
              f"qps={r['qps']:10.1f} p50={r['p50_ms']:.2f}ms "
              f"p99={r['p99_ms']:.2f}ms traces={r['traces']}")
    print(f"wrote {args.out} (+ .metrics.json)")
    if any(r["retraced_in_stream"] for r in result["rows"]):
        raise SystemExit("serve path retraced inside a request stream "
                         "(more than one trace per shape bucket)")
    if any(r["pad_allocs_in_stream"] for r in result["rows"]):
        raise SystemExit("request padding allocated per request instead of "
                         "reusing the per-rung scratch")

"""Corollary 2.1 validation: iterations-to-epsilon vs max delay tau.

The paper's claim: tau does not change the ORDER of convergence, only the
constants (stepsize ceiling ~ 1/tau^2 in the worst term).  We run the
quadratic potential at fixed gamma across a tau grid and measure (a) the
stationary W2 error floor and (b) iterations to reach a W2 threshold; both
must grow polynomially (bounded by the theory ratio), never diverge.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import samplers
from repro.core import (
    ProblemConstants,
    Quadratic,
    constant_delays,
    gamma_eps_kl,
    n_eps_kl,
)
from repro.metrics import w2_to_gaussian

SIGMA = 0.2
GAMMA = 5e-3
STEPS = 12_000


def run(taus=(0, 1, 2, 4, 8, 16), n_chains=64, seed=0):
    quad = Quadratic.make(jax.random.PRNGKey(seed), d=4, m=1.0, L=3.0)
    target_cov = jnp.diag(quad.stationary_cov(SIGMA))
    rows = []
    for tau in taus:
        mode = "consistent" if tau > 0 else "sync"
        sampler = samplers.sgld(mode, lambda p, b: quad.grad(p, b),
                                gamma=GAMMA, sigma=SIGMA,
                                tau=max(tau, 1) if tau > 0 else 0)
        delays = jnp.asarray(constant_delays(tau, STEPS).delays) if tau \
            else jnp.zeros((STEPS,), jnp.int32)
        batches = jnp.zeros((STEPS, 1))

        def chain(key):
            st = sampler.init(jnp.zeros(4) + 3.0, key)
            _, traj = sampler.run(st, batches, delays)
            return traj

        trajs = jax.jit(jax.vmap(chain))(
            jax.random.split(jax.random.PRNGKey(seed + 1), n_chains))
        trajs = np.asarray(trajs)  # (chains, steps, d)
        # cross-chain law at checkpoints
        w2s = []
        ks = list(range(200, STEPS, 400))
        for k in ks:
            w2s.append(float(w2_to_gaussian(jnp.asarray(trajs[:, k]),
                                            quad.x_star, target_cov)))
        w2s = np.asarray(w2s)
        floor = float(w2s[-5:].mean())
        thresh = 0.5
        hit = next((ks[i] for i in range(len(ks)) if w2s[i] < thresh), STEPS)
        c = ProblemConstants(m=quad.m, L=quad.L, d=4, G=6.0, sigma=SIGMA,
                             tau=max(tau, 1), w2sq_0=9.0 * 4)
        rows.append({
            "bench": "tau_sweep", "tau": tau, "w2_floor": floor,
            "iters_to_0.5": hit,
            "theory_gamma_eps": gamma_eps_kl(c, 0.25),
            "theory_n_eps": n_eps_kl(c, 0.25),
        })
    return rows


def main(fast=True):
    return run(taus=(0, 4, 16) if fast else (0, 1, 2, 4, 8, 16),
               n_chains=32 if fast else 64)


if __name__ == "__main__":
    for r in run():
        print(r)

"""Ensemble-scale async-SGLD: empirical-W2-vs-wallclock and async-vs-sync
speedup curves (the shape of paper Figs 1b/2b/3b), measured honestly.

A C-chain :class:`~repro.cluster.ClusterEngine` ensemble advances C
independent P-worker async runs in one jitted scan; at every chunk boundary
the chain cloud is compared against draws from the closed-form Gibbs
posterior of a quadratic potential with debiased Sinkhorn W2 — convergence
*in measure*, no single-chain moment-matched proxy.  The synchronous
baseline executes the barrier schedule (one update per round, round time =
max over P workers) so both curves share a simulated wall-clock axis and a
gradient-evaluation budget.

The batch-policy sweep compares heterogeneous (inverse-speed) per-worker
batch sizes against fixed-size minibatches **at an equal total
gradient-evaluation budget** on an overhead-heavy heterogeneous pool: both
arms run the masked bucket-padded executor path with linear step-size
scaling, and the recorded frontier is W2 against cumulative grad evals and
against simulated wall clock.  The run fails unless inverse-speed batching
reaches the fixed arm's final W2 in less simulated wall clock.

The scenario matrix runs the sampler zoo through the same harness: SGLD,
SVRG-LD, stale-corrected SGLD, SGHMC, and SGLD over an AR(1)-dependent
data stream all consume the *same* async worker schedules, the same
per-chain budget of ``commits x base_batch`` example-gradient evaluations
through the masked bucket-padded executor path, and the same closed-form
Gibbs target — so the recorded W2-vs-simulated-wallclock frontiers are
directly comparable across rows, and ``check_bench.py`` gates each row's
final W2 against the committed baseline.

``python benchmarks/bench_cluster.py [--smoke] [--out BENCH_cluster.json]``
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import instrument
from repro.cluster import (
    ClusterEngine,
    WorkerSchedule,
    chain_positions,
    ensemble_async,
    ensemble_w2,
    w2_recorder,
)
from repro.core import (
    FaultPlan,
    Quadratic,
    WorkerModel,
    simulate_async,
    simulate_sync,
    speedup_vs_sync,
    truncate_to_evals,
)
from repro.data import ar1_stream
from repro.faults import nan_storm
from repro.obs import cluster_timeline, registry, write_chrome_trace
from repro import samplers


def _target_samples(quad: Quadratic, sigma: float, n: int, seed: int):
    """Draws from the closed-form stationary law N(x*, sigma A^-1)."""
    std = jnp.sqrt(quad.stationary_cov(sigma))
    return quad.x_star + std * jax.random.normal(jax.random.PRNGKey(seed),
                                                 (n, quad.d))


def _run_ensemble(sampler, schedule, *, num_chains, steps, chunk, target,
                  seed, jitter):
    hook = w2_recorder(target, every=chunk, num_iters=100)
    engine = ClusterEngine(sampler, num_chains=num_chains, chunk_size=chunk,
                           hooks=[])
    d = int(target.shape[1])
    state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed), jitter=jitter)
    # warm-up: compile the scan chunk and the Sinkhorn kernel off the clock
    warm, _ = engine.run(state, steps=min(steps, chunk), schedule=schedule)
    float(ensemble_w2(chain_positions(warm.params), target, num_iters=100))
    engine.hooks = [hook]
    state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed), jitter=jitter)
    t0 = time.time()
    # traces inside the timed run are reported (not gated: a ragged final
    # chunk legitimately compiles one extra program the warm-up never saw)
    with instrument() as rep:
        state, _ = engine.run(state, steps=steps, schedule=schedule)
        jax.block_until_ready(state.params)
    return hook.record, time.time() - t0, rep.num_traces


def _policy_curves(rec):
    return {
        "commits": [r["step"] for r in rec],
        "grad_evals": [r["grad_evals"] for r in rec],
        "sim_time": [r["commit_time"] for r in rec],
        "w2": [r["w2"] for r in rec],
    }


def run_batch_policies(num_chains: int = 64, workers: int = 8,
                       fixed_commits: int = 960, d: int = 2,
                       gamma: float = 0.02, sigma: float = 0.5,
                       base_batch: int = 8, noise_scale: float = 1.0,
                       heterogeneity: float = 0.6, update_cost: float = 0.6,
                       n_target: int = 256, seed: int = 0,
                       chunks: int = 16) -> dict:
    """Heterogeneous (inverse-speed) vs fixed batch sizes at an equal total
    gradient-evaluation budget.

    Both arms run the same masked bucket-padded path (the fixed arm through
    ``batch_policy="explicit"`` at constant ``base_batch``), the same
    per-example oracle — quadratic drift plus iid per-example gradient
    noise, so batch size genuinely trades variance — and linear step-size
    scaling ``gamma_k ∝ b_k``.  The pool is overhead-heavy and strongly
    heterogeneous (default worker speeds spread 0.4..1.6, serialized commit
    cost 0.6 of a mean step), where fixed small batches burn wall clock on
    per-commit overhead while slow workers commit stale, high-variance
    gradients.
    """
    quad = Quadratic.make(jax.random.PRNGKey(seed), d=d, m=1.0, L=3.0)
    target = _target_samples(quad, sigma, n_target, seed + 1)
    per_ex = lambda p, e: quad.grad(p, None) + noise_scale * e  # noqa: E731
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 3),
                                        (8192, d)), np.float32)
    wm = WorkerModel(num_workers=workers, heterogeneity=heterogeneity,
                     update_cost=update_cost, seed=seed)
    budget = fixed_commits * base_batch  # grad evals per chain

    fixed_scheds = ensemble_async(wm, fixed_commits, num_chains, seed=seed,
                                  batch_policy="fixed",
                                  base_batch=base_batch)
    het_traces = [truncate_to_evals(
        simulate_async(wm, fixed_commits, seed=seed + c,
                       batch_policy="inverse-speed", base_batch=base_batch),
        budget) for c in range(num_chains)]
    het_scheds = [WorkerSchedule.from_trace(t) for t in het_traces]
    het_steps = min(len(s) for s in het_scheds)
    tau = max(max(s.max_delay for s in fixed_scheds),
              max(s.max_delay for s in het_scheds))

    def arm(policy, scheds, steps, **run_kw):
        sampler = samplers.sgld("consistent", per_ex, gamma=gamma,
                                sigma=sigma, tau=max(tau, 1),
                                base_batch=base_batch)
        chunk = max(1, steps // chunks)
        hook = w2_recorder(target, every=chunk, num_iters=100)
        engine = ClusterEngine(sampler, num_chains=num_chains,
                               chunk_size=chunk, batch_policy=policy,
                               hooks=[hook])
        state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed + 2),
                            jitter=2.0)
        t0 = time.time()
        with instrument() as rep:
            state, _ = engine.run(state, steps=steps, schedule=scheds,
                                  data=data, **run_kw)
            jax.block_until_ready(state.params)
        return hook.record, time.time() - t0, rep.num_traces

    fixed_rec, fixed_dev_s, fixed_traces = arm(
        "explicit", fixed_scheds, fixed_commits,
        batch_sizes=np.full(fixed_commits, base_batch))
    het_rec, het_dev_s, het_traces = arm("inverse-speed", het_scheds,
                                         het_steps)

    final_w2_fixed = fixed_rec[-1]["w2"]
    final_w2_het = het_rec[-1]["w2"]
    wallclock_fixed = fixed_rec[-1]["commit_time"]
    wallclock_het = het_rec[-1]["commit_time"]
    # first simulated time at which the het arm's W2 drops to the fixed
    # arm's final value — the W2-at-equal-wallclock headline
    het_time_to_fixed_w2 = next(
        (r["commit_time"] for r in het_rec if r["w2"] <= final_w2_fixed),
        None)
    advantage = (wallclock_fixed / het_time_to_fixed_w2
                 if het_time_to_fixed_w2 else None)
    return {
        "config": {"num_chains": num_chains, "workers": workers,
                   "fixed_commits": fixed_commits, "het_commits": het_steps,
                   "base_batch": base_batch, "budget_grad_evals": budget,
                   "heterogeneity": heterogeneity,
                   "update_cost": update_cost, "d": d,
                   "gamma": gamma, "sigma": sigma,
                   "noise_scale": noise_scale, "seed": seed},
        "fixed": _policy_curves(fixed_rec),
        "inverse_speed": _policy_curves(het_rec),
        "final_w2_fixed": final_w2_fixed,
        "final_w2_het": final_w2_het,
        "wallclock_fixed": wallclock_fixed,
        "wallclock_het": wallclock_het,
        "het_time_to_fixed_final_w2": het_time_to_fixed_w2,
        "het_wallclock_advantage": (round(advantage, 3) if advantage
                                    else None),
        "device_wall_s": {"fixed": round(fixed_dev_s, 3),
                          "het": round(het_dev_s, 3)},
        "traces_in_run": {"fixed": fixed_traces, "het": het_traces},
    }


def run_scenarios(num_chains: int = 64, workers: int = 8,
                  commits: int = 960, d: int = 2, gamma: float = 0.02,
                  sigma: float = 0.5, base_batch: int = 8,
                  noise_scale: float = 1.0, anchor_every: int = 64,
                  friction: float = 1.0, stale_strength: float = 0.1,
                  stale_gamma_scale: float = 0.05, rho: float = 0.9,
                  n_target: int = 256, seed: int = 0,
                  chunks: int = 16) -> dict:
    """The sampler-zoo scenario matrix: one row per sampler, matched
    everything else.

    Every row shares the quadratic target, the async worker schedules
    (hence the same endogenous staleness and the same simulated wall
    clock), and a per-chain budget of ``commits x base_batch``
    example-gradient evaluations consumed through the masked
    ``batch_policy="explicit"`` executor path.  The per-example oracle is
    quadratic drift plus additive data noise, ``g(p, e) = A(p - x*) +
    noise_scale * e`` — so the minibatch gradient variance comes from the
    data, SVRG's control variate ``g_B(x) - g_B(x_anchor)`` genuinely
    cancels it, and the AR(1) row changes *only* the temporal dependence
    of the stream (same stationary marginal).

    Rows:

    - ``sgld``   plain delayed-read SGLD — the reference frontier.
    - ``svrg``   :func:`repro.samplers.svrg`; anchor refreshed every
      ``anchor_every`` commits inside the scanned carry.  Each commit
      additionally evaluates the minibatch oracle at the anchor (same
      examples, 2x oracle calls) — reported, not hidden.
    - ``stale``  SGLD + :func:`repro.samplers.stale_correction` (Taylor
      compensation ``stale_strength``, step shrink ``stale_gamma_scale``);
      the explicit compensation is only stable while ``strength * |g| *
      |x - x_hat|`` stays below ~1, which with ``jitter=2`` transients and
      ``tau ~ 8`` bounds the usable strength near 0.1 here.
    - ``sghmc``  :func:`repro.samplers.sghmc` with drag ``friction``.
    - ``ar1``    plain SGLD over an :func:`repro.data.ar1_stream`
      dependent stream with autocorrelation ``rho``.
    """
    quad = Quadratic.make(jax.random.PRNGKey(seed), d=d, m=1.0, L=3.0)
    target = _target_samples(quad, sigma, n_target, seed + 1)
    per_ex = lambda p, e: quad.grad(p, None) + noise_scale * e  # noqa: E731
    n_rows = commits * base_batch
    data_iid = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 3),
                                            (n_rows, d)), np.float32)
    data_ar1 = np.asarray(ar1_stream(jax.random.PRNGKey(seed + 3),
                                     steps=commits, batch=base_batch, d=d,
                                     rho=rho), np.float32).reshape(n_rows, d)
    full_grad = lambda p: (quad.grad(p, None)  # noqa: E731
                           + noise_scale * jnp.asarray(data_iid.mean(0)))

    wm = WorkerModel(num_workers=workers, seed=seed)
    scheds = ensemble_async(wm, commits, num_chains, seed=seed)
    tau = max(max(s.max_delay for s in scheds), 1)
    chunk = max(1, commits // chunks)

    def arm(sampler, data):
        hook = w2_recorder(target, every=chunk, num_iters=100)
        engine = ClusterEngine(sampler, num_chains=num_chains,
                               chunk_size=chunk, batch_policy="explicit",
                               hooks=[hook])
        state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed + 2),
                            jitter=2.0)
        t0 = time.time()
        with instrument() as rep:
            state, _ = engine.run(state, steps=commits, schedule=scheds,
                                  data=data,
                                  batch_sizes=np.full(commits, base_batch))
            jax.block_until_ready(state.params)
        return hook.record, time.time() - t0, rep.num_traces

    common = dict(gamma=gamma, sigma=sigma, tau=tau, base_batch=base_batch)
    rows_spec = {
        "sgld": (samplers.sgld("consistent", per_ex, **common), data_iid),
        "svrg": (samplers.svrg("consistent", per_ex, full_grad,
                               anchor_every=anchor_every, **common),
                 data_iid),
        "stale": (samplers.sgld("consistent", per_ex,
                                stale_strength=stale_strength,
                                stale_gamma_scale=stale_gamma_scale,
                                **common), data_iid),
        "sghmc": (samplers.sghmc("consistent", per_ex, friction=friction,
                                 **common), data_iid),
        "ar1": (samplers.sgld("consistent", per_ex, **common), data_ar1),
    }
    rows = {}
    for name, (sampler, data) in rows_spec.items():
        rec, dev_s, traces = arm(sampler, data)
        rows[name] = {
            "final_w2": rec[-1]["w2"],
            "wallclock": rec[-1]["commit_time"],
            "grad_evals": rec[-1]["grad_evals"],
            "oracle_calls_per_commit": 2 if name == "svrg" else 1,
            "curve": _policy_curves(rec),
            "device_wall_s": round(dev_s, 3),
            "traces_in_run": traces,
        }
    return {
        "config": {"num_chains": num_chains, "workers": workers,
                   "commits": commits, "d": d, "gamma": gamma,
                   "sigma": sigma, "base_batch": base_batch,
                   "budget_grad_evals": commits * base_batch,
                   "noise_scale": noise_scale, "anchor_every": anchor_every,
                   "friction": friction, "stale_strength": stale_strength,
                   "stale_gamma_scale": stale_gamma_scale, "rho": rho,
                   "tau_realized": tau, "n_target": n_target, "seed": seed},
        "rows": rows,
    }


def run_chaos(num_chains: int = 64, workers: int = 8, commits: int = 960,
              d: int = 2, gamma: float = 0.05, sigma: float = 0.5,
              n_target: int = 256, seed: int = 0, chunks: int = 16,
              crash_rate: float = 0.15, mean_downtime: float = 2.0,
              pause_rate: float = 0.1, mean_pause: float = 1.0,
              poison_rate: float = 0.005) -> dict:
    """Self-healing under chaos: W2-at-budget through crashes, pauses, and
    NaN-poisoned chains vs the fault-free arm on the same harness.

    The clean arm is plain async SGLD on fault-free worker schedules.  The
    storm arm draws its schedules from the same :class:`WorkerModel` with a
    :class:`FaultPlan` (workers crash mid-flight and rejoin after an
    exponential downtime, losing every commit in transit; pauses stretch
    staleness without losing work), NaN-poisons a seeded ``poison_rate``
    fraction of (commit, chain) slots via :func:`repro.faults.nan_storm`,
    and runs with ``health_check=True`` so poisoned chains are quarantined
    on device and respawned from healthy donors at chunk boundaries.  Both
    arms record the same debiased-Sinkhorn W2 frontier against the same
    closed-form Gibbs target, so the storm-vs-clean W2 ratio *is* the cost
    of the faults — ``check_bench.py`` gates the storm W2 inside a band of
    the clean arm, and the fault accounting (lost commits, poison events,
    respawns, final healthy count) exactly: the injection is seeded and
    deterministic, so any drift is a code change, not noise.  Each arm must
    also stay a single compiled program (``traces_in_run``): fault handling
    is masking and host-side bookkeeping, never a retrace.
    """
    quad = Quadratic.make(jax.random.PRNGKey(seed), d=d, m=1.0, L=3.0)
    target = _target_samples(quad, sigma, n_target, seed + 1)
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731

    plan = FaultPlan(crash_rate=crash_rate, mean_downtime=mean_downtime,
                     pause_rate=pause_rate, mean_pause=mean_pause)
    scheds_clean = ensemble_async(
        WorkerModel(num_workers=workers, seed=seed),
        commits, num_chains, seed=seed)
    scheds_storm = ensemble_async(
        WorkerModel(num_workers=workers, seed=seed, faults=plan),
        commits, num_chains, seed=seed)
    # crashed-and-rejoined workers read much staler iterates than a healthy
    # pool: the ring must fit the storm arm's realized staleness
    tau = max(max(s.max_delay for s in scheds_clean),
              max(s.max_delay for s in scheds_storm), 1)
    chunk = max(1, commits // chunks)
    poison = nan_storm(commits, num_chains, rate=poison_rate, seed=seed + 7)

    def arm(scheds, *, health_check, poison=None):
        sampler = samplers.sgld("consistent", grad, gamma=gamma, sigma=sigma,
                                tau=tau)
        hook = w2_recorder(target, every=chunk, num_iters=100)
        engine = ClusterEngine(sampler, num_chains=num_chains,
                               chunk_size=chunk, hooks=[hook],
                               health_check=health_check)
        state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed + 2),
                            jitter=2.0)
        t0 = time.time()
        with instrument() as rep:
            state, _ = engine.run(state, steps=commits, schedule=scheds,
                                  poison=poison)
            jax.block_until_ready(state.params)
        return hook.record, time.time() - t0, rep.num_traces, state

    clean_rec, clean_s, clean_traces, _ = arm(scheds_clean,
                                              health_check=False)
    respawn0 = registry().counter(
        "chains.respawned",
        "quarantined chains respawned from a healthy donor").value
    storm_rec, storm_s, storm_traces, storm_state = arm(
        scheds_storm, health_check=True, poison=poison)
    respawned = registry().get("chains.respawned").value - respawn0

    lost = int(sum(s.num_lost for s in scheds_storm))
    w2_clean = clean_rec[-1]["w2"]
    w2_storm = storm_rec[-1]["w2"]
    health = getattr(storm_state, "health", None)
    return {
        "config": {"num_chains": num_chains, "workers": workers,
                   "commits": commits, "d": d, "gamma": gamma,
                   "sigma": sigma, "tau_realized": tau,
                   "n_target": n_target, "seed": seed,
                   "crash_rate": crash_rate, "mean_downtime": mean_downtime,
                   "pause_rate": pause_rate, "mean_pause": mean_pause,
                   "poison_rate": poison_rate},
        "clean": _policy_curves(clean_rec),
        "storm": _policy_curves(storm_rec),
        "final_w2_clean": w2_clean,
        "final_w2_storm": w2_storm,
        "w2_storm_over_clean": round(w2_storm / w2_clean, 3),
        "lost_commits": lost,
        "lost_frac": round(lost / (commits * num_chains), 4),
        "poison_events": int(poison.sum()),
        "respawned": int(respawned),
        "chains_healthy_final": (int(np.asarray(health).sum())
                                 if health is not None else num_chains),
        "device_wall_s": {"clean": round(clean_s, 3),
                          "storm": round(storm_s, 3)},
        "traces_in_run": {"clean": clean_traces, "storm": storm_traces},
        # storm-arm commit spans with crashed commits marked "commit
        # (lost)" — recovery is visible in Perfetto (popped into
        # <out>.chaos_timeline.json before the payload is written)
        "timeline": cluster_timeline(scheds_storm),
    }


def run(num_chains: int = 64, workers: int = 8, commits: int = 960,
        d: int = 2, gamma: float = 0.05, sigma: float = 0.5,
        n_target: int = 256, seed: int = 0, chunks: int = 16):
    quad = Quadratic.make(jax.random.PRNGKey(seed), d=d, m=1.0, L=3.0)
    target = _target_samples(quad, sigma, n_target, seed + 1)
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731

    wm = WorkerModel(num_workers=workers, seed=seed)
    async_scheds = ensemble_async(wm, commits, num_chains, seed=seed)
    tau = max(s.max_delay for s in async_scheds)
    chunk = max(1, commits // chunks)

    async_sampler = samplers.sgld("consistent", grad, gamma=gamma,
                                  sigma=sigma, tau=max(tau, 1))
    async_rec, async_dev_s, async_traces = _run_ensemble(
        async_sampler, async_scheds, num_chains=num_chains, steps=commits,
        chunk=chunk, target=target, seed=seed + 2, jitter=2.0)

    # barrier baseline: commits//P rounds, each worth P gradient evaluations
    rounds = max(1, commits // workers)
    sync_trace = simulate_sync(wm, rounds, seed=seed)
    sync_sched = WorkerSchedule.from_trace(sync_trace)
    sync_sampler = samplers.sgld("sync", grad, gamma=gamma, sigma=sigma)
    sync_chunk = max(1, rounds // chunks)
    sync_rec, sync_dev_s, sync_traces = _run_ensemble(
        sync_sampler, sync_sched, num_chains=num_chains, steps=rounds,
        chunk=sync_chunk, target=target, seed=seed + 2, jitter=2.0)

    speedup = speedup_vs_sync(async_scheds[0].to_trace(), sync_trace)
    return {
        "config": {"num_chains": num_chains, "workers": workers,
                   "commits": commits, "d": d, "gamma": gamma, "sigma": sigma,
                   "tau_realized": tau, "n_target": n_target, "seed": seed},
        "async": {
            "grad_evals": [r["step"] for r in async_rec],
            "sim_time": [r["commit_time"] for r in async_rec],
            "w2": [r["w2"] for r in async_rec],
        },
        "sync": {
            "grad_evals": [r["step"] * workers for r in sync_rec],
            "sim_time": [r["commit_time"] for r in sync_rec],
            "w2": [r["w2"] for r in sync_rec],
        },
        "speedup_vs_sync": round(speedup, 3),
        "final_w2_async": async_rec[-1]["w2"],
        "final_w2_sync": sync_rec[-1]["w2"],
        "device_wall_s": {"async": round(async_dev_s, 3),
                          "sync": round(sync_dev_s, 3)},
        "traces_in_run": {"async": async_traces, "sync": sync_traces},
        # per-worker commit spans of the first chains, Perfetto-openable
        # (popped into <out>.timeline.json before the payload is written)
        "timeline": cluster_timeline(async_scheds),
    }


def _row(result: dict) -> dict:
    us = result["device_wall_s"]["async"] / result["config"]["commits"] * 1e6
    bp = result.get("batch_policy", {})
    scen = result.get("scenarios", {}).get("rows", {})
    ch = result.get("chaos")
    return {
        "bench": "cluster", "us_per_call": round(us, 1),
        "chains": result["config"]["num_chains"],
        "workers": result["config"]["workers"],
        "speedup_vs_sync": result["speedup_vs_sync"],
        "final_w2_async": round(result["final_w2_async"], 4),
        "final_w2_sync": round(result["final_w2_sync"], 4),
        "het_wallclock_advantage": bp.get("het_wallclock_advantage"),
        "scenario_w2": {name: round(r["final_w2"], 4)
                        for name, r in scen.items()},
        "chaos_w2_storm": (round(ch["final_w2_storm"], 4) if ch else None),
        "chaos_w2_ratio": (ch.get("w2_storm_over_clean") if ch else None),
    }


SMOKE_KW = dict(num_chains=8, workers=4, commits=240, chunks=24, n_target=128)
SMOKE_POLICY_KW = dict(num_chains=8, workers=4, fixed_commits=240, chunks=24,
                       n_target=128)
SMOKE_SCENARIO_KW = dict(num_chains=8, workers=4, commits=240, chunks=24,
                         n_target=128, anchor_every=48)
SMOKE_CHAOS_KW = dict(num_chains=8, workers=4, commits=240, chunks=24,
                      n_target=128)


def full(fast: bool = True) -> dict:
    result = run(**(SMOKE_KW if fast else {}))
    result["batch_policy"] = run_batch_policies(
        **(SMOKE_POLICY_KW if fast else {}))
    result["scenarios"] = run_scenarios(
        **(SMOKE_SCENARIO_KW if fast else {}))
    result["chaos"] = run_chaos(**(SMOKE_CHAOS_KW if fast else {}))
    return result


def chaos_only(fast: bool = True) -> dict:
    """The chaos-smoke CI payload: just the clean-vs-storm arm pair, with
    a ``kind`` marker so ``check_bench.py`` dispatches the chaos gate."""
    return {"kind": "cluster-chaos",
            "chaos": run_chaos(**(SMOKE_CHAOS_KW if fast else {}))}


def main(fast: bool = True):
    return [_row(full(fast))]


#: in-run acceptance band for the storm arm: its W2-at-budget must stay
#: within CHAOS_W2_FACTOR x the fault-free arm's, with an absolute floor so
#: a very tight clean W2 cannot make the band impossibly narrow
#: (scripts/check_bench.py applies the same band against the baseline)
#: (at smoke scale the healed storm arm lands at ~0.9x the clean W2 — the
#: respawned chains clone healthy donors, so the faults cost commits, not
#: mixing; 2x headroom flags a broken quarantine long before NaN)
CHAOS_W2_FACTOR = 2.0
CHAOS_W2_FLOOR = 0.8


def _check_chaos_gate(ch: dict) -> None:
    w2c, w2s = ch["final_w2_clean"], ch["final_w2_storm"]
    if not w2s == w2s:  # NaN guard
        raise SystemExit("storm-arm W2 is NaN: the quarantine/respawn path "
                         "failed to keep the ensemble finite")
    band = max(CHAOS_W2_FACTOR * w2c, CHAOS_W2_FLOOR)
    if w2s > band:
        raise SystemExit(
            f"storm-arm W2 {w2s:.4f} left the self-healing band "
            f"{band:.4f} (clean {w2c:.4f} x {CHAOS_W2_FACTOR}, floor "
            f"{CHAOS_W2_FLOOR})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (8 chains, 240 commits)")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos-smoke payload only (fault-free vs "
                    "crash/pause/NaN-storm arm, self-healing on)")
    ap.add_argument("--out", default="BENCH_cluster.json")
    args = ap.parse_args()
    stem = args.out[:-5] if args.out.endswith(".json") else args.out
    if args.chaos:
        result = chaos_only(args.smoke)
    else:
        result = full(args.smoke)
        write_chrome_trace(f"{stem}.timeline.json", result.pop("timeline"))
    write_chrome_trace(f"{stem}.chaos_timeline.json",
                       result["chaos"].pop("timeline"))
    registry().write_snapshot(f"{stem}.metrics.json")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    if not args.chaos:
        print(json.dumps(_row(result)))
        bp = result["batch_policy"]
        print(f"batch policies at {bp['config']['budget_grad_evals']} grad "
              f"evals/chain: fixed W2 {bp['final_w2_fixed']:.4f} in "
              f"{bp['wallclock_fixed']:.1f} sim-units, inverse-speed W2 "
              f"{bp['final_w2_het']:.4f} in {bp['wallclock_het']:.1f} "
              f"(reached fixed's final W2 at "
              f"{bp['het_time_to_fixed_final_w2'] or float('nan'):.1f}; "
              f"advantage {bp['het_wallclock_advantage']}x)")
        scen = result["scenarios"]
        print(f"scenario matrix at {scen['config']['budget_grad_evals']} "
              "grad evals/chain: " + ", ".join(
                  f"{name} W2 {r['final_w2']:.4f}"
                  for name, r in scen["rows"].items()))
    ch = result["chaos"]
    print(f"chaos: clean W2 {ch['final_w2_clean']:.4f} vs storm "
          f"{ch['final_w2_storm']:.4f} "
          f"({ch['w2_storm_over_clean']}x) with {ch['lost_commits']} "
          f"commits lost ({ch['lost_frac']:.1%}), "
          f"{ch['poison_events']} NaN poisons, {ch['respawned']} respawns, "
          f"{ch['chains_healthy_final']}/{ch['config']['num_chains']} "
          f"chains healthy at budget")
    print(f"wrote {args.out} (+ .metrics.json, .chaos_timeline.json"
          + (")" if args.chaos else ", .timeline.json)"))
    if not args.chaos:
        if result["speedup_vs_sync"] <= 1.0:
            raise SystemExit("async-vs-sync speedup did not exceed 1")
        adv = result["batch_policy"]["het_wallclock_advantage"]
        if adv is None or adv <= 1.0:
            raise SystemExit(
                "inverse-speed batching did not reach the fixed-batch "
                f"final W2 in less simulated wall clock (advantage {adv})")
    _check_chaos_gate(ch)

"""Ensemble-scale async-SGLD: empirical-W2-vs-wallclock and async-vs-sync
speedup curves (the shape of paper Figs 1b/2b/3b), measured honestly.

A C-chain :class:`~repro.cluster.ClusterEngine` ensemble advances C
independent P-worker async runs in one jitted scan; at every chunk boundary
the chain cloud is compared against draws from the closed-form Gibbs
posterior of a quadratic potential with debiased Sinkhorn W2 — convergence
*in measure*, no single-chain moment-matched proxy.  The synchronous
baseline executes the barrier schedule (one update per round, round time =
max over P workers) so both curves share a simulated wall-clock axis and a
gradient-evaluation budget.

``python benchmarks/bench_cluster.py [--smoke] [--out BENCH_cluster.json]``
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.cluster import (
    ClusterEngine,
    WorkerSchedule,
    chain_positions,
    ensemble_async,
    ensemble_w2,
    w2_recorder,
)
from repro.core import Quadratic, WorkerModel, simulate_sync, speedup_vs_sync
from repro import samplers


def _target_samples(quad: Quadratic, sigma: float, n: int, seed: int):
    """Draws from the closed-form stationary law N(x*, sigma A^-1)."""
    std = jnp.sqrt(quad.stationary_cov(sigma))
    return quad.x_star + std * jax.random.normal(jax.random.PRNGKey(seed),
                                                 (n, quad.d))


def _run_ensemble(sampler, schedule, *, num_chains, steps, chunk, target,
                  seed, jitter):
    hook = w2_recorder(target, every=chunk, num_iters=100)
    engine = ClusterEngine(sampler, num_chains=num_chains, chunk_size=chunk,
                           hooks=[])
    d = int(target.shape[1])
    state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed), jitter=jitter)
    # warm-up: compile the scan chunk and the Sinkhorn kernel off the clock
    warm, _ = engine.run(state, steps=min(steps, chunk), schedule=schedule)
    float(ensemble_w2(chain_positions(warm.params), target, num_iters=100))
    engine.hooks = [hook]
    state = engine.init(jnp.zeros(d), jax.random.PRNGKey(seed), jitter=jitter)
    t0 = time.time()
    state, _ = engine.run(state, steps=steps, schedule=schedule)
    jax.block_until_ready(state.params)
    return hook.record, time.time() - t0


def run(num_chains: int = 64, workers: int = 8, commits: int = 960,
        d: int = 2, gamma: float = 0.05, sigma: float = 0.5,
        n_target: int = 256, seed: int = 0, chunks: int = 16):
    quad = Quadratic.make(jax.random.PRNGKey(seed), d=d, m=1.0, L=3.0)
    target = _target_samples(quad, sigma, n_target, seed + 1)
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731

    wm = WorkerModel(num_workers=workers, seed=seed)
    async_scheds = ensemble_async(wm, commits, num_chains, seed=seed)
    tau = max(s.max_delay for s in async_scheds)
    chunk = max(1, commits // chunks)

    async_sampler = samplers.sgld("consistent", grad, gamma=gamma,
                                  sigma=sigma, tau=max(tau, 1))
    async_rec, async_dev_s = _run_ensemble(
        async_sampler, async_scheds, num_chains=num_chains, steps=commits,
        chunk=chunk, target=target, seed=seed + 2, jitter=2.0)

    # barrier baseline: commits//P rounds, each worth P gradient evaluations
    rounds = max(1, commits // workers)
    sync_trace = simulate_sync(wm, rounds, seed=seed)
    sync_sched = WorkerSchedule.from_trace(sync_trace)
    sync_sampler = samplers.sgld("sync", grad, gamma=gamma, sigma=sigma)
    sync_chunk = max(1, rounds // chunks)
    sync_rec, sync_dev_s = _run_ensemble(
        sync_sampler, sync_sched, num_chains=num_chains, steps=rounds,
        chunk=sync_chunk, target=target, seed=seed + 2, jitter=2.0)

    speedup = speedup_vs_sync(async_scheds[0].to_trace(), sync_trace)
    return {
        "config": {"num_chains": num_chains, "workers": workers,
                   "commits": commits, "d": d, "gamma": gamma, "sigma": sigma,
                   "tau_realized": tau, "n_target": n_target, "seed": seed},
        "async": {
            "grad_evals": [r["step"] for r in async_rec],
            "sim_time": [r["commit_time"] for r in async_rec],
            "w2": [r["w2"] for r in async_rec],
        },
        "sync": {
            "grad_evals": [r["step"] * workers for r in sync_rec],
            "sim_time": [r["commit_time"] for r in sync_rec],
            "w2": [r["w2"] for r in sync_rec],
        },
        "speedup_vs_sync": round(speedup, 3),
        "final_w2_async": async_rec[-1]["w2"],
        "final_w2_sync": sync_rec[-1]["w2"],
        "device_wall_s": {"async": round(async_dev_s, 3),
                          "sync": round(sync_dev_s, 3)},
    }


def _row(result: dict) -> dict:
    us = result["device_wall_s"]["async"] / result["config"]["commits"] * 1e6
    return {
        "bench": "cluster", "us_per_call": round(us, 1),
        "chains": result["config"]["num_chains"],
        "workers": result["config"]["workers"],
        "speedup_vs_sync": result["speedup_vs_sync"],
        "final_w2_async": round(result["final_w2_async"], 4),
        "final_w2_sync": round(result["final_w2_sync"], 4),
    }


SMOKE_KW = dict(num_chains=8, workers=4, commits=240, chunks=24, n_target=128)


def main(fast: bool = True):
    return [_row(run(**(SMOKE_KW if fast else {})))]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (8 chains, 240 commits)")
    ap.add_argument("--out", default="BENCH_cluster.json")
    args = ap.parse_args()
    result = run(**(SMOKE_KW if args.smoke else {}))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(_row(result)))
    print(f"wrote {args.out}")
    if result["speedup_vs_sync"] <= 1.0:
        raise SystemExit("async-vs-sync speedup did not exceed 1")

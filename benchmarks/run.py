"""Benchmark runner: one module per paper table/figure family + roofline.

``python -m benchmarks.run``           fast pass, prints CSV
``python -m benchmarks.run --full``    full paper grids (slow, writes JSONs)
``python -m benchmarks.run --only regression,rica``
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_cluster,
    bench_decode,
    bench_engine,
    bench_kernels,
    bench_regression,
    bench_rica,
    bench_roofline,
    bench_serve,
    bench_speedup,
    bench_tau_sweep,
)

BENCHES = {
    "regression": bench_regression.main,   # paper Figs 1-4, 9-15
    "rica": bench_rica.main,               # paper Figs 5-8, 11-12, 16-17
    "speedup": bench_speedup.main,         # paper sub-figures (b)
    "tau_sweep": bench_tau_sweep.main,     # Corollary 2.1
    "kernels": bench_kernels.main,         # Pallas hot-path
    "engine": bench_engine.main,           # scan-chunked Engine vs host loop
    "cluster": bench_cluster.main,         # C-chain ensemble W2 + speedup
    "serve": bench_serve.main,             # chain-bank predictive serving
    "decode": bench_decode.main,           # streaming BMA decode tokens/sec
    "roofline": bench_roofline.main,       # §Roofline table (from dry-run)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()
    names = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            rows = BENCHES[name](fast=not args.full)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}")
            continue
        wall_us = (time.time() - t0) * 1e6
        for row in rows:
            us = row.pop("us_per_call", round(wall_us / max(len(rows), 1), 1))
            tag = row.pop("bench", name)
            derived = ";".join(f"{k}={v}" for k, v in row.items())
            print(f"{tag},{us},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

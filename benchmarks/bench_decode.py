"""Streaming BMA decode from the chain bank: tokens/sec and per-token
latency percentiles vs. chain count and shard count.

A :class:`~repro.cluster.decode.DecodeEngine` streams greedy generations for
a mixed prompt stream (batch sizes and prompt lengths drawn from ladders, so
the (bucket, max_new) traces are genuinely exercised) against a reduced
transformer bank.  Each row reports end-to-end tokens/sec, per-token latency
percentiles, the trace count, and the prompt-scratch allocation count — the
run **fails** on an in-stream retrace, on per-request pad allocations, or
(with >= 8 devices) when sharded C=8 decoding is not sublinear in C, i.e.
when it fails to beat 8x the C=1 per-token cost.  The shard sweep runs on
whatever devices exist; CI forces 8 host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

A final **continuous-batching** block replays one Poisson arrival stream of
mixed-budget requests through a convoyed static-batch baseline (legacy
``generate``, groups of ``num_slots`` at the group-max budget) and through
:class:`~repro.cluster.paged.PagedDecodeEngine` (slot-level admission over
the paged KV bank), reporting sustained QPS, p99 TTFT, and bank-page
utilization — the run fails unless continuous batching sustains a QPS
uplift > 1 with zero in-stream retraces and zero host pad allocations.

``python benchmarks/bench_decode.py [--smoke] [--out BENCH_decode.json]``
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace

import jax
import numpy as np

from repro.analysis import instrument
from repro.cluster import DecodeEngine, PagedDecodeEngine
from repro.cluster.api import (
    Request,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
)
from repro.configs import get_reduced
from repro.models.transformer import Model, init_params
from repro.obs import (
    decode_timeline,
    paged_timeline,
    registry,
    write_chrome_trace,
)
from repro.obs.trace import tracer
from repro.utils import bucket_size

ARCH = "qwen3-4b"


def _bench_cfg():
    """The reduced config scaled up until per-chain compute dominates
    dispatch: at the CPU-smoke size (d=256) the per-token cost is
    overhead-bound and the sharded-sublinearity margin is within CI noise;
    at d=512 the margin is a robust ~1.7x."""
    return replace(get_reduced(ARCH), d_model=512, d_ff=1536, num_heads=8,
                   num_kv_heads=2, head_dim=64, vocab_size=2048)


def _bank(cfg, chains: int, seed: int):
    return jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), chains))


def _measure(engine: DecodeEngine, *, requests: int, max_batch: int,
             max_prompt: int, max_new: int, seed: int) -> dict:
    cfg = engine.model.cfg
    rng = np.random.default_rng(seed)
    shapes = list(zip(rng.integers(1, max_batch + 1, size=requests),
                      rng.integers(4, max_prompt + 1, size=requests)))
    stream = [rng.integers(0, cfg.vocab_size, size=(int(b), int(t)),
                           dtype=np.int32) for b, t in shapes]
    rungs = sorted({(bucket_size(int(b)), bucket_size(int(t)))
                    for b, t in shapes})
    for b, t in rungs:  # compile every (bucket, max_new) pair off the clock
        engine.generate(np.zeros((b, t), np.int32), max_new)

    lat = []
    n_tokens = 0
    t_all = time.time()
    # any trace or pad alloc inside this block is a stream-path regression;
    # the report's stream_flags() feed the row fields check_bench gates on
    with instrument() as rep:
        for prompt in stream:
            t0 = time.time()
            res = engine.generate(prompt, max_new)
            lat.append(time.time() - t0)
            n_tokens += res.tokens.size
    total_s = time.time() - t_all
    per_tok_ms = np.asarray(lat) * 1e3 / max_new
    p50, p99 = (float(np.percentile(per_tok_ms, p)) for p in (50, 99))
    return {
        "chains": engine.num_chains,
        "shards": (engine.mesh.shape[engine.chain_axis]
                   if engine.mesh is not None else 1),
        "requests": requests,
        "tokens": n_tokens,
        "rungs": len(rungs),
        "traces": engine.num_traces,
        **rep.stream_flags(),
        "tokens_per_s": round(n_tokens / total_s, 1),
        "per_token_p50_ms": round(p50, 3),
        "per_token_p99_ms": round(p99, 3),
    }


def _measure_continuous(model, params, *, requests: int, num_slots: int,
                        prompt_len: int, max_new: int, max_seq: int,
                        page_size: int, decode_chunk: int,
                        arrival_qps: float, seed: int) -> dict:
    """Continuous batching vs a convoyed static batch on one Poisson
    arrival stream.

    Both servers see the same mixed-budget request stream with exponential
    inter-arrival gaps.  Arrivals live on a *virtual* clock; each service
    call's wall-clock duration advances it, so the comparison measures the
    servers, not the random sleeps.  The static baseline convoys: it groups
    ``num_slots`` requests in arrival order, waits for the group's last
    arrival, and runs one legacy batch ``generate`` at the group's pow2-
    bucketed max budget — every sequence decodes to the longest budget in
    its convoy.  The paged engine admits each request the moment a slot
    frees and retires it at its own budget.  Sustained QPS (completed
    requests over makespan) and p99 TTFT (static: batch completion; paged:
    the admission prefill that emits the first token) are reported per
    server; the uplift is the acceptance criterion.
    """
    cfg = model.cfg
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=(prompt_len,),
                            dtype=np.int32) for _ in range(requests)]
    budgets = rng.integers(2, max_new + 1, size=requests)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_qps, size=requests))

    def pow2(n):  # static budget bucket: pow2 (one trace per bucket),
        # capped at what the contiguous cache can hold past the prompt
        return min(1 << (int(n) - 1).bit_length(), max_seq - prompt_len)

    # ---- convoyed static baseline --------------------------------------
    groups = [list(range(g, min(g + num_slots, requests)))
              for g in range(0, requests, num_slots)]
    eng = DecodeEngine(model=model, params=params, max_seq=max_seq)
    for idx in groups:  # compile every (b_rung, max_new bucket) off-clock
        eng.generate(np.zeros((len(idx), prompt_len), np.int32),
                     pow2(max(budgets[i] for i in idx)))
    clock, done, generated = 0.0, {}, 0
    with instrument() as rep_s:
        for idx in groups:
            batch = np.stack([prompts[i] for i in idx])
            mn = pow2(max(budgets[i] for i in idx))
            clock = max(clock, float(arrivals[idx[-1]]))  # convoy wait
            t0 = time.time()
            eng.generate(batch, mn)
            clock += time.time() - t0
            generated += len(idx) * mn
            for i in idx:
                done[i] = clock
    useful = int(budgets.sum())
    ttft_s = [done[i] - float(arrivals[i]) for i in range(requests)]
    static = {
        "qps": round(requests / clock, 2),
        "p99_ttft_ms": round(float(np.percentile(ttft_s, 99)) * 1e3, 1),
        "makespan_s": round(clock, 4),
        "wasted_token_frac": round(1.0 - useful / generated, 4),
        **rep_s.stream_flags(),
    }

    # ---- continuous batching over the paged bank -----------------------
    peng = PagedDecodeEngine(model=model, params=params,
                             num_slots=num_slots, page_size=page_size,
                             max_seq=max_seq, decode_chunk=decode_chunk)
    for _ in range(num_slots):  # warm the prefill rung + the step body
        peng.submit(Request(tokens=prompts[0], max_new_tokens=max_new))
    peng.drain()
    traces_warm = peng.num_traces
    reqs = [Request(tokens=prompts[i], max_new_tokens=int(budgets[i]))
            for i in range(requests)]
    clock, i, n_done = 0.0, 0, 0
    windows, util = [], []
    gauge = registry().get("paged.page_utilization")
    with instrument() as rep_c:
        while n_done < requests:
            while i < requests and float(arrivals[i]) <= clock:
                peng.submit(reqs[i])
                i += 1
            if peng.num_active == 0 and peng.num_waiting == 0 \
                    and not peng._pending and i < requests:
                clock = float(arrivals[i])  # idle: fast-forward to arrival
                continue
            t0 = time.time()
            comps = peng.step()
            t1 = time.time()
            windows.append((t0, t1, clock))
            clock += t1 - t0
            n_done += len(comps)
            util.append(gauge.value)

    def virtual(wall):  # wall stamp inside a step window -> virtual clock
        for w0, w1, v0 in windows:
            if w0 <= wall <= w1:
                return v0 + (wall - w0)
        return clock

    ttft_c = [virtual(r.timing["first_token"]) - float(arrivals[j])
              for j, r in enumerate(reqs)]
    paged = {
        "qps": round(requests / clock, 2),
        "p99_ttft_ms": round(float(np.percentile(ttft_c, 99)) * 1e3, 1),
        "makespan_s": round(clock, 4),
        "page_utilization_mean": round(float(np.mean(util)), 4),
        "traces": peng.num_traces,
        "new_traces_in_stream": peng.num_traces - traces_warm,
        **rep_c.stream_flags(),
    }
    uplift = round(paged["qps"] / static["qps"], 3)
    return {
        "config": {"requests": requests, "num_slots": num_slots,
                   "prompt_len": prompt_len, "max_new": max_new,
                   "page_size": page_size, "decode_chunk": decode_chunk,
                   "arrival_qps": arrival_qps, "seed": seed},
        "static": static,
        "paged": paged,
        "qps_uplift": uplift,
        "pass": uplift > 1.0,
    }


def _measure_deadline(model, params, *, requests: int, num_slots: int,
                      prompt_len: int, max_new: int, max_seq: int,
                      page_size: int, decode_chunk: int, seed: int) -> dict:
    """Deadline-aware shedding under burst overload: goodput of a
    deadline-armed paged server vs the same server with no deadlines.

    All ``requests`` arrive at once into ``num_slots`` slots — an overload
    spike where queueing delay, not service time, dominates the tail.  The
    no-deadline arm serves the whole backlog; a request counts toward
    *goodput* only if it finished within the budget D of its submission.
    D self-calibrates to the median completion latency of that arm, so the
    comparison tracks this machine's service rate instead of hard-coding a
    wall-clock number.  The deadline arm resubmits the identical burst with
    ``deadline_ms=D``: requests past D while still waiting are shed
    un-admitted (``STATUS_SHED``, zero wasted decode) and active ones are
    cut short with their partial prefix (``STATUS_TIMEOUT``), so no slot
    keeps burning on a request that already missed its budget.  Acceptance:
    on-time completions per second of server busy time must go *up* when
    shedding is on (``goodput_uplift > 1``), every request must come back
    with a terminal status, and neither arm may trace inside the stream —
    deadline handling is host-side bookkeeping, never a recompile.
    """
    cfg = model.cfg
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=(prompt_len,),
                            dtype=np.int32) for _ in range(requests)]
    budgets = rng.integers(max(2, max_new // 2), max_new + 1, size=requests)

    def serve(deadline_ms):
        peng = PagedDecodeEngine(model=model, params=params,
                                 num_slots=num_slots, page_size=page_size,
                                 max_seq=max_seq, decode_chunk=decode_chunk)
        peng.submit(Request(tokens=prompts[0], max_new_tokens=max_new))
        peng.drain()  # warm the prefill rung + the step body off the clock
        warm = peng.num_traces
        reqs = [Request(tokens=prompts[i], max_new_tokens=int(budgets[i]),
                        deadline_ms=deadline_ms) for i in range(requests)]
        t0 = time.time()
        with instrument() as rep:
            for r in reqs:
                peng.submit(r)
            comps = peng.drain()
        makespan = time.time() - t0
        lat = [r.timing["finished"] - r.timing["submitted"] for r in reqs]
        return comps, lat, makespan, rep.stream_flags(), \
            peng.num_traces - warm

    comps0, lat0, span0, flags0, new_tr0 = serve(None)
    deadline_ms = round(float(np.percentile(lat0, 50)) * 1e3, 3)
    comps1, _, span1, flags1, new_tr1 = serve(deadline_ms)

    n_status = lambda cs, st: sum(c.status == st for c in cs)  # noqa: E731
    on_time0 = sum(lt <= deadline_ms * 1e-3 for lt in lat0)
    ok1 = n_status(comps1, STATUS_OK)
    shed1 = n_status(comps1, STATUS_SHED)
    timeout1 = n_status(comps1, STATUS_TIMEOUT)
    goodput0 = on_time0 / span0
    goodput1 = ok1 / span1
    uplift = round(goodput1 / goodput0, 3) if goodput0 else None
    return {
        "config": {"requests": requests, "num_slots": num_slots,
                   "prompt_len": prompt_len, "max_new": max_new,
                   "max_seq": max_seq, "page_size": page_size,
                   "decode_chunk": decode_chunk, "seed": seed},
        "deadline_ms": deadline_ms,
        "no_deadline": {"makespan_s": round(span0, 4),
                        "completed": len(comps0), "on_time": int(on_time0),
                        "goodput_rps": round(goodput0, 2),
                        "new_traces_in_stream": new_tr0, **flags0},
        "deadline": {"makespan_s": round(span1, 4), "ok": ok1,
                     "shed": shed1, "timeout": timeout1,
                     "goodput_rps": round(goodput1, 2),
                     "new_traces_in_stream": new_tr1, **flags1},
        "goodput_uplift": uplift,
        "pass": (uplift is not None and uplift > 1.0
                 and ok1 + shed1 + timeout1 == requests),
    }


def run(chain_sweep=(1, 4, 8), shard_sweep=(4, 8), requests: int = 40,
        max_batch: int = 8, max_prompt: int = 16, max_new: int = 16,
        max_seq: int = 64, seed: int = 0,
        continuous_kw: dict | None = None,
        deadline_kw: dict | None = None) -> dict:
    cfg = _bench_cfg()
    model = Model(cfg, remat=False)
    kw = dict(requests=requests, max_batch=max_batch, max_prompt=max_prompt,
              max_new=max_new, seed=seed + 1)
    rows = []
    # span tracing stays ON through the measured streams: the stream-flag
    # gates double as the proof that tracing adds no retrace/pad-alloc
    tr = tracer()
    tr.clear()
    tr.enable()
    try:
        for chains in chain_sweep:
            eng = DecodeEngine(model=model, params=_bank(cfg, chains, seed),
                               max_seq=max_seq)
            rows.append(_measure(eng, **kw))
        chains = max(chain_sweep)
        n_dev = len(jax.devices())
        for shards in shard_sweep:
            if shards > n_dev or chains % shards:
                continue
            mesh = jax.make_mesh((shards,), ("data",),
                                 devices=jax.devices()[:shards])
            eng = DecodeEngine(model=model, params=_bank(cfg, chains, seed),
                               max_seq=max_seq, mesh=mesh)
            rows.append(_measure(eng, **kw))
    finally:
        tr.disable()
    timeline = decode_timeline(tr.drain())

    # continuous batching vs convoyed static batch, same Poisson stream.
    # Long budgets on a wide slot (max_seq 128 >> the rows' max_seq) are
    # deliberate: they grow both the convoy's pow2 over-generation and the
    # decode/prefill ratio, which is where slot-level admission pays —
    # short-budget streams are dispatch-bound and show no uplift on CPU.
    cont_kw = dict(requests=12, num_slots=4, prompt_len=4, max_new=96,
                   max_seq=128, page_size=8, decode_chunk=8,
                   arrival_qps=200.0, seed=seed + 2)
    cont_kw.update(continuous_kw or {})
    tr.enable()
    try:
        continuous = _measure_continuous(
            model, _bank(cfg, max(chain_sweep), seed), **cont_kw)
    finally:
        tr.disable()
    paged_tl = paged_timeline(tr.drain())

    # deadline-aware shedding on the same paged engine: burst overload,
    # self-calibrating budget (see _measure_deadline)
    dl_kw = dict(requests=16, num_slots=4, prompt_len=4, max_new=64,
                 max_seq=128, page_size=8, decode_chunk=8, seed=seed + 3)
    dl_kw.update(deadline_kw or {})
    deadline = _measure_deadline(model, _bank(cfg, max(chain_sweep), seed),
                                 **dl_kw)

    # acceptance: sharded C-chain decode is sublinear in C — C=8 over 8
    # devices must beat 8x the C=1 per-token cost
    sublinear = None
    c1 = next((r for r in rows if r["chains"] == 1 and r["shards"] == 1), None)
    cmax = next((r for r in rows if r["chains"] == chains
                 and r["shards"] == chains), None)
    if c1 is not None and cmax is not None:
        bound = chains * c1["per_token_p50_ms"]
        sublinear = {
            "chains": chains,
            "c1_per_token_ms": c1["per_token_p50_ms"],
            "sharded_per_token_ms": cmax["per_token_p50_ms"],
            "linear_bound_ms": round(bound, 3),
            "speedup_vs_linear": round(bound / cmax["per_token_p50_ms"], 2),
            "pass": cmax["per_token_p50_ms"] < bound,
        }
    return {
        "kind": "decode",
        "config": {"arch": ARCH, "chain_sweep": list(chain_sweep),
                   "requests": requests, "max_batch": max_batch,
                   "max_prompt": max_prompt, "max_new": max_new,
                   "max_seq": max_seq, "seed": seed,
                   "devices": n_dev},
        "rows": rows,
        "sublinear": sublinear,
        "continuous": continuous,
        "deadline": deadline,
        # per-request decode.generate spans with amortized token slices
        # (popped into <out>.timeline.json before the payload is written)
        "timeline": timeline,
        # per-slot continuous-batching timeline (<out>.paged_timeline.json)
        "paged_timeline": paged_tl,
    }


def _row(result: dict) -> dict:
    """CSV row for benchmarks.run: the largest unsharded configuration."""
    best = [r for r in result["rows"] if r["shards"] == 1][-1]
    return {
        "bench": "decode",
        "us_per_call": round(best["per_token_p50_ms"] * 1e3, 1),
        "chains": best["chains"], "tokens_per_s": best["tokens_per_s"],
        "per_token_p50_ms": best["per_token_p50_ms"],
        "per_token_p99_ms": best["per_token_p99_ms"],
        "traces": best["traces"],
        "cont_qps_uplift": result["continuous"]["qps_uplift"],
        "deadline_goodput_uplift": result["deadline"]["goodput_uplift"],
    }


SMOKE_KW = dict(chain_sweep=(1, 8), shard_sweep=(8,), requests=12,
                max_batch=4, max_prompt=8, max_new=8, max_seq=32,
                deadline_kw=dict(requests=10, max_new=32, max_seq=64))


def main(fast: bool = True):
    return [_row(run(**(SMOKE_KW if fast else {})))]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (1/8 chains, 12 requests)")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args()
    result = run(**(SMOKE_KW if args.smoke else {}))
    stem = args.out[:-5] if args.out.endswith(".json") else args.out
    write_chrome_trace(f"{stem}.timeline.json", result.pop("timeline"))
    write_chrome_trace(f"{stem}.paged_timeline.json",
                       result.pop("paged_timeline"))
    registry().write_snapshot(f"{stem}.metrics.json")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(_row(result)))
    for r in result["rows"]:
        print(f"  chains={r['chains']:3d} shards={r['shards']} "
              f"tok/s={r['tokens_per_s']:9.1f} "
              f"per-tok p50={r['per_token_p50_ms']:.2f}ms "
              f"p99={r['per_token_p99_ms']:.2f}ms traces={r['traces']}")
    sub = result["sublinear"]
    if sub is not None:
        print(f"  sublinear: C={sub['chains']} sharded "
              f"{sub['sharded_per_token_ms']:.2f}ms/tok vs linear bound "
              f"{sub['linear_bound_ms']:.2f}ms ({sub['speedup_vs_linear']}x)")
    cont = result["continuous"]
    print(f"  continuous: paged {cont['paged']['qps']} qps "
          f"(p99 TTFT {cont['paged']['p99_ttft_ms']}ms, "
          f"pages {cont['paged']['page_utilization_mean']:.0%}) vs convoyed "
          f"{cont['static']['qps']} qps "
          f"(p99 TTFT {cont['static']['p99_ttft_ms']}ms, "
          f"{cont['static']['wasted_token_frac']:.0%} tokens wasted): "
          f"{cont['qps_uplift']}x uplift")
    dl = result["deadline"]
    print(f"  deadline: D={dl['deadline_ms']:.0f}ms burst of "
          f"{dl['config']['requests']}: no-deadline "
          f"{dl['no_deadline']['on_time']} on time in "
          f"{dl['no_deadline']['makespan_s']:.2f}s "
          f"({dl['no_deadline']['goodput_rps']} rps) vs shedding "
          f"{dl['deadline']['ok']} ok / {dl['deadline']['shed']} shed / "
          f"{dl['deadline']['timeout']} cut in "
          f"{dl['deadline']['makespan_s']:.2f}s "
          f"({dl['deadline']['goodput_rps']} rps): "
          f"{dl['goodput_uplift']}x goodput")
    print(f"wrote {args.out} (+ .timeline.json, .paged_timeline.json, "
          ".metrics.json)")
    if any(r["retraced_in_stream"] for r in result["rows"]):
        raise SystemExit("decode path retraced inside the prompt stream "
                         "(more than one trace per (bucket, max_new) pair)")
    if any(r["traces"] != r["rungs"] for r in result["rows"]):
        raise SystemExit("trace count != rung count: the decode program is "
                         "not exactly one trace per (bucket, max_new) pair")
    if any(r["pad_allocs_in_stream"] for r in result["rows"]):
        raise SystemExit("prompt padding allocated per request instead of "
                         "reusing the per-rung scratch")
    if sub is not None and not sub["pass"]:
        raise SystemExit(
            f"sharded decode is not sublinear in C: "
            f"{sub['sharded_per_token_ms']:.2f}ms/token >= "
            f"{sub['linear_bound_ms']:.2f}ms (C x the C=1 cost)")
    if not cont["pass"]:
        raise SystemExit(
            f"continuous batching lost its sustained-QPS uplift over the "
            f"convoyed static batch: {cont['qps_uplift']}x <= 1")
    if cont["paged"]["new_traces_in_stream"] or \
            cont["paged"]["retraced_in_stream"]:
        raise SystemExit("paged engine retraced inside the arrival stream")
    if cont["paged"]["pad_allocs_in_stream"] or \
            cont["static"]["pad_allocs_in_stream"]:
        raise SystemExit("host pad scratch allocated inside the arrival "
                         "stream instead of reusing the per-rung buffer")
    if not dl["pass"]:
        raise SystemExit(
            "deadline shedding did not raise goodput under burst overload "
            f"({dl['goodput_uplift']}x <= 1, or a request came back "
            "without a terminal status)")
    if dl["deadline"]["new_traces_in_stream"] or \
            dl["no_deadline"]["new_traces_in_stream"]:
        raise SystemExit("paged engine retraced inside the deadline burst "
                         "(deadline handling must stay host-side)")

"""Unified Engine: no per-delay retraces, scan-chunking speedup over the
per-step host loop, hooks, and train_loop integration."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.core import Quadratic
from repro.train.engine import Engine, checkpoint_hook, log_hook

STEPS = 40


@pytest.fixture(scope="module")
def quad_sampler():
    quad = Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)
    return samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                         gamma=0.01, sigma=0.5, tau=4)


def test_no_retrace_across_delay_values(quad_sampler):
    """Distinct realized delays must NOT retrigger compilation: the old
    loops passed python ints (one XLA program per delay value), the Engine
    feeds delays as traced int32 arrays."""
    engine = Engine(quad_sampler, chunk_size=10)
    delays = np.asarray([0, 1, 2, 3, 4] * 8)  # 5 distinct values, 4 chunks
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(1))
    state, _ = engine.run(state, steps=STEPS, delays=delays)
    assert engine.num_traces == 1, engine.num_traces
    # a remainder chunk is the only legitimate second trace
    state, _ = engine.run(state, steps=15, delays=delays)
    assert engine.num_traces == 2, engine.num_traces


def test_engine_faster_than_per_step_loop(quad_sampler):
    """Scan-chunking amortizes dispatch: one jit call per chunk instead of
    one per step must win wall-clock on a dispatch-bound problem."""
    steps = 600
    delays = jnp.asarray(np.random.default_rng(0).integers(0, 5, steps),
                         jnp.int32)
    batches = jnp.zeros((steps, 1))

    jstep = jax.jit(quad_sampler.step)
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(2))
    state, _ = jstep(state, batches[0], delays[0])  # compile
    jax.block_until_ready(state.params)
    t0 = time.time()
    for k in range(steps):
        state, _ = jstep(state, batches[k], delays[k])
    jax.block_until_ready(state.params)
    t_loop = time.time() - t0

    engine = Engine(quad_sampler, chunk_size=100, collect_aux=False)
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(2))
    state, _ = engine.run(state, steps=steps, batches=batches, delays=delays)
    jax.block_until_ready(state.params)  # warm (compiles the chunk)
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(2))
    t0 = time.time()
    state, _ = engine.run(state, steps=steps, batches=batches, delays=delays)
    jax.block_until_ready(state.params)
    t_engine = time.time() - t0

    assert t_engine < t_loop, (t_engine, t_loop)


def test_engine_matches_per_step_stepping(quad_sampler):
    delays = np.asarray([0, 2, 4, 1] * 10)
    batches = jnp.zeros((STEPS, 1))
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(3))
    jstep = jax.jit(quad_sampler.step)
    for k in range(STEPS):
        state, _ = jstep(state, batches[k], jnp.int32(delays[k]))
    engine = Engine(quad_sampler, chunk_size=7)  # remainder chunk included
    e_state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(3))
    e_state, _ = engine.run(e_state, steps=STEPS, batches=batches,
                            delays=delays)
    np.testing.assert_allclose(np.asarray(e_state.params),
                               np.asarray(state.params), rtol=1e-6, atol=1e-7)


def test_hooks_and_aux_collection(tmp_path, quad_sampler):
    quad = Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)
    sampler = samplers.sgld(
        "sync", lambda p, b: (quad.grad(p, b), {"loss": quad.value(p, b)}),
        gamma=0.01, sigma=0.5, has_aux=True)
    seen = []
    lines = []
    ckpt = os.path.join(tmp_path, "engine_ckpt.npz")
    engine = Engine(
        sampler, chunk_size=10,
        hooks=[lambda step_end, state, aux: seen.append(step_end),
               log_hook(every=10, log_fn=lines.append),
               checkpoint_hook(ckpt, every=20)])
    state = sampler.init(jnp.zeros(4), jax.random.PRNGKey(4))
    state, aux = engine.run(state, steps=STEPS)
    assert seen == [10, 20, 30, 40]
    assert len(lines) == 4 and "loss" in lines[0]
    assert os.path.exists(ckpt)
    assert aux["loss"].shape == (STEPS,)
    assert np.all(np.isfinite(aux["loss"]))


def test_engine_generates_batches_on_device(quad_sampler):
    """batch_fn(key) is vmapped over a chunk of keys; trajectories match
    pre-stacked batches bit-for-bit."""
    quad = Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0,
                          grad_noise=0.5)

    def grad(p, batch):
        return quad.grad(p, None, key=batch["key"])

    sampler = samplers.sgld("sync", grad, gamma=0.01, sigma=0.5)

    def batch_fn(key):
        return {"key": jax.random.fold_in(key, 0)}

    engine = Engine(sampler, batch_fn=batch_fn, chunk_size=8)
    state = sampler.init(jnp.zeros(4), jax.random.PRNGKey(5))
    state, _ = engine.run(state, steps=24, key=jax.random.PRNGKey(6))
    assert np.all(np.isfinite(np.asarray(state.params)))


def test_checkpoint_hook_flushes_final_state(tmp_path, quad_sampler):
    """steps not a multiple of `every` must still save the final state."""
    ckpt = os.path.join(tmp_path, "flush_ckpt.npz")
    engine = Engine(quad_sampler, chunk_size=10,
                    hooks=[checkpoint_hook(ckpt, every=10)])
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(7))
    state, _ = engine.run(state, steps=25)
    with np.load(ckpt) as data:
        assert int(data["__step__"]) == 25


def test_engine_accepts_delay_trace_and_threads_commit_times(quad_sampler):
    from repro.core import constant_delays

    trace = constant_delays(3, STEPS)
    engine = Engine(quad_sampler, chunk_size=10)
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(8))
    state, aux = engine.run(state, steps=STEPS, delays=trace)
    np.testing.assert_array_equal(aux["commit_time"], trace.commit_times)

    # identical trajectory to passing the raw delays ndarray
    state2 = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(8))
    state2, _ = engine.run(state2, steps=STEPS, delays=trace.delays)
    np.testing.assert_array_equal(np.asarray(state.params),
                                  np.asarray(state2.params))


def test_engine_rejects_delays_deeper_than_ring(quad_sampler):
    """tau=4 ring (depth 5) cannot serve staleness 5+: raise, don't clamp."""
    engine = Engine(quad_sampler, chunk_size=10)
    state = quad_sampler.init(jnp.zeros(4), jax.random.PRNGKey(9))
    delays = np.asarray([0, 1, 5, 2] * 10)
    with pytest.raises(ValueError, match="does not fit the iterate ring"):
        engine.run(state, steps=STEPS, delays=delays)


def test_train_loop_runs_through_engine():
    from dataclasses import replace

    from repro.configs import ShapeConfig, get_reduced
    from repro.core.sgld import SGLDConfig
    from repro.data import make_batch
    from repro.models.transformer import Model, init_params
    from repro.train.loop import train_loop

    cfg = replace(get_reduced("qwen3-4b"), dtype="float32")
    model = Model(cfg, mesh=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    delays = np.asarray([0, 1, 2, 1, 0, 2], dtype=np.int32)
    lines = []
    state, history = train_loop(
        model, params, SGLDConfig(mode="consistent", gamma=1e-3, sigma=1e-6,
                                  tau=2),
        lambda k: make_batch(cfg, shape, k, "train"), steps=6,
        key=jax.random.PRNGKey(1), delays=delays, log_every=3,
        log_fn=lines.append)
    assert [k for k, _ in history] == [0, 3, 5]
    assert all(np.isfinite(v) for _, v in history)
    assert lines  # log hook fired

"""Watchdog-wrapped subprocess runner for the sharded test scripts.

The sharded equivalence tests spawn ``python -c SCRIPT`` children with 8
forced host devices; a wedged child (XLA deadlock, runaway compile) used to
hold the whole suite hostage until the outer CI timeout.  ``run_json`` puts
every child in its own process group and, when the watchdog fires,
SIGKILLs the *group* — grandchildren holding the stdout/stderr pipes can't
keep ``communicate()`` blocked — then fails the test with the captured
output tails instead of hanging.
"""

import json
import os
import signal
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_json(script: str, *, timeout: float = 600, env: dict | None = None):
    """Run ``python -c script`` under a hard watchdog; parse the last
    stdout line as JSON.

    The child gets ``PYTHONPATH=src`` and ``JAX_PLATFORMS=cpu`` (override
    via ``env``).  A non-zero exit asserts with the stderr tail; a timeout
    SIGKILLs the child's whole process group and asserts with both tails.
    """
    full_env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    if env:
        full_env.update(env)
    proc = subprocess.Popen(
        [sys.executable, "-c", script], stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True, env=full_env,
        start_new_session=True)
    try:
        out, err = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        try:
            os.killpg(proc.pid, signal.SIGKILL)
        except ProcessLookupError:
            pass
        out, err = proc.communicate()
        raise AssertionError(
            f"subprocess watchdog fired after {timeout}s\n"
            f"--- stdout tail ---\n{(out or '')[-2000:]}\n"
            f"--- stderr tail ---\n{(err or '')[-2000:]}")
    assert proc.returncode == 0, err[-3000:]
    return json.loads(out.strip().splitlines()[-1])

"""Launch-stack integration at CI scale: a 2x4 debug mesh in a subprocess
(8 forced host devices) exercises param_structs -> lower -> compile ->
roofline for a reduced arch, train + decode."""

import os

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from dataclasses import replace
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced, ShapeConfig
from repro.launch.steps import (build_model, param_structs, batch_specs,
                                cache_spec_tree, make_sgld_train_step,
                                make_decode_step)
from repro.launch import roofline as rl
from repro.launch.jaxpr_cost import step_cost

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("ci", seq_len=64, global_batch=4, kind="train",
                    num_microbatches=2)
cfg0 = replace(get_reduced("qwen3-4b"), num_heads=8, num_kv_heads=2)
model, cfg, baxes, faxes = build_model(cfg0, shape, mesh, opts=("attn_shard",))
pstructs, pshard = param_structs(cfg, mesh, faxes)
bstructs = batch_specs(cfg, shape, mesh, baxes)
rep = NamedSharding(mesh, P())
out = {}
from repro.utils import use_mesh
with use_mesh(mesh):
    step = make_sgld_train_step(model, shape)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep)
    compiled = jax.jit(step, out_shardings=(pshard, rep)).lower(
        pstructs, bstructs, key).compile()
    cost = step_cost(step, pstructs, bstructs, key, num_devices=8)
    roof = rl.analyze("ci/train", compiled, 8, rl.model_flops(cfg, shape),
                      jaxpr_cost=cost)
    out["train"] = {"dominant": roof.dominant,
                    "flops": roof.flops_per_device,
                    "coll": roof.collective_bytes_per_device}
    # decode
    dshape = ShapeConfig("ci_dec", seq_len=64, global_batch=4, kind="decode")
    model2, cfg2, baxes2, _ = build_model(cfg0, dshape, mesh)
    cstructs, cshard = cache_spec_tree(model2, cfg2, dshape, mesh, baxes2)
    bst = batch_specs(cfg2, dshape, mesh, baxes2, kind="decode")
    dstep = make_decode_step(model2)
    c2 = jax.jit(dstep, out_shardings=(None, cshard)).lower(
        pstructs, cstructs, bst).compile()
    out["decode_ok"] = True
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_launch_stack():
    from subproc import run_json

    out = run_json(SCRIPT, timeout=600)
    assert out["decode_ok"]
    assert out["train"]["flops"] > 0
    assert out["train"]["dominant"] in ("compute", "memory", "collective")

"""cluster.decode: streaming BMA decode from the chain bank.

The acceptance criteria of the decode subsystem: greedy streaming decode is
bitwise-equal to a jitted prefill-per-step reference (padding included), the
KV bank wraps correctly at ``smax`` under a sliding window, a mixed prompt
stream compiles one trace per (bucket, max_new) pair, the fused Pallas
decode step is bitwise-equal to its oracle, and sharded decode is
bitwise-equal to unsharded (slow subprocess test)."""

import os
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.cluster import DecodeEngine, ServeEngine
from repro.configs import get_reduced
from repro.kernels.ops import fused_decode_step
from repro.kernels.ref import decode_step_ref
from repro.models import bma_logits, transformer_next_token_predict
from repro.models.transformer import Model, init_params

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
C = 4


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("qwen3-4b")


@pytest.fixture(scope="module")
def model(cfg):
    return Model(cfg, remat=False)


@pytest.fixture(scope="module")
def bank(cfg):
    return jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), C))


def prompt_batch(b, t, vocab, seed=1):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0,
                                         vocab, dtype=jnp.int32))


def prefill_per_step_reference(model, bank, prompt: np.ndarray, n: int):
    """Greedy decode where every token re-runs the full (unpadded) prompt
    forward — jitted once per sequence length, BMA-reduced identically."""

    @jax.jit
    def last_logits(bank, toks):
        def one(p):
            logits, _, _ = model.forward(p, {"tokens": toks})
            return logits[:, -1]

        return bma_logits(jax.vmap(one)(bank))

    toks = prompt.copy()
    out_toks, out_logits = [], []
    for _ in range(n):
        logp = np.asarray(last_logits(bank, jnp.asarray(toks)))
        nxt = np.argmax(logp, axis=-1).astype(np.int32)
        out_toks.append(nxt)
        out_logits.append(logp)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    return np.stack(out_toks, axis=1), np.stack(out_logits, axis=1)


# ---------------------------------------------------------------------------
# greedy decode-vs-prefill parity: the acceptance-criterion check
# ---------------------------------------------------------------------------
def test_greedy_decode_bitwise_equals_prefill_per_step(cfg, model, bank):
    """Streaming decode (cached, padded to rungs (4, 8)) must be
    bitwise-equal — tokens AND BMA logits — to the prefill-per-step
    reference on the unpadded prompt."""
    engine = DecodeEngine(model=model, params=bank, max_seq=32,
                          return_logits=True)
    prompt = prompt_batch(3, 5, cfg.vocab_size)
    res = engine.generate(prompt, 6)
    ref_toks, ref_logits = prefill_per_step_reference(model, bank, prompt, 6)
    assert np.array_equal(res.tokens, ref_toks)
    assert np.array_equal(res.logits, ref_logits)
    assert res.tokens.shape == (3, 6)
    assert res.tokens.dtype == np.int32
    # BMA logits are normalized log-probabilities of the predictive law
    np.testing.assert_allclose(
        np.exp(res.logits).sum(axis=-1), 1.0, atol=1e-5)


def test_mixed_prompt_stream_one_trace_per_rung_pair(cfg, model, bank):
    """Distinct (B, T) requests bucket to rung pairs; the engine compiles
    once per pair and every request still matches its unpadded reference."""
    engine = DecodeEngine(model=model, params=bank, max_seq=32)
    shapes = [(3, 5), (4, 8), (2, 5), (3, 4), (1, 7), (4, 6)]
    rungs = set()
    for i, (b, t) in enumerate(shapes):
        prompt = prompt_batch(b, t, cfg.vocab_size, seed=10 + i)
        res = engine.generate(prompt, 4)
        ref_toks, _ = prefill_per_step_reference(model, bank, prompt, 4)
        assert np.array_equal(res.tokens, ref_toks), (b, t)
        rungs.add((1 << (b - 1).bit_length(), 1 << (t - 1).bit_length()))
    assert engine.num_traces == len(rungs)
    # prompt pad scratch: one buffer per rung pair, not one per request
    assert engine.num_host_pad_allocs == len(rungs)


def test_kv_bank_wraparound_at_smax_with_window(cfg, model, bank):
    """Decoding past the ring's smax slots under a sliding window must keep
    matching the full-recompute reference while oldest slots are
    overwritten in place."""
    cfgw = replace(cfg, sliding_window=16)
    mw = Model(cfgw, remat=False)
    bankw = jax.vmap(lambda k: init_params(k, cfgw))(
        jax.random.split(jax.random.PRNGKey(0), C))
    engine = DecodeEngine(model=mw, params=bankw, max_seq=64)  # smax == 16
    prompt = prompt_batch(2, 5, cfgw.vocab_size, seed=3)
    n = 20  # final position 24 > smax: the ring wraps
    res = engine.generate(prompt, n)
    ref_toks, _ = prefill_per_step_reference(mw, bankw, prompt, n)
    assert np.array_equal(res.tokens, ref_toks)


def test_prompt_longer_than_cache_raises(cfg, model, bank):
    engine = DecodeEngine(model=model, params=bank, max_seq=8)
    with pytest.raises(ValueError, match="overflows"):
        engine.generate(prompt_batch(2, 9, cfg.vocab_size), 2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.generate(prompt_batch(2, 4, cfg.vocab_size), 0)
    # a windowed model wraps legitimately, so the overflow guard steps
    # aside — but a prompt rung beyond the window's smax still fails loudly
    windowed = Model(replace(cfg, sliding_window=4), remat=False)
    engine_w = DecodeEngine(model=windowed, params=bank, max_seq=8)
    with pytest.raises(ValueError, match="exceeds the cache"):
        engine_w.generate(prompt_batch(2, 5, cfg.vocab_size), 2)


def test_full_attention_overflow_raises_instead_of_ring_wrap(cfg, model,
                                                             bank):
    """Without a sliding window, overwriting the ring's oldest slot would
    silently drop real context — the engine must refuse up front."""
    engine = DecodeEngine(model=model, params=bank, max_seq=16)
    with pytest.raises(ValueError, match="overflows"):
        engine.generate(prompt_batch(2, 6, cfg.vocab_size), 9)  # 8 + 9 > 16
    assert engine.generate(prompt_batch(2, 6, cfg.vocab_size), 8).tokens.shape \
        == (2, 8)  # exactly filling the cache is fine


def test_sampled_decode_deterministic_and_in_vocab(cfg, model, bank):
    engine = DecodeEngine(model=model, params=bank, max_seq=32)
    prompt = prompt_batch(2, 4, cfg.vocab_size, seed=5)
    key = jax.random.PRNGKey(7)
    a = engine.generate(prompt, 5, key=key)
    b = engine.generate(prompt, 5, key=key)
    c = engine.generate(prompt, 5, key=jax.random.PRNGKey(8))
    assert np.array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens)  # keys matter
    assert a.tokens.min() >= 0 and a.tokens.max() < cfg.vocab_size
    # greedy and sampled are distinct traces of the same rung, counted once
    assert engine.num_traces == 1


def test_cache_bank_allocated_once_per_rung_and_reused(cfg, model, bank):
    engine = DecodeEngine(model=model, params=bank, max_seq=32)
    prompt = prompt_batch(3, 5, cfg.vocab_size)
    engine.generate(prompt, 3)
    assert set(engine._cache) == {4}  # one persistent bank for rung B=4
    k_leaf = engine._cache[4]["attn"]["k"]
    assert k_leaf.shape[:3] == (C, cfg.num_layers, 4)
    engine.generate(prompt, 3)
    assert set(engine._cache) == {4}  # reused (donated through), not regrown
    engine.generate(prompt_batch(7, 5, cfg.vocab_size), 3)
    assert set(engine._cache) == {4, 8}


# ---------------------------------------------------------------------------
# fused Pallas decode step
# ---------------------------------------------------------------------------
def test_fused_kernel_bitwise_vs_ref():
    B, H, KV, hd, smax = 3, 4, 2, 16, 12
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    kn = jax.random.normal(ks[1], (B, KV, hd), jnp.bfloat16)
    vn = jax.random.normal(ks[2], (B, KV, hd), jnp.bfloat16)
    kc = jax.random.normal(ks[3], (B, smax, KV, hd), jnp.bfloat16)
    vc = jax.random.normal(ks[4], (B, smax, KV, hd), jnp.bfloat16)
    valid = (jnp.arange(smax) < 7).astype(jnp.int32)
    slot = jnp.int32(6)
    o, ko, vo = fused_decode_step(q, kn, vn, kc, vc, valid, slot)
    ro, rk, rv = decode_step_ref(q.reshape(B, KV, H // KV, hd), kn, vn, kc,
                                 vc, valid, slot)
    assert np.array_equal(np.asarray(o, jnp.float32),
                          np.asarray(ro.reshape(B, H, hd), jnp.float32))
    assert np.array_equal(np.asarray(ko), np.asarray(rk))
    assert np.array_equal(np.asarray(vo), np.asarray(rv))
    # the written slot holds the new k/v, every other slot is untouched
    assert np.array_equal(np.asarray(ko[:, 6]), np.asarray(kn))
    mask = np.arange(smax) != 6
    assert np.array_equal(np.asarray(ko[:, mask]), np.asarray(kc[:, mask]))


def test_fused_kernel_chain_batched_bitwise():
    """The chain axis arrives via vmap (pallas batching rule): every chain's
    row must equal its own single-call kernel output bitwise."""
    Cc, B, H, KV, hd, smax = 3, 2, 4, 2, 8, 10
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (Cc, B, H, hd), jnp.bfloat16)
    kn = jax.random.normal(ks[1], (Cc, B, KV, hd), jnp.bfloat16)
    vn = jax.random.normal(ks[2], (Cc, B, KV, hd), jnp.bfloat16)
    kc = jax.random.normal(ks[3], (Cc, B, smax, KV, hd), jnp.bfloat16)
    vc = jax.random.normal(ks[4], (Cc, B, smax, KV, hd), jnp.bfloat16)
    valid = jnp.ones((smax,), jnp.int32)
    slot = jnp.int32(9)
    out = jax.vmap(lambda a, b, c, d, e: fused_decode_step(
        a, b, c, d, e, valid, slot))(q, kn, vn, kc, vc)
    for c in range(Cc):
        one = fused_decode_step(q[c], kn[c], vn[c], kc[c], vc[c], valid, slot)
        for got, want in zip(out, one):
            assert np.array_equal(np.asarray(got[c], jnp.float32),
                                  np.asarray(want, jnp.float32)), c


def test_fused_decode_matches_unfused(cfg, model, bank):
    """fused=True is an opt-in hot-path swap: same tokens, same BMA logits
    as the unfused engine on this build (both paths share fp32 softmax and
    reduction order)."""
    prompt = prompt_batch(3, 5, cfg.vocab_size, seed=2)
    plain = DecodeEngine(model=model, params=bank, max_seq=32,
                         return_logits=True)
    fused = DecodeEngine(model=model, params=bank, max_seq=32, fused=True,
                         return_logits=True)
    a = plain.generate(prompt, 6)
    b = fused.generate(prompt, 6)
    assert np.array_equal(a.tokens, b.tokens)
    np.testing.assert_allclose(a.logits, b.logits, atol=1e-5)


# ---------------------------------------------------------------------------
# bank restore / serve bridge / validation
# ---------------------------------------------------------------------------
def test_from_checkpoint_streams_same_tokens(cfg, model, bank, tmp_path):
    path = str(tmp_path / "bank.npz")
    save_checkpoint(path, bank)
    like = jax.tree_util.tree_map(lambda x: x[0], bank)
    restored = DecodeEngine.from_checkpoint(path, model, like, max_seq=32)
    live = DecodeEngine(model=model, params=bank, max_seq=32)
    assert restored.num_chains == C
    prompt = prompt_batch(2, 6, cfg.vocab_size, seed=4)
    assert np.array_equal(restored.generate(prompt, 5).tokens,
                          live.generate(prompt, 5).tokens)


def test_serve_engine_decoder_bridge(cfg, model, bank):
    """ServeEngine.decoder: single-shot predictive serving and streaming
    decode share one bank and one bucket ladder."""
    serve = ServeEngine(predict_fn=transformer_next_token_predict(model),
                        params=bank, donate=False, buckets=(4, 8))
    engine = serve.decoder(model, max_seq=32)
    assert engine.buckets == [4, 8]
    assert engine.params is serve.params
    prompt = prompt_batch(2, 4, cfg.vocab_size, seed=6)
    res = engine.generate(prompt, 3)
    ref_toks, _ = prefill_per_step_reference(model, bank, prompt, 3)
    assert np.array_equal(res.tokens, ref_toks)


def test_decode_rejects_non_attention_stacks():
    cfg = get_reduced("xlstm-1.3b")
    params = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), 2))
    with pytest.raises(ValueError, match="attention stack"):
        DecodeEngine(model=Model(cfg, remat=False), params=params)


# ---------------------------------------------------------------------------
# sharded decode (subprocess: 8 forced host devices, debug mesh)
# ---------------------------------------------------------------------------
SCRIPT_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.cluster import DecodeEngine
from repro.configs import get_reduced
from repro.launch.mesh import make_debug_mesh
from repro.models.transformer import Model, init_params

cfg = get_reduced("qwen3-4b")
model = Model(cfg, remat=False)
bank = jax.vmap(lambda k: init_params(k, cfg))(
    jax.random.split(jax.random.PRNGKey(0), 8))
prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0,
                                       cfg.vocab_size, dtype=jnp.int32))

local = DecodeEngine(model=model, params=bank, max_seq=32, return_logits=True)
mesh = make_debug_mesh(data=4, model=2)
sharded = DecodeEngine(model=model, params=bank, max_seq=32, mesh=mesh,
                       return_logits=True)
a, b = local.generate(prompt, 6), sharded.generate(prompt, 6)

twod = DecodeEngine(model=model, params=bank, max_seq=32, mesh=mesh,
                    shard_params=True, return_logits=True)
c = twod.generate(prompt, 6)
wq_spec = None
for path, leaf in jax.tree_util.tree_flatten_with_path(twod.params)[0]:
    if "wq" in "/".join(str(getattr(k, "key", k)) for k in path):
        wq_spec = tuple(str(s) for s in leaf.sharding.spec)
print(json.dumps({
    "tokens_bitwise": bool(np.array_equal(a.tokens, b.tokens)),
    "logits_bitwise": bool(np.array_equal(a.logits, b.logits)),
    "chain_axis_sharded":
        jax.tree_util.tree_leaves(sharded.params)[0].sharding.spec[0] == "data",
    "traces": sharded.num_traces,
    "twod_tokens_equal": bool(np.array_equal(a.tokens, c.tokens)),
    "twod_logits_close": bool(np.allclose(a.logits, c.logits, atol=0.1)),
    "twod_wq_spec": wq_spec,
}))
"""


@pytest.mark.slow
def test_sharded_decode_bitwise_equal_single_device():
    """Acceptance criterion: chain-sharded streaming decode (per-token
    all-gather of the logit block, replicated BMA) is bitwise-equal to the
    single-device engine, and the 2-D (chains x tensor-parallel) bank
    streams the same tokens."""
    from subproc import run_json

    res = run_json(SCRIPT_SHARDED, timeout=900)
    assert res["tokens_bitwise"], res
    assert res["logits_bitwise"], res
    assert res["chain_axis_sharded"], res
    assert res["traces"] == 1, res
    assert res["twod_tokens_equal"], res
    assert res["twod_logits_close"], res
    assert res["twod_wq_spec"] == ["data", "None", "None", "model"], res

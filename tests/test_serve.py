"""cluster.serve: per-query predictive statistics bitwise-equal to the
single-device gather-then-reduce reference (sharded included), bucket
padding transparent to the statistics, one trace per shape bucket across a
mixed request stream, and checkpoint restore into the ensemble layout."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.checkpoint import restore_ensemble, save_checkpoint
from repro.cluster import (
    ClusterEngine,
    ServeEngine,
    bucket_size,
    ensemble_async,
    predictive_stats,
)
from repro.core import PolyRegression, WorkerModel
from repro.models import regression_predict

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
C = 8


@pytest.fixture(scope="module")
def reg():
    return PolyRegression.make(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def bank():
    return jax.random.normal(jax.random.PRNGKey(1), (C, 5))


@pytest.fixture(scope="module")
def reference(reg, bank):
    """The gather-then-reduce reference: the whole bank on one device, the
    unpadded query batch, the shared reduction — jitted like the engine."""
    predict = regression_predict(reg)
    qs = jnp.asarray((0.05, 0.5, 0.95), jnp.float32)

    @jax.jit
    def ref(params, queries):
        preds = jax.vmap(predict, in_axes=(0, None))(params, queries)
        return predictive_stats(preds, qs)

    return lambda queries: ref(bank, queries)


# ---------------------------------------------------------------------------
# bucket ladder
# ---------------------------------------------------------------------------
def test_bucket_size_defaults_to_powers_of_two():
    assert [bucket_size(n) for n in (1, 2, 3, 4, 5, 9, 33)] == \
        [1, 2, 4, 4, 8, 16, 64]
    with pytest.raises(ValueError):
        bucket_size(0)


def test_bucket_size_explicit_ladder_is_a_contract():
    assert bucket_size(5, buckets=(4, 16)) == 16
    with pytest.raises(ValueError, match="exceed the largest bucket"):
        bucket_size(17, buckets=(4, 16))


# ---------------------------------------------------------------------------
# statistics parity + padding transparency: the acceptance-criterion checks
# ---------------------------------------------------------------------------
def test_stats_bitwise_equal_gather_then_reduce(reg, bank, reference):
    engine = ServeEngine(predict_fn=regression_predict(reg), params=bank)
    z = jnp.linspace(-1.0, 1.0, 5)  # padded up to bucket 8
    res, ref = engine(z), reference(z)
    assert np.array_equal(np.asarray(res.mean), np.asarray(ref.mean))
    assert np.array_equal(np.asarray(res.var), np.asarray(ref.var))
    assert np.array_equal(np.asarray(res.quantiles), np.asarray(ref.quantiles))
    assert res.mean.shape == (5,) and res.quantiles.shape == (3, 5)


def test_bucket_padding_transparent_across_mixed_stream(reg, bank, reference):
    """Every request of a mixed stream must produce stats identical to its
    unpadded reference, while compiling at most one trace per bucket."""
    engine = ServeEngine(predict_fn=regression_predict(reg), params=bank)
    sizes = [3, 4, 2, 7, 8, 5, 1, 6, 4, 3]
    for i, n in enumerate(sizes):
        z = jax.random.uniform(jax.random.PRNGKey(i), (n,),
                               minval=-1.0, maxval=1.0)
        res, ref = engine(z), reference(z)
        for got, want in zip(res, ref):
            assert np.array_equal(np.asarray(got), np.asarray(want)), n
    assert engine.num_traces == len({bucket_size(n) for n in sizes})


def test_padding_never_consumes_the_callers_buffer(reg, bank):
    """donate_argnums applies to the engine's own padded buffer: a request
    exactly at a bucket boundary must leave the caller's array usable."""
    engine = ServeEngine(predict_fn=regression_predict(reg), params=bank)
    z = jnp.linspace(-1.0, 1.0, 4)  # exactly bucket 4, no padding needed
    engine(z)
    np.testing.assert_allclose(np.asarray(z)[-1], 1.0)  # not donated away


def test_host_padding_reuses_one_scratch_per_rung(reg, bank, reference):
    """Host-query padding must allocate one scratch buffer per (rung, leaf)
    and then rewrite it in place — zero allocations per request — without
    perturbing the statistics."""
    engine = ServeEngine(predict_fn=regression_predict(reg), params=bank)
    rng = np.random.default_rng(0)
    first = rng.uniform(-1.0, 1.0, 5).astype(np.float32)
    engine(first)
    assert engine.num_host_pad_allocs == 1  # rung 8 scratch created
    buf0 = engine._host_scratch.get(("pad", 0), (8,), np.float32)
    for i in range(6):  # same rung, distinct sizes: no new allocations
        z = rng.uniform(-1.0, 1.0, 5 + (i % 3)).astype(np.float32)
        res, ref = engine(z), reference(jnp.asarray(z))
        for got, want in zip(res, ref):
            assert np.array_equal(np.asarray(got), np.asarray(want)), i
    assert engine.num_host_pad_allocs == 1
    assert engine._host_scratch.get(("pad", 0), (8,), np.float32) is buf0
    engine(rng.uniform(-1.0, 1.0, 12).astype(np.float32))  # rung 16
    assert engine.num_host_pad_allocs == 2


def test_pytree_queries_pad_and_slice(reg, bank):
    """Dict-shaped query batches bucket on the shared leading axis."""

    def predict(w, batch):
        return reg.predict(w, reg.features(batch["z"])) + batch["offset"]

    engine = ServeEngine(predict_fn=predict, params=bank)
    batch = {"z": jnp.linspace(-1.0, 1.0, 3), "offset": jnp.zeros(3)}
    res = engine(batch)
    assert res.mean.shape == (3,)
    assert np.all(np.isfinite(np.asarray(res.mean)))


def test_quantile_order_matches_engine_quantiles(reg, bank):
    engine = ServeEngine(predict_fn=regression_predict(reg), params=bank,
                         quantiles=(0.1, 0.9))
    res = engine(jnp.linspace(-1.0, 1.0, 4))
    assert res.quantiles.shape == (2, 4)
    assert np.all(np.asarray(res.quantiles[0]) <= np.asarray(res.quantiles[1]))
    assert np.all(np.asarray(res.var) >= 0.0)
    assert np.array_equal(np.asarray(res.std), np.sqrt(np.asarray(res.var)))


# ---------------------------------------------------------------------------
# checkpoint: ensemble layout export/restore
# ---------------------------------------------------------------------------
def test_save_ensemble_restores_into_serve(reg, tmp_path):
    """train -> save_ensemble -> from_checkpoint serves the same statistics
    as serving the live ClusterEngine state."""
    scheds = ensemble_async(WorkerModel(num_workers=4, seed=1), 12, C, seed=0)
    tau = max(s.max_delay for s in scheds)
    sampler = samplers.sgld("consistent", lambda w, b: reg.grad(w, b),
                            gamma=1e-4, sigma=1e-3, tau=max(tau, 1))
    engine = ClusterEngine(sampler, num_chains=C, chunk_size=6,
                           batch_fn=lambda k: reg.sample_batch(k, 32))
    state = engine.init(jnp.zeros(5), jax.random.PRNGKey(3), jitter=0.1)
    state, _ = engine.run(state, steps=12, schedule=scheds,
                          key=jax.random.PRNGKey(4))

    path = str(tmp_path / "bank.npz")
    engine.save_ensemble(state, path)
    live = ServeEngine.from_cluster(state, regression_predict(reg))
    restored = ServeEngine.from_checkpoint(path, like=jnp.zeros(5),
                                           predict_fn=regression_predict(reg))
    assert restored.num_chains == C
    z = jnp.linspace(-1.0, 1.0, 6)
    for got, want in zip(restored(z), live(z)):
        assert np.array_equal(np.asarray(got), np.asarray(want))


def test_restore_ensemble_broadcasts_single_model(tmp_path):
    path = str(tmp_path / "single.npz")
    single = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.float32(1.5)}
    save_checkpoint(path, single)
    with pytest.raises(ValueError, match="num_chains"):
        restore_ensemble(path, single)
    bank = restore_ensemble(path, single, num_chains=4)
    assert bank["w"].shape == (4, 2, 3) and bank["b"].shape == (4,)
    assert np.array_equal(np.asarray(bank["w"][2]), np.asarray(single["w"]))


def test_restore_ensemble_rejects_mixed_layout(tmp_path):
    """A checkpoint mixing chain-stacked and single-model leaves (scalar
    leaves included) must raise the documented ValueError, not crash."""
    path = str(tmp_path / "mixed.npz")
    like = {"w": jnp.zeros((2, 3)), "b": jnp.float32(0.0)}
    save_checkpoint(path, {"w": jnp.zeros((C, 2, 3)), "b": jnp.float32(0.0)})
    with pytest.raises(ValueError, match="neither a single-model nor"):
        restore_ensemble(path, like)


def test_non_donating_engine_exact_bucket_passthrough(reg, bank, reference):
    """donate=False serves exact-bucket device requests without the
    donation-shield copy, and the statistics are unchanged."""
    engine = ServeEngine(predict_fn=regression_predict(reg), params=bank,
                         donate=False)
    z = jnp.linspace(-1.0, 1.0, 8)  # exactly bucket 8
    res, ref = engine(z), reference(z)
    for got, want in zip(res, ref):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(z)[-1] == 1.0  # caller's buffer untouched


def test_restore_ensemble_rejects_chain_mismatch(tmp_path):
    path = str(tmp_path / "bank.npz")
    single = {"w": jnp.zeros((2, 3))}
    save_checkpoint(path, {"w": jnp.zeros((C, 2, 3))})
    with pytest.raises(ValueError, match=f"holds {C} chains"):
        restore_ensemble(path, single, num_chains=3)
    assert restore_ensemble(path, single)["w"].shape == (C, 2, 3)


# ---------------------------------------------------------------------------
# model-layer predict fns: the transformer serving path
# ---------------------------------------------------------------------------
def test_transformer_bank_serves_next_token_logits():
    from repro.configs import get_reduced
    from repro.models import transformer_next_token_predict
    from repro.models.transformer import Model, init_params

    cfg = get_reduced("qwen3-4b")
    model = Model(cfg, mesh=None, remat=False)
    chains = 2
    params = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), chains))
    predict = transformer_next_token_predict(model)
    engine = ServeEngine(predict_fn=predict, params=params,
                         quantiles=(0.1, 0.9))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    res = engine({"tokens": tokens})
    assert res.mean.shape == (3, cfg.vocab_size)
    assert res.quantiles.shape == (2, 3, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(res.mean)))

    # Bayesian model averaging: the ensemble mean is the chain average of
    # the per-chain serving-path logits
    per_chain = jax.jit(jax.vmap(predict, in_axes=(0, None)))(
        params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(res.mean),
                               np.asarray(jnp.mean(per_chain, axis=0)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# sharded serving (subprocess: 8 forced host devices, debug mesh)
# ---------------------------------------------------------------------------
SCRIPT_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.cluster import ServeEngine
from repro.core import PolyRegression
from repro.launch.mesh import make_debug_mesh
from repro.models import regression_predict

reg = PolyRegression.make(jax.random.PRNGKey(0))
bank = jax.random.normal(jax.random.PRNGKey(1), (8, 5))
predict = regression_predict(reg)

local = ServeEngine(predict_fn=predict, params=bank)
mesh = make_debug_mesh(data=4, model=2)
sharded = ServeEngine(predict_fn=predict, params=bank, mesh=mesh)

equal = True
for i, n in enumerate((5, 3, 16, 8)):
    z = jax.random.uniform(jax.random.PRNGKey(10 + i), (n,),
                           minval=-1.0, maxval=1.0)
    a, b = local(z), sharded(z)
    equal &= all(np.array_equal(np.asarray(x), np.asarray(y))
                 for x, y in zip(a, b))
spec = sharded.params.sharding.spec
print(json.dumps({
    "bitwise_equal": bool(equal),
    "chain_axis_sharded": spec[0] == "data",
    "traces": sharded.num_traces,
    "buckets": 3,
}))
"""


@pytest.mark.slow
def test_sharded_serve_bitwise_equal_single_device():
    """Acceptance criterion: chain-sharded predictive mean/var/quantiles are
    bitwise-equal to the gathered single-device reference, with one trace
    per shape bucket."""
    from subproc import run_json

    res = run_json(SCRIPT_SHARDED, timeout=600)
    assert res["bitwise_equal"], res
    assert res["chain_axis_sharded"], res
    assert res["traces"] == res["buckets"], res

"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned architecture runs one forward/train step and one decode step on CPU,
asserting output shapes and no NaNs."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ShapeConfig, get_reduced
from repro.core import SGLDConfig
from repro.data import make_batch
from repro.models.transformer import Model, init_params, loss_fn
from repro.train.loop import make_train_step

TRAIN_SHAPE = ShapeConfig("smoke_train", seq_len=64, global_batch=2,
                          kind="train")
DEC_SHAPE = ShapeConfig("smoke_dec", seq_len=32, global_batch=2, kind="decode")


@pytest.fixture(scope="module", params=ARCH_IDS)
def setup(request):
    cfg = replace(get_reduced(request.param), dtype="float32")
    model = Model(cfg, mesh=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, model, params


def test_forward_shapes_and_finite(setup):
    aid, cfg, model, params = setup
    batch = make_batch(cfg, TRAIN_SHAPE, jax.random.PRNGKey(1), "train")
    loss, metrics = loss_fn(model, params, batch)
    assert np.isfinite(float(loss)), aid
    assert float(loss) > 0


def test_sgld_train_step_updates_params(setup):
    aid, cfg, model, params = setup
    sgld = SGLDConfig(mode="sync", gamma=1e-3, sigma=1e-8)
    sampler, step_fn = make_train_step(model, sgld)
    state = sampler.init(params, jax.random.PRNGKey(2))
    batch = make_batch(cfg, TRAIN_SHAPE, jax.random.PRNGKey(3), "train")
    new_state, metrics = jax.jit(step_fn)(state, batch, 0)
    assert np.isfinite(float(metrics["loss"]))
    # params changed and stayed finite
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), state.params,
        new_state.params)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0
    for leaf in jax.tree_util.tree_leaves(new_state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), aid


def test_serve_step_shapes(setup):
    aid, cfg, model, params = setup
    cache = model.init_cache(2, DEC_SHAPE.seq_len,
                             prefill_len=DEC_SHAPE.seq_len - 1)
    batch = make_batch(cfg, DEC_SHAPE, jax.random.PRNGKey(4), "decode")
    logits, new_cache = jax.jit(model.serve_step)(
        params, cache, batch["tokens"], batch["cur_pos"])
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), aid


def test_decode_consistent_with_forward(setup):
    """Greedy next-token from decode path == argmax of last-position logits
    from the parallel forward (attention-only archs, exact cache replay)."""
    aid, cfg, model, params = setup
    if cfg.block_pattern[0] != "attn_mlp":
        pytest.skip("recurrent archs covered by block tests; MoE capacity "
                    "dropping differs between 2-token decode and 32-token "
                    "forward (by design)")
    if cfg.frontend:
        pytest.skip("frontend archs: positions differ between paths")
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, S), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    logits_full, _, _ = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(2, S + 1)
    for t in range(S):
        logits_dec, cache = model.serve_step(params, cache, tokens[:, t:t + 1],
                                             jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(logits_dec[:, 0]),
                               atol=2e-3, rtol=1e-2)

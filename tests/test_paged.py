"""cluster.paged: continuous batching over the paged KV bank.

The paged serving contract, pinned:

- a single-slot paged engine is **bitwise-equal** (tokens AND BMA logits)
  to the contiguous :class:`DecodeEngine` on the same request;
- at ``num_slots > 1`` the step batch runs at width S, so XLA may pick a
  different (gemm vs gemv) matmul schedule than the contiguous B=1 path —
  the honest invariant is **slot-occupancy invariance**: a request decodes
  bitwise-identically whether it runs alone in the engine or interleaved
  with a full complement of neighbours;
- admission is slot-level: a waiting prompt is prefilled the moment a
  sequence finishes or is evicted, never at batch boundaries;
- priority eviction requeues the victim and replays it bitwise (sampling
  keys are folded per absolute position, so a replay resamples the exact
  same tokens);
- the engine compiles one prefill trace per prompt rung plus ONE step
  trace for its whole lifetime, and a warm stream never retraces or
  allocates pad scratch;
- the fused Pallas paged step is bitwise-equal to its oracle and slots
  into the engine without changing tokens.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.instrument import instrument
from repro.cluster import DecodeEngine, PagedDecodeEngine
from repro.cluster.api import Request
from repro.cluster.paged import PageAllocator
from repro.configs import get_reduced
from repro.kernels.ops import fused_paged_decode_step
from repro.kernels.ref import paged_decode_step_ref
from repro.models.transformer import Model, init_params

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
C = 4


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("qwen3-4b")


@pytest.fixture(scope="module")
def model(cfg):
    return Model(cfg, remat=False)


@pytest.fixture(scope="module")
def bank(cfg):
    return jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), C))


def prompts_and_budgets(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    lens = [5, 3, 7, 2, 6, 4][:n]
    budgets = [6, 2, 9, 1, 12, 7][:n]
    toks = [rng.integers(0, cfg.vocab_size, (t,), dtype=np.int32)
            for t in lens]
    return toks, budgets


def fresh(model, bank, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_seq", 32)
    kw.setdefault("decode_chunk", 4)
    return PagedDecodeEngine(model=model, params=bank, **kw)


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------
def test_page_allocator_reserves_garbage_page_and_round_trips():
    a = PageAllocator(9)  # pages 1..8 usable, page 0 is the garbage sink
    assert a.free_pages == 8
    got = a.alloc(3)
    assert got is not None and len(got) == 3 and 0 not in got
    assert a.free_pages == 5
    assert a.alloc(6) is None          # insufficient: no partial grant
    assert a.free_pages == 5           # failed alloc takes nothing
    a.free(got)
    assert a.free_pages == 8
    assert sorted(a.alloc(8)) == list(range(1, 9))


def test_page_allocator_rejects_bad_frees():
    a = PageAllocator(5)
    with pytest.raises(ValueError, match="bad page id"):
        a.free([0])                    # the garbage page is never owned
    with pytest.raises(ValueError, match="bad page id"):
        a.free([5])
    with pytest.raises(ValueError, match="need >= 2 pages"):
        PageAllocator(1)


# ---------------------------------------------------------------------------
# parity contract
# ---------------------------------------------------------------------------
def test_single_slot_bitwise_vs_contiguous_engine(cfg, model, bank):
    """A num_slots=1 paged engine IS the contiguous engine, bit for bit:
    same tokens, same per-token BMA logits, page indirection invisible."""
    ref = DecodeEngine(model=model, params=bank, max_seq=32,
                       return_logits=True)
    eng = fresh(model, bank, num_slots=1, return_logits=True)
    toks, budgets = prompts_and_budgets(cfg, n=3)
    for t, n in zip(toks, budgets):
        want = ref.generate(t[None], n)
        rid = eng.submit(Request(tokens=t, max_new_tokens=n))
        got = {c.request_id: c for c in eng.drain()}[rid]
        assert np.array_equal(got.tokens, want.tokens[0])
        assert np.array_equal(got.logits, want.logits[0])
        assert got.finish_reason == "length"


def test_slot_occupancy_invariance(cfg, model, bank):
    """A request's tokens and logits are bitwise-identical whether it runs
    alone in the 4-slot engine or packed in with five neighbours — garbage
    writes from idle slots and physical page placement never leak in."""
    toks, budgets = prompts_and_budgets(cfg)
    solo_eng = fresh(model, bank, return_logits=True)
    solo = []
    for t, n in zip(toks, budgets):
        r = solo_eng.submit(Request(tokens=t, max_new_tokens=n))
        solo.append({c.request_id: c for c in solo_eng.drain()}[r])

    busy = fresh(model, bank, return_logits=True)
    ids = [busy.submit(Request(tokens=t, max_new_tokens=n))
           for t, n in zip(toks, budgets)]
    comps = {c.request_id: c for c in busy.drain()}
    for rid, s in zip(ids, solo):
        assert np.array_equal(comps[rid].tokens, s.tokens)
        assert np.array_equal(comps[rid].logits, s.logits)
        assert len(comps[rid].tokens) == len(s.tokens)


def test_fused_paged_engine_matches_unfused(cfg, model, bank):
    """fused=True swaps the step attention inner loop for the Pallas paged
    kernel: same tokens, BMA logits equal to the unfused engine."""
    toks, budgets = prompts_and_budgets(cfg)
    plain = fresh(model, bank, return_logits=True)
    fused = fresh(model, bank, fused=True, return_logits=True)
    ids_p = [plain.submit(Request(tokens=t, max_new_tokens=n))
             for t, n in zip(toks, budgets)]
    ids_f = [fused.submit(Request(tokens=t, max_new_tokens=n))
             for t, n in zip(toks, budgets)]
    a = {c.request_id: c for c in plain.drain()}
    b = {c.request_id: c for c in fused.drain()}
    for rp, rf in zip(ids_p, ids_f):
        assert np.array_equal(a[rp].tokens, b[rf].tokens)
        np.testing.assert_allclose(a[rp].logits, b[rf].logits, atol=1e-5)


# ---------------------------------------------------------------------------
# scheduler: admission, eviction, determinism
# ---------------------------------------------------------------------------
def test_admission_on_finish_not_batch_boundary(cfg, model, bank):
    """With 2 slots and 3 requests, the third is prefilled the moment the
    first finishes — mid-stream, while the second is still decoding."""
    eng = fresh(model, bank, num_slots=2, decode_chunk=2)
    toks, _ = prompts_and_budgets(cfg, seed=3)
    ids = [eng.submit(Request(tokens=t, max_new_tokens=n))
           for t, n in zip(toks[:3], (2, 8, 6))]
    out1 = eng.step()  # admits the first two; one chunk retires request 0
    assert [c.request_id for c in out1] == [ids[0]]
    assert eng.num_active == 2     # request 2 took the freed slot already
    assert eng.num_waiting == 0
    comps = {c.request_id: c for c in eng.drain()}
    assert set(comps) == set(ids[1:])
    # replaying each solo through an identical engine is bitwise-equal
    ref = fresh(model, bank, num_slots=2, decode_chunk=2)
    for rid, (t, n) in zip(ids, zip(toks[:3], (2, 8, 6))):
        r = ref.submit(Request(tokens=t, max_new_tokens=n))
        want = {c.request_id: c for c in ref.drain()}[r]
        got = comps.get(rid, out1[0])
        assert np.array_equal(got.tokens, want.tokens)


def test_priority_eviction_replays_victim_bitwise(cfg, model, bank):
    """A higher-priority arrival preempts the running low-priority request;
    the victim requeues and — thanks to position-folded keys — replays the
    exact same tokens it would have produced undisturbed."""
    ref = DecodeEngine(model=model, params=bank, max_seq=32)
    eng = fresh(model, bank, num_slots=1, decode_chunk=2)
    toks, _ = prompts_and_budgets(cfg, seed=7)
    tl, th = toks[0], toks[1]
    rl = eng.submit(Request(tokens=tl, max_new_tokens=8, priority=0))
    eng.step()  # low admitted, two tokens in flight
    rh = eng.submit(Request(tokens=th, max_new_tokens=4, priority=5))
    comps = {c.request_id: c for c in eng.drain()}
    cl, ch = comps[rl], comps[rh]
    # num_slots=1 keeps the step width at the contiguous B=1 shape, so the
    # strong bitwise-vs-contiguous comparison applies to both requests
    assert np.array_equal(cl.tokens, ref.generate(tl[None], 8).tokens[0])
    assert np.array_equal(ch.tokens, ref.generate(th[None], 4).tokens[0])
    assert cl.timing.get("evictions", 0) == 1
    assert "evictions" not in ch.timing or ch.timing["evictions"] == 0
    assert ch.timing["finished"] <= cl.timing["finished"]


def test_sampled_requests_deterministic_per_key_and_in_vocab(cfg, model,
                                                            bank):
    eng = fresh(model, bank)
    toks, _ = prompts_and_budgets(cfg, seed=9)
    t = toks[0]

    def run(seed):
        r = eng.submit(Request(tokens=t, max_new_tokens=8,
                               key=np.asarray(jax.random.PRNGKey(seed),
                                              np.uint32)))
        return {c.request_id: c for c in eng.drain()}[r]

    a, b, c = run(11), run(11), run(12)
    assert np.array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens)
    assert a.tokens.min() >= 0 and a.tokens.max() < cfg.vocab_size


# ---------------------------------------------------------------------------
# trace discipline / allocator hygiene
# ---------------------------------------------------------------------------
def test_one_step_trace_plus_one_prefill_trace_per_rung(cfg, model, bank):
    """Lifetime trace budget: one prefill trace per prompt rung touched,
    ONE step trace total; a warm replay of the whole stream compiles
    nothing and allocates no pad scratch."""
    eng = fresh(model, bank, return_logits=True)
    toks, budgets = prompts_and_budgets(cfg)
    rungs = {1 << (len(t) - 1).bit_length() for t in toks}

    def stream():
        ids = [eng.submit(Request(tokens=t, max_new_tokens=n))
               for t, n in zip(toks, budgets)]
        return ids, eng.drain()

    stream()  # cold: compiles prefill rungs + the step body
    assert eng.num_traces == len(rungs) + 1
    assert eng.num_host_pad_allocs == len(rungs)
    with instrument() as rep:
        _, comps = stream()  # warm replay
    assert rep.num_traces == 0, rep.traces
    assert rep.num_pad_allocs == 0, rep.pad_allocs
    assert len(comps) == len(toks)
    assert eng.num_traces == len(rungs) + 1
    # every page is back in the pool once the stream drains
    assert eng._allocator.free_pages == eng.num_pages - 1
    assert eng.num_active == 0 and eng.num_waiting == 0


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_paged_validation_errors(cfg, model, bank):
    eng = fresh(model, bank)
    t = np.zeros((5,), np.int32)
    with pytest.raises(ValueError, match="1-D prompt"):
        eng.submit(Request(tokens=np.zeros((2, 5), np.int32),
                           max_new_tokens=3))
    with pytest.raises(ValueError, match="max_new_tokens >= 1"):
        eng.submit(Request(tokens=t, max_new_tokens=0))
    with pytest.raises(ValueError, match="overflows"):
        eng.submit(Request(tokens=t, max_new_tokens=30))  # 5 + 30 > 32
    with pytest.raises(ValueError, match="multiple of"):
        fresh(model, bank, max_seq=30)  # 30 % 8 != 0


# ---------------------------------------------------------------------------
# fused Pallas paged step vs oracle
# ---------------------------------------------------------------------------
def test_paged_kernel_bitwise_vs_ref():
    S, H, KV, hd, n_pages, ps, maxp = 3, 4, 2, 16, 7, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (S, H, hd), jnp.bfloat16)
    kn = jax.random.normal(ks[1], (S, KV, hd), jnp.bfloat16)
    vn = jax.random.normal(ks[2], (S, KV, hd), jnp.bfloat16)
    kp = jax.random.normal(ks[3], (n_pages, ps, KV, hd), jnp.bfloat16)
    vp = jax.random.normal(ks[4], (n_pages, ps, KV, hd), jnp.bfloat16)
    tables = jnp.asarray([[1, 4, 0], [2, 0, 0], [3, 5, 6]], jnp.int32)
    pos = jnp.asarray([5, 2, 9], jnp.int32)
    o, ko, vo = fused_paged_decode_step(q, kn, vn, kp, vp, tables, pos)
    ro, rk, rv = paged_decode_step_ref(q.reshape(S, KV, H // KV, hd), kn, vn,
                                       kp, vp, tables, pos)
    assert np.array_equal(np.asarray(o, jnp.float32),
                          np.asarray(ro.reshape(S, H, hd), jnp.float32))
    assert np.array_equal(np.asarray(ko), np.asarray(rk))
    assert np.array_equal(np.asarray(vo), np.asarray(rv))
    # each slot's new row landed in its own mapped page at pos % page_size
    for s, (p, off) in enumerate([(4, 1), (2, 2), (6, 1)]):
        assert np.array_equal(np.asarray(ko[p, off]), np.asarray(kn[s])), s


def test_paged_kernel_chain_batched_bitwise():
    """Chain axis via vmap (pallas batching rule): each chain's output must
    equal its own single-call kernel run bitwise."""
    Cc, S, H, KV, hd, n_pages, ps = 3, 2, 4, 2, 8, 5, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (Cc, S, H, hd), jnp.bfloat16)
    kn = jax.random.normal(ks[1], (Cc, S, KV, hd), jnp.bfloat16)
    vn = jax.random.normal(ks[2], (Cc, S, KV, hd), jnp.bfloat16)
    kp = jax.random.normal(ks[3], (Cc, n_pages, ps, KV, hd), jnp.bfloat16)
    vp = jax.random.normal(ks[4], (Cc, n_pages, ps, KV, hd), jnp.bfloat16)
    tables = jnp.asarray([[1, 3], [2, 4]], jnp.int32)
    pos = jnp.asarray([6, 3], jnp.int32)
    out = jax.vmap(lambda a, b, c, d, e: fused_paged_decode_step(
        a, b, c, d, e, tables, pos))(q, kn, vn, kp, vp)
    for c in range(Cc):
        one = fused_paged_decode_step(q[c], kn[c], vn[c], kp[c], vp[c],
                                      tables, pos)
        for got, want in zip(out, one):
            assert np.array_equal(np.asarray(got[c], jnp.float32),
                                  np.asarray(want, jnp.float32)), c


# ---------------------------------------------------------------------------
# sharded paged decode (subprocess: 8 forced host devices, debug mesh)
# ---------------------------------------------------------------------------
SCRIPT_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.cluster import PagedDecodeEngine
from repro.cluster.api import Request
from repro.configs import get_reduced
from repro.launch.mesh import make_debug_mesh
from repro.models.transformer import Model, init_params

cfg = get_reduced("qwen3-4b")
model = Model(cfg, remat=False)
bank = jax.vmap(lambda k: init_params(k, cfg))(
    jax.random.split(jax.random.PRNGKey(0), 8))
rng = np.random.default_rng(0)
reqs = [(rng.integers(0, cfg.vocab_size, (t,), dtype=np.int32), n)
        for t, n in [(5, 6), (3, 4), (7, 5)]]

def run(**kw):
    eng = PagedDecodeEngine(model=model, params=bank, num_slots=2,
                            page_size=8, max_seq=32, decode_chunk=4, **kw)
    ids = [eng.submit(Request(tokens=t, max_new_tokens=n)) for t, n in reqs]
    comps = {c.request_id: c for c in eng.drain()}
    return [comps[r].tokens for r in ids], eng

a, _ = run()
mesh = make_debug_mesh(data=4, model=2)
b, sharded = run(mesh=mesh)
c, _ = run(mesh=mesh, shard_params=True)
print(json.dumps({
    "tokens_bitwise": all(bool(np.array_equal(x, y)) for x, y in zip(a, b)),
    "chain_axis_sharded":
        jax.tree_util.tree_leaves(sharded.params)[0].sharding.spec[0]
        == "data",
    "twod_tokens_equal": all(bool(np.array_equal(x, y))
                             for x, y in zip(a, c)),
}))
"""


@pytest.mark.slow
def test_sharded_paged_decode_matches_single_device():
    """Chain-sharded paged decode (per-token all-gather + replicated BMA)
    streams the same tokens as the single-device engine, and the 2-D
    (chains x tensor-parallel) bank agrees too."""
    from subproc import run_json

    res = run_json(SCRIPT_SHARDED, timeout=900)
    assert res["tokens_bitwise"], res
    assert res["chain_axis_sharded"], res
    assert res["twod_tokens_equal"], res

"""Composable sampler API: preset-vs-legacy parity (all four modes), chain
composability, delay policies, fused-vs-unfused commit, ring wraparound."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.core import Quadratic, constant_delays
from repro.core import delay as delay_lib
from repro.samplers.policies import ConstantDelay, PerCoordinateDelay
from repro.samplers.transforms import noise_like, sgld_apply
from repro.utils import tree_zeros_like

GAMMA = 0.01
SIGMA = 0.5
STEPS = 60


@pytest.fixture(scope="module")
def quad():
    return Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)


def legacy_reference_run(mode, grad, x0, key, gamma, sigma, tau, delays, steps):
    """Verbatim pre-redesign ``SGLDSampler.step`` math (the parity oracle)."""
    ring = (delay_lib.init_ring(x0, tau)
            if mode in ("consistent", "inconsistent") else None)
    pending = tree_zeros_like(x0) if mode == "pipeline" else None
    params = x0
    traj = []
    for k in range(steps):
        key, k_noise, k_delay = jax.random.split(key, 3)
        g_step = jnp.asarray(gamma, jnp.float32)
        scale = jnp.sqrt(2.0 * sigma * g_step)
        noise = noise_like(k_noise, params, scale, jnp.float32)
        d = jnp.asarray(delays[k], jnp.int32)
        if mode == "sync":
            params = sgld_apply(params, grad(params, None), g_step, noise)
        elif mode == "pipeline":
            new_grad = grad(params, None)
            params = sgld_apply(params, pending, g_step, noise)
            pending = new_grad
        else:
            if mode == "consistent":
                x_hat = delay_lib.read_consistent(ring, d)
            else:
                cds = delay_lib.sample_coordinate_delays(k_delay, ring, d)
                x_hat = delay_lib.read_inconsistent(ring, cds)
            params = sgld_apply(params, grad(x_hat, None), g_step, noise)
            ring = delay_lib.push(ring, params)
        traj.append(params)
    return jnp.stack(traj)


def _delays_for(tau, steps):
    if tau:
        return jnp.asarray(constant_delays(tau, steps).delays)
    return jnp.zeros((steps,), jnp.int32)


@pytest.mark.parametrize("mode,tau", [("sync", 0), ("pipeline", 0),
                                      ("consistent", 4), ("inconsistent", 4)])
def test_preset_matches_legacy_sampler(quad, mode, tau):
    """samplers.sgld(mode=...) reproduces the string-dispatched sampler's
    trajectory under a fixed PRNG key (fp32 allclose; the residual is
    jit-vs-eager fusion, not algorithm)."""
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731
    delays = _delays_for(tau, STEPS)
    want = legacy_reference_run(mode, grad, jnp.zeros(4), jax.random.PRNGKey(1),
                                GAMMA, SIGMA, tau, delays, STEPS)
    sampler = samplers.sgld(mode, grad, gamma=GAMMA, sigma=SIGMA, tau=tau)
    state = sampler.init(jnp.zeros(4), jax.random.PRNGKey(1))
    _, got = jax.jit(lambda s: sampler.run(s, jnp.zeros((STEPS, 1)), delays))(state)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-6)


def test_deprecated_shim_delegates_to_presets(quad):
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731
    from repro.core import SGLDConfig, SGLDSampler

    with pytest.warns(DeprecationWarning):
        legacy = SGLDSampler(SGLDConfig(mode="consistent", gamma=GAMMA,
                                        sigma=SIGMA, tau=4), grad)
    new = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA, tau=4)
    delays = _delays_for(4, 30)
    s1 = legacy.init(jnp.zeros(4), jax.random.PRNGKey(2))
    s2 = new.init(jnp.zeros(4), jax.random.PRNGKey(2))
    _, t1 = jax.jit(lambda s: legacy.run(s, jnp.zeros((30, 1)), delays))(s1)
    _, t2 = jax.jit(lambda s: new.run(s, jnp.zeros((30, 1)), delays))(s2)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_chain_composes_to_gradient_descent(quad):
    """With the noise stage omitted, the chain is plain delayed GD."""
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731
    sampler = samplers.Sampler(
        samplers.chain(samplers.gradients(grad), samplers.apply_sgld_update()),
        gamma=0.05)
    state = sampler.init(jnp.ones(4) * 3.0, jax.random.PRNGKey(3))
    x = jnp.ones(4) * 3.0
    for _ in range(20):
        state, _ = sampler.step(state, None)
        x = x - 0.05 * grad(x, None)
    np.testing.assert_allclose(np.asarray(state.params), np.asarray(x),
                               rtol=1e-6)


def test_constant_delay_policy_equals_warmup_trace(quad):
    """ConstantDelay(tau) == TraceDelay fed the constant_delays warm-up
    trace (staleness can't exceed the commit count)."""
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731
    tau, steps = 3, 25
    by_policy = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA,
                              tau=tau, delay_policy=ConstantDelay(tau))
    by_trace = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA,
                             tau=tau)
    delays = jnp.asarray(constant_delays(tau, steps).delays)
    s1 = by_policy.init(jnp.zeros(4), jax.random.PRNGKey(4))
    s2 = by_trace.init(jnp.zeros(4), jax.random.PRNGKey(4))
    _, t1 = jax.jit(lambda s: by_policy.run(s, jnp.zeros((steps, 1))))(s1)
    _, t2 = jax.jit(lambda s: by_trace.run(s, jnp.zeros((steps, 1)), delays))(s2)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_per_coordinate_policy_fused_gather_matches_reference(quad):
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731
    delays = _delays_for(4, 20)
    ref = samplers.sgld("inconsistent", grad, gamma=GAMMA, sigma=SIGMA, tau=4)
    fused = samplers.sgld("inconsistent", grad, gamma=GAMMA, sigma=SIGMA,
                          tau=4,
                          delay_policy=PerCoordinateDelay(4, fused=True))
    s1 = ref.init(jnp.zeros(4), jax.random.PRNGKey(5))
    s2 = fused.init(jnp.zeros(4), jax.random.PRNGKey(5))
    _, t1 = jax.jit(lambda s: ref.run(s, jnp.zeros((20, 1)), delays))(s1)
    _, t2 = jax.jit(lambda s: fused.run(s, jnp.zeros((20, 1)), delays))(s2)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


# ---------------------------------------------------------------------------
# fused Pallas commit vs unfused reference
# ---------------------------------------------------------------------------
def test_fused_update_equals_apply_update_at_zero_temperature(quad):
    """With sigma=0 both commit paths are x - gamma*g exactly."""
    grad = lambda p, b: quad.grad(p, b)  # noqa: E731
    ref = samplers.sgld("sync", grad, gamma=0.05, sigma=0.0)
    fus = samplers.sgld("sync", grad, gamma=0.05, sigma=0.0, fused=True)
    s1 = ref.init(jnp.ones(4), jax.random.PRNGKey(6))
    s2 = fus.init(jnp.ones(4), jax.random.PRNGKey(6))
    _, t1 = jax.jit(lambda s: ref.run(s, jnp.zeros((10, 1))))(s1)
    _, t2 = jax.jit(lambda s: fus.run(s, jnp.zeros((10, 1))))(s2)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2),
                               rtol=1e-6, atol=1e-7)


def test_fused_update_noise_statistics():
    """At scale=1 the fused kernel's VMEM-generated noise is standard normal."""
    params = {"w": jnp.zeros((40_000,)), "b": jnp.zeros((300,))}
    grad = lambda p, b: tree_zeros_like(p)  # noqa: E731
    # sqrt(2 * sigma * gamma) = 1
    sampler = samplers.sgld("sync", grad, gamma=1.0, sigma=0.5, fused=True)
    state = sampler.init(params, jax.random.PRNGKey(7))
    state, _ = jax.jit(sampler.step)(state, None, 0)
    z = np.concatenate([np.asarray(x).ravel()
                        for x in jax.tree_util.tree_leaves(state.params)])
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02


# ---------------------------------------------------------------------------
# ring buffer wraparound (satellite)
# ---------------------------------------------------------------------------
def test_ring_wraparound_after_more_than_depth_pushes():
    """After depth+k pushes the ring holds exactly the last ``depth``
    snapshots, reads walk them newest-to-oldest, and older snapshots are
    gone (overwritten in place)."""
    params = {"w": jnp.zeros((2,))}
    tau = 2  # depth = 3
    ring = delay_lib.init_ring(params, tau=tau)
    n_push = 2 * ring.depth + 1  # 7: wraps the ring twice
    for k in range(1, n_push + 1):
        ring = delay_lib.push(ring, {"w": jnp.full((2,), float(k))})
    for d in range(ring.depth):
        got = float(delay_lib.read_consistent(ring, d)["w"][0])
        assert got == float(n_push - d), (d, got)
    # beyond-depth delays clamp to the oldest retained snapshot
    assert float(delay_lib.read_consistent(ring, 99)["w"][0]) == float(
        n_push - tau)
    # every retained slot is one of the last `depth` pushes — nothing older
    vals = set(np.asarray(ring.history["w"])[:, 0].tolist())
    assert vals == {float(v) for v in range(n_push - tau, n_push + 1)}
    # head keeps cycling: another full wrap lands on the same slot index
    head_before = int(ring.head)
    for k in range(ring.depth):
        ring = delay_lib.push(ring, {"w": jnp.full((2,), 100.0 + k)})
    assert int(ring.head) == head_before

"""Flash attention vs naive oracle: forward, backward, windows, GQA, decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    decode_attention,
    flash_attention,
    naive_attention,
)


def _qkv(key, B, S, H, KV, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, KV, hd)),
            jax.random.normal(ks[2], (B, S, KV, hd)))


@given(seed=st.integers(0, 50), window=st.sampled_from([None, 32, 64]),
       gqa=st.sampled_from([1, 2, 4]))
@settings(max_examples=12, deadline=None)
def test_flash_forward_matches_naive(seed, window, gqa):
    B, S, KV, hd = 2, 128, 2, 16
    H = KV * gqa
    q, k, v = _qkv(jax.random.PRNGKey(seed), B, S, H, KV, hd)
    o1 = naive_attention(q, k, v, causal=True, window=window)
    o2 = flash_attention(q, k, v, True, window, 32, 32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [None, 64])
def test_flash_gradients_match_naive(window):
    B, S, H, KV, hd = 2, 256, 8, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, hd)
    t = jax.random.normal(jax.random.PRNGKey(9), (B, S, H, hd))

    def loss(att):
        def f(q, k, v):
            return jnp.sum(att(q, k, v) * t)
        return f

    f_naive = loss(lambda q, k, v: naive_attention(
        q, k, v, causal=True, window=window))
    f_flash = loss(lambda q, k, v: flash_attention(
        q, k, v, True, window, 64, 64))
    g1 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        scale = np.abs(np.asarray(a)).max() + 1e-6
        np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                                   atol=5e-5)


def test_decode_matches_last_row_of_full_attention():
    """Decoding position S-1 against a full cache == last row of causal
    attention over the full sequence."""
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, H, KV, hd)
    full = naive_attention(q, k, v, causal=True)
    pos = jnp.arange(S)
    got = decode_attention(q[:, -1:], k, v, pos, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(got),
                               atol=2e-5, rtol=1e-4)


def test_decode_window_masks_old_positions():
    B, S, H, KV, hd = 1, 64, 2, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, H, KV, hd)
    pos = jnp.arange(S)
    w = 16
    got = decode_attention(q[:, -1:], k, v, pos, jnp.int32(S - 1), window=w)
    want = naive_attention(q, k, v, causal=True, window=w)[:, -1:]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_ring_cache_decode_equivalence():
    """A rolled (ring) cache with position bookkeeping gives the same answer
    as the dense cache for sliding-window decode."""
    B, H, KV, hd, w = 1, 2, 2, 8, 16
    S = 48
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, H, KV, hd)
    # dense reference
    want = naive_attention(q, k, v, causal=True, window=w)[:, -1:]
    # ring cache of size w holding the last w positions
    slots = [(p % w) for p in range(S)]
    k_ring = jnp.zeros((B, w, KV, hd))
    v_ring = jnp.zeros((B, w, KV, hd))
    pos_ring = -jnp.ones((w,), jnp.int32)
    for p in range(S):
        k_ring = k_ring.at[:, slots[p]].set(k[:, p])
        v_ring = v_ring.at[:, slots[p]].set(v[:, p])
        pos_ring = pos_ring.at[slots[p]].set(p)
    got = decode_attention(q[:, -1:], k_ring, v_ring, pos_ring,
                           jnp.int32(S - 1), window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)

"""Docs can't rot: every fenced ``python run`` block in docs/*.md and
README.md is extracted and executed (tiny shapes, CPU-friendly).

The convention: open a fence with ```` ```python run ```` to mark a block
runnable.  Plain ```` ```python ```` blocks are illustrative (they may
reference undefined names like a trained `engine`) and are not executed —
but every runnable block must be self-contained: its own imports, its own
tiny data.
"""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(ROOT.glob("docs/*.md")) + [ROOT / "README.md"]

FENCE = re.compile(r"^```python run\s*$\n(.*?)^```\s*$", re.M | re.S)


def _blocks():
    found = []
    for path in DOC_FILES:
        text = path.read_text()
        for i, m in enumerate(FENCE.finditer(text)):
            line = text[: m.start()].count("\n") + 2  # first code line
            found.append(pytest.param(
                m.group(1), id=f"{path.name}:{line}#block{i}"))
    return found


_ALL = _blocks()


def test_docs_have_runnable_blocks():
    """The convention stays exercised: at least the THEORY and SAMPLERS
    pages carry runnable examples."""
    names = {p.id.split(":")[0] for p in _ALL}
    assert "THEORY.md" in names
    assert "SAMPLERS.md" in names
    assert "README.md" in names


@pytest.mark.parametrize("source", _ALL)
def test_doc_block_runs(source):
    exec(compile(source, "<doc-block>", "exec"), {"__name__": "__docs__"})

"""repro.faults: deterministic fault injection and the self-healing story.

The robustness contract, pinned:

- chaos schedules are *structurally* gated: a zero-rate :class:`FaultPlan`
  realizes the bitwise-identical trace to no plan at all, and a fault-free
  engine run compiles the exact pre-fault program (``health_check`` off,
  no ``alive``/``poison`` operands threaded);
- dead commits are masked no-ops on device: the chain's iterate freezes,
  its commit counter still ticks (the version slot burns), and the whole
  chaos run stays one scan trace;
- a NaN'd chain is quarantined sticky on device, excluded from W2 /
  R-hat / ESS, respawned from a healthy donor at a chunk boundary, and a
  partially-quarantined bank serves a degraded BMA (all-quarantined
  raises);
- checkpoint/resume stitches bitwise — including across a SIGKILL — and a
  truncated or bit-flipped checkpoint raises
  :class:`CorruptCheckpointError` naming the damage;
- serving degrades instead of stalling: ``max_waiting`` backpressure
  rejects, expired waiting requests are shed, expired active slots are
  cut short with the partial prefix.
"""

import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.cluster import (
    ClusterEngine,
    HealthState,
    PagedDecodeEngine,
    ServeEngine,
    WorkerSchedule,
    diagnostics_recorder,
    ensemble_async,
    healthy_chains,
    w2_recorder,
)
from repro.cluster.api import (
    FINISH_DEADLINE,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    QueueFullError,
    Request,
)
from repro.core import Quadratic, WorkerModel, simulate_async
from repro.faults import FaultPlan, nan_storm
from repro.obs.timeline import cluster_timeline

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
C, STEPS, TAU = 8, 37, 8
CHAOS = FaultPlan(crash_rate=0.15, mean_downtime=2.0,
                  pause_rate=0.1, mean_pause=1.0)


@pytest.fixture(scope="module")
def quad():
    return Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)


@pytest.fixture(scope="module")
def quad_sampler(quad):
    return samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                         gamma=0.01, sigma=0.5, tau=TAU)


@pytest.fixture(scope="module")
def deep_sampler(quad):
    # crashed workers rejoin with much staler reads than a healthy pool
    # ever produces; chaos runs need a deeper iterate ring
    return samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                         gamma=0.01, sigma=0.5, tau=32)


def chaos_schedules(steps=STEPS, chains=C, seed=0):
    wm = WorkerModel(num_workers=4, seed=1, faults=CHAOS)
    return ensemble_async(wm, steps, chains, seed=seed)


# ---------------------------------------------------------------------------
# chaos schedules: simulation + structural gating
# ---------------------------------------------------------------------------
def test_zero_rate_fault_plan_is_bitwise_noop():
    """The fault RNG is a dedicated salted stream, so merely *attaching* an
    inert plan must not perturb a single drawn time or delay."""
    wm0 = WorkerModel(num_workers=4, seed=2)
    wm1 = WorkerModel(num_workers=4, seed=2, faults=FaultPlan())
    a = simulate_async(wm0, 200, seed=5)
    b = simulate_async(wm1, 200, seed=5)
    np.testing.assert_array_equal(a.delays, b.delays)
    np.testing.assert_array_equal(a.commit_times, b.commit_times)
    np.testing.assert_array_equal(a.worker_ids, b.worker_ids)
    assert b.alive is None and b.num_lost == 0
    assert not FaultPlan().active and CHAOS.active


def test_chaos_trace_loses_commits_and_roundtrips():
    wm = WorkerModel(num_workers=4, seed=2, faults=CHAOS)
    tr = simulate_async(wm, 200, seed=5)
    assert tr.alive is not None and 0 < tr.num_lost < 200
    # crashes burn version slots: delays stay the arange-minus-read identity
    sched = WorkerSchedule.from_trace(tr)
    np.testing.assert_array_equal(sched.alive, tr.alive)
    np.testing.assert_array_equal(sched.to_trace().alive, tr.alive)
    np.testing.assert_array_equal(
        sched.delays, np.arange(200) - sched.read_versions)
    # commit times stay sorted even across downtime/rejoin events
    assert np.all(np.diff(tr.commit_times) >= 0)


def test_fault_plan_validates():
    with pytest.raises(ValueError):
        FaultPlan(crash_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(pause_rate=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(crash_rate=0.1, mean_downtime=-1.0)


def test_nan_storm_deterministic_and_validated():
    a = nan_storm(40, 8, rate=0.1, seed=3)
    b = nan_storm(40, 8, rate=0.1, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (40, 8) and a.dtype == bool and a.any()
    assert not nan_storm(40, 8, rate=0.0).any()
    with pytest.raises(ValueError):
        nan_storm(10, 2, rate=1.5)


def test_timeline_annotates_lost_commits():
    tr = simulate_async(WorkerModel(num_workers=4, seed=2, faults=CHAOS),
                        120, seed=5)
    sched = WorkerSchedule.from_trace(tr)
    events = cluster_timeline(sched)["traceEvents"]
    lost = [e for e in events if e.get("name") == "commit (lost)"]
    live = [e for e in events if e.get("name") == "commit"]
    assert len(lost) == tr.num_lost
    assert len(live) == 120 - tr.num_lost
    assert all(e["args"]["lost"] for e in lost)


# ---------------------------------------------------------------------------
# executor: dead commits as masked no-ops, zero-fault bitwise pinning
# ---------------------------------------------------------------------------
def test_dead_commits_freeze_iterate_but_burn_version_slots(quad_sampler):
    """A chain whose every commit is lost keeps its init params bit-for-bit
    while its commit counter ticks to STEPS — and the masked program is
    still one trace."""
    fresh_reads = np.arange(STEPS)
    dead = WorkerSchedule(read_versions=fresh_reads,
                          worker_ids=np.zeros(STEPS, np.int64),
                          commit_times=np.arange(STEPS, dtype=np.float64),
                          num_workers=1, alive=np.zeros(STEPS, bool))
    live = WorkerSchedule(read_versions=fresh_reads,
                          worker_ids=np.zeros(STEPS, np.int64),
                          commit_times=np.arange(STEPS, dtype=np.float64),
                          num_workers=1)
    engine = ClusterEngine(quad_sampler, num_chains=2, chunk_size=10)
    state = engine.init(jnp.ones(4), jax.random.PRNGKey(0))
    p0 = np.asarray(state.params)
    out, _ = engine.run(state, steps=30, schedule=[dead, live])
    assert np.array_equal(np.asarray(out.params[0]), p0[0])  # frozen
    assert not np.array_equal(np.asarray(out.params[1]), p0[1])  # moved
    assert np.all(np.asarray(out.step) == 30)  # slots burn regardless
    assert engine.num_traces == 1


def test_health_check_without_faults_is_bitwise_identical(quad_sampler):
    """The acceptance pin: a zero-fault configuration must produce the
    exact trajectory of the pre-fault engine — health masking composes via
    ``where(keep, new, old)`` with keep always True, and quarantine never
    triggers."""
    sched = ensemble_async(WorkerModel(num_workers=4, seed=1), 30, C,
                           seed=0)
    plain = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10)
    state = plain.init(jnp.zeros(4), jax.random.PRNGKey(42))
    ref, _ = plain.run(state, steps=30, schedule=sched)

    guarded = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10,
                            health_check=True)
    state = guarded.init(jnp.zeros(4), jax.random.PRNGKey(42))
    out, _ = guarded.run(state, steps=30, schedule=sched)
    assert isinstance(out, HealthState)
    assert np.asarray(out.health).all()
    assert np.array_equal(np.asarray(out.params), np.asarray(ref.params))
    assert np.array_equal(np.asarray(out.key), np.asarray(ref.key))
    assert guarded.num_traces == 1


def test_chaos_run_stays_single_trace_and_finite(deep_sampler):
    engine = ClusterEngine(deep_sampler, num_chains=C, chunk_size=10,
                           health_check=True)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(3))
    out, _ = engine.run(state, steps=60, schedule=chaos_schedules(60))
    assert np.isfinite(np.asarray(out.params)).all()
    assert np.all(np.asarray(out.step) == 60)
    assert engine.num_traces == 1


# ---------------------------------------------------------------------------
# quarantine + respawn
# ---------------------------------------------------------------------------
def test_poison_quarantines_then_respawns(quad_sampler):
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10,
                           health_check=True)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(1))
    poison = np.zeros((30, C), bool)
    poison[5, 2] = poison[5, 5] = True
    out, _ = engine.run(state, steps=30, poison=poison)
    assert isinstance(out, HealthState)
    assert np.asarray(out.health).all()  # respawned at a chunk boundary
    assert np.isfinite(np.asarray(out.params)).all()
    # respawned chains got fresh fold_in keys: they decorrelate from donors
    p = np.asarray(out.params)
    assert not np.array_equal(p[2], p[0]) and not np.array_equal(p[5], p[1])
    assert engine.num_traces == 1


def test_quarantine_without_respawn_is_sticky(quad_sampler):
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10,
                           health_check=True, respawn=False)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(1))
    poison = np.zeros((30, C), bool)
    poison[5, 2] = poison[5, 5] = True
    out, _ = engine.run(state, steps=30, poison=poison)
    health = np.asarray(out.health)
    assert not health[2] and not health[5] and health.sum() == C - 2
    # the quarantined chains froze at their last healthy iterate: finite
    assert np.isfinite(np.asarray(out.params)).all()


def test_recorders_mask_unhealthy_chains(quad_sampler):
    """W2 / R-hat / ESS stay finite while a quarantined chain rides along
    in the carry — the reductions drop it instead of going NaN."""
    target = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (256, 4)))
    w2 = w2_recorder(jnp.asarray(target), every=5)
    diag = diagnostics_recorder(every=1, window=8)
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=5,
                           health_check=True, respawn=False,
                           hooks=[w2, diag])
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(1))
    poison = np.zeros((40, C), bool)
    poison[3, 1] = True
    out, _ = engine.run(state, steps=40, poison=poison)
    assert not np.asarray(out.health)[1]
    assert len(w2.record) > 0 and len(diag.record) > 0
    assert all(np.isfinite(r["w2"]) for r in w2.record)
    assert all(np.isfinite(r["rhat_max"]) and np.isfinite(r["ess_min"])
               for r in diag.record)
    mask = healthy_chains(np.asarray(out.params), out)
    assert not mask[1] and mask.sum() == C - 1


def test_degraded_serving_drops_quarantined_chains(quad_sampler):
    state = ClusterEngine(quad_sampler, num_chains=4,
                          chunk_size=5).init(jnp.zeros(4),
                                             jax.random.PRNGKey(0))
    bad = state.params.at[1].set(jnp.nan)
    hs = HealthState(state._replace(params=bad),
                     jnp.array([True, False, True, True]))
    eng = ServeEngine.from_cluster(hs, lambda p, x: x @ p)
    assert eng.num_chains == 3  # chain 1 dropped from the bank
    assert np.isfinite(np.asarray(eng.params)).all()
    with pytest.raises(ValueError, match="every chain is quarantined"):
        ServeEngine.from_cluster(
            HealthState(state, jnp.zeros(4, bool)), lambda p, x: x @ p)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------
def test_resume_stitches_bitwise(deep_sampler, tmp_path):
    sched = chaos_schedules(40)
    poison = nan_storm(40, C, rate=0.01, seed=7)

    def engine():
        return ClusterEngine(deep_sampler, num_chains=C, chunk_size=10,
                             health_check=True)

    full_eng = engine()
    state = full_eng.init(jnp.zeros(4), jax.random.PRNGKey(6))
    full, _ = full_eng.run(state, steps=40, schedule=sched, poison=poison)

    ck = str(tmp_path / "run.npz")
    part_eng = engine()
    state = part_eng.init(jnp.zeros(4), jax.random.PRNGKey(6))
    part_eng.run(state, steps=20, schedule=sched, poison=poison[:20],
                 checkpoint_path=ck)
    # the interrupted run above only knew the first 20 commits; resume
    # replays the *full* call and stitches from the newest checkpoint
    res_eng = engine()
    state = res_eng.init(jnp.zeros(4), jax.random.PRNGKey(6))
    out, _ = res_eng.resume(ck, state, steps=40, schedule=sched,
                            poison=poison)
    for a, b in zip(jax.tree_util.tree_leaves(full),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_with_missing_file_starts_fresh(quad_sampler, tmp_path):
    ck = str(tmp_path / "never_written.npz")
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(0))
    out, _ = engine.resume(ck, state, steps=20)
    assert np.all(np.asarray(out.step) == 20)
    assert os.path.exists(ck)  # the fresh run checkpointed to the same path


def test_corrupt_checkpoint_raises_loudly(quad_sampler, tmp_path):
    from repro.checkpoint import CorruptCheckpointError, save_checkpoint

    ck = str(tmp_path / "ck.npz")
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(0))
    engine.run(state, steps=20, checkpoint_path=ck)

    truncated = str(tmp_path / "trunc.npz")
    blob = open(ck, "rb").read()
    open(truncated, "wb").write(blob[:len(blob) // 2])
    with pytest.raises(CorruptCheckpointError):
        engine.resume(truncated, state, steps=40)

    flipped = str(tmp_path / "flip.npz")
    corrupt = bytearray(blob)
    corrupt[len(corrupt) // 2] ^= 0xFF  # bit-flip mid-archive
    open(flipped, "wb").write(bytes(corrupt))
    with pytest.raises(CorruptCheckpointError):
        engine.resume(flipped, state, steps=40)

    # legacy checkpoints (no CRC manifest) still load
    legacy = str(tmp_path / "legacy.npz")
    save_checkpoint(legacy, {"x": np.arange(4.0)})
    from repro.checkpoint import restore_checkpoint

    got = restore_checkpoint(legacy, {"x": np.zeros(4)})
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(4.0))


_KILL_SCRIPT = r"""
import os, signal
import jax, jax.numpy as jnp, numpy as np
from repro import samplers
from repro.cluster import ClusterEngine
from repro.core import Quadratic

quad = Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)
sampler = samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                        gamma=0.01, sigma=0.5, tau=8)
kills = [3]
def killer(done, state, aux):
    kills[0] -= 1
    if kills[0] == 0:
        os.kill(os.getpid(), signal.SIGKILL)  # no atexit, no cleanup
engine = ClusterEngine(sampler, num_chains=8, chunk_size=10,
                       health_check=True, hooks=[killer])
state = engine.init(jnp.zeros(4), jax.random.PRNGKey(6))
engine.run(state, steps=60, checkpoint_path=CKPT)
"""


@pytest.mark.slow
def test_resume_after_sigkill_is_bitwise(quad_sampler, tmp_path):
    """Kill -9 mid-run (after the third chunk's checkpoint), then resume:
    the stitched trajectory equals the uninterrupted one leaf-exact."""
    ck = str(tmp_path / "killed.npz")
    script = f"CKPT = {ck!r}\n" + _KILL_SCRIPT
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == -signal.SIGKILL, proc.stderr[-2000:]
    assert os.path.exists(ck)  # at least one atomic checkpoint landed

    engine = ClusterEngine(quad_sampler, num_chains=8, chunk_size=10,
                           health_check=True)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(6))
    out, _ = engine.resume(ck, state, steps=60)

    ref_eng = ClusterEngine(quad_sampler, num_chains=8, chunk_size=10,
                            health_check=True)
    state = ref_eng.init(jnp.zeros(4), jax.random.PRNGKey(6))
    ref, _ = ref_eng.run(state, steps=60)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serving degradation: backpressure + deadlines
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def paged():
    from repro.configs import get_reduced
    from repro.models.transformer import Model, init_params

    cfg = get_reduced("qwen3-4b")
    model = Model(cfg, remat=False)
    bank = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), 2))
    return cfg, model, bank


def _mk(cfg, t=4, n=5, **kw):
    rng = np.random.default_rng(t * 31 + n)
    return Request(tokens=rng.integers(0, cfg.vocab_size, (t,),
                                       dtype=np.int32),
                   max_new_tokens=n, **kw)


def test_max_waiting_backpressure(paged):
    cfg, model, bank = paged
    eng = PagedDecodeEngine(model=model, params=bank, num_slots=2,
                            page_size=8, max_seq=32, decode_chunk=4,
                            max_waiting=3)
    for _ in range(3):
        eng.submit(_mk(cfg))
    with pytest.raises(QueueFullError, match="max_waiting"):
        eng.submit(_mk(cfg))
    out = eng.drain()  # draining frees the queue again
    assert len(out) == 3 and all(c.status == STATUS_OK for c in out)
    eng.submit(_mk(cfg))
    eng.drain()


def test_deadline_sheds_waiting_requests(paged):
    """deadline_ms=0 expires at submission: the request is shed with empty
    tokens before any prefill is spent on it; a generous deadline rides
    along untouched."""
    cfg, model, bank = paged
    eng = PagedDecodeEngine(model=model, params=bank, num_slots=2,
                            page_size=8, max_seq=32, decode_chunk=4)
    doomed = eng.submit(_mk(cfg, deadline_ms=0.0))
    fine = eng.submit(_mk(cfg, deadline_ms=1e9))
    comps = {c.request_id: c for c in eng.drain()}
    assert comps[doomed].status == STATUS_SHED
    assert comps[doomed].finish_reason == FINISH_DEADLINE
    assert comps[doomed].tokens.size == 0
    assert comps[fine].status == STATUS_OK and comps[fine].tokens.size == 5


def test_deadline_cuts_short_active_requests(paged):
    """A deadline expiring mid-decode returns the partial prefix with
    STATUS_TIMEOUT instead of convoying the other slots."""
    cfg, model, bank = paged
    eng = PagedDecodeEngine(model=model, params=bank, num_slots=2,
                            page_size=8, max_seq=32, decode_chunk=4)
    r = _mk(cfg, n=24)
    rid = eng.submit(r)
    eng.step()  # admitted: prefill token + one chunk
    r.deadline_ms = 0.0  # force expiry while decoding
    comps = {c.request_id: c for c in eng.drain()}
    c = comps[rid]
    assert c.status == STATUS_TIMEOUT and c.finish_reason == FINISH_DEADLINE
    assert 0 < c.tokens.size < 24  # the partial prefix survived
    assert eng.num_active == 0  # the slot and its pages were released


def test_shed_and_timeout_are_observable(paged):
    from repro.obs.metrics import registry

    cfg, model, bank = paged
    eng = PagedDecodeEngine(model=model, params=bank, num_slots=2,
                            page_size=8, max_seq=32, decode_chunk=4)
    shed0 = registry().counter(
        "requests.shed", "requests dropped un-admitted: deadline expired "
        "while waiting").value
    eng.submit(_mk(cfg, deadline_ms=0.0))
    eng.drain()
    assert registry().counter(
        "requests.shed", "requests dropped un-admitted: deadline expired "
        "while waiting").value == shed0 + 1

"""JL002 clean variant: the donated name is rebound by the call itself, so
nothing reads the dead buffer."""

import jax


def _update(state, grad):
    return state - 0.1 * grad


update = jax.jit(_update, donate_argnums=(0,))


def run(state, grad, steps):
    for _ in range(steps):
        state = update(state, grad)
    return state

"""Pragma fixture: every seeded violation here is silenced inline — the
linter must record the findings as suppressed, never as active."""

import jax


def sample(key, shape):
    noise = jax.random.normal(key, shape)
    init = jax.random.uniform(key, shape)  # jaxlint: disable=JL003
    return noise, init


@jax.jit
def loss(err):
    return float(err.sum())  # jaxlint: disable=JL004

"""Seeded JL001 violation: a jitted function fed a loop-varying Python
scalar — every distinct value compiles a new XLA program."""

import jax


@jax.jit
def step(x, n):
    return x * n


def run(batches):
    out = []
    for batch in batches:
        # the unpadded length changes per batch -> one trace per length
        out.append(step(batch, int(batch.shape[0])))
    return out

"""Seeded JL003 violation: one PRNG key consumed by two draws — the noise
and the init are silently identical streams."""

import jax


def sample(key, shape):
    noise = jax.random.normal(key, shape)
    init = jax.random.uniform(key, shape)
    return noise, init

"""JL005 clean variants: the in-place update declares the alias; a
shape-changing kernel (reduction) needs none."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def _reduce_kernel(h_ref, o_ref):
    o_ref[...] = h_ref[...].sum(axis=0)


def double(x):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={0: 0},
    )(x)


def collapse(history):
    depth, n = history.shape
    return pl.pallas_call(
        _reduce_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
    )(history)

"""Seeded JL005 violation: an in-place Pallas update whose output mirrors
the input, without input_output_aliases — XLA double-buffers through HBM."""

import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double(x):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def scaled(x, g):
    rows, lanes = x.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), x.dtype),
    )(x)

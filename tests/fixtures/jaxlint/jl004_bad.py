"""Seeded JL004 violations: host syncs and Python control flow inside
traced code — a jitted loss and a lax.scan body."""

import jax
import numpy as np


@jax.jit
def loss(params, batch):
    err = params - batch
    return float(err.sum())


def trajectory(xs):
    def body(carry, inp):
        if inp > 0:
            carry = carry + inp
        host = np.asarray(carry)
        return carry, host

    return jax.lax.scan(body, 0.0, xs)

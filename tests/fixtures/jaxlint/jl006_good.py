"""JL006 clean variant: every spec axis exists in the mesh."""

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

mesh = jax.make_mesh((8,), ("data",))
bank_sharding = NamedSharding(mesh, P("data"))


def shard_stats(fn, bank):
    mapped = shard_map(fn, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P("data"))
    return mapped(bank)

"""JL003 clean variant: the key is split (or folded) before every draw, the
repo's standard idiom."""

import jax


def sample(key, shape):
    k_noise, k_init = jax.random.split(key)
    noise = jax.random.normal(k_noise, shape)
    init = jax.random.uniform(k_init, shape)
    return noise, init


def per_step(key, step, shape):
    key = jax.random.fold_in(key, step)
    return jax.random.normal(key, shape)

"""Seeded JL002 violation: a buffer handed to XLA under donate_argnums is
read again in the caller after the call."""

import jax


def _update(state, grad):
    return state - 0.1 * grad


update = jax.jit(_update, donate_argnums=(0,))


def run(state, grad):
    new_state = update(state, grad)
    # `state` was donated: its buffer may already hold `new_state`
    drift = state - new_state
    return new_state, drift

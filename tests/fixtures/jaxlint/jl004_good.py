"""JL004 clean variant: values stay on device; data-dependent branches go
through jnp.where, and the host conversion happens in the host driver."""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def loss(params, batch):
    err = params - batch
    return err.sum()


def trajectory(xs):
    def body(carry, inp):
        carry = jnp.where(inp > 0, carry + inp, carry)
        return carry, carry

    return jax.lax.scan(body, 0.0, xs)


def host_driver(params, batch):
    val = loss(params, batch)
    return float(np.asarray(val))

# jaxlint: disable-file=JL003
"""File-wide pragma fixture: JL003 is disabled for the whole file, while
other rules stay live."""

import jax


def sample(key, shape):
    noise = jax.random.normal(key, shape)
    init = jax.random.uniform(key, shape)
    return noise, init

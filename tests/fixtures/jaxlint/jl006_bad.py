"""Seeded JL006 violation: the partition spec names an axis the mesh never
defined — the dimension silently replicates."""

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

mesh = jax.make_mesh((8,), ("data",))
bank_sharding = NamedSharding(mesh, P("model"))


def shard_stats(fn, bank):
    mapped = shard_map(fn, mesh=mesh, in_specs=(P("chains"),),
                       out_specs=P("chains"))
    return mapped(bank)

"""JL001 clean variant: the loop passes device arrays padded to a fixed
bucket, so every iteration reuses one compiled program."""

import jax
import jax.numpy as jnp

BUCKET = 64


@jax.jit
def step(x, n):
    return x * n


def run(batches):
    out = []
    for batch in batches:
        padded = jnp.zeros((BUCKET,), batch.dtype).at[:batch.shape[0]].set(
            batch)
        out.append(step(padded, jnp.asarray(batch.shape[0])))
    return out

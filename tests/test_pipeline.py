"""Host data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher


def test_prefetcher_yields_distinct_batches():
    def batch_fn(key):
        return {"x": jax.random.normal(key, (4, 8))}

    pf = Prefetcher(batch_fn, jax.random.PRNGKey(0))
    try:
        b1 = next(pf)
        b2 = next(pf)
        assert b1["x"].shape == (4, 8)
        assert float(jnp.abs(b1["x"] - b2["x"]).max()) > 0
    finally:
        pf.close()


def test_prefetcher_keeps_up():
    def batch_fn(key):
        return jax.random.randint(key, (16,), 0, 100)

    pf = Prefetcher(batch_fn, jax.random.PRNGKey(1), depth=3)
    try:
        out = [np.asarray(next(pf)) for _ in range(10)]
        assert len(out) == 10
    finally:
        pf.close()

"""Core sampler behaviour: all four modes, stationary statistics, paper
properties (tau robustness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Quadratic,
    SGLDConfig,
    SGLDSampler,
    constant_delays,
)

SIGMA = 0.5
GAMMA = 0.01
N_STEPS = 15_000
BURN = 5_000


@pytest.fixture(scope="module")
def quad():
    return Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)


def _run(quad, mode, tau=0, delays=None, steps=N_STEPS, seed=1):
    cfg = SGLDConfig(mode=mode, gamma=GAMMA, sigma=SIGMA, tau=tau)
    sampler = SGLDSampler(cfg, lambda p, b: quad.grad(p, b))
    state = sampler.init(jnp.zeros(4), jax.random.PRNGKey(seed))
    batches = jnp.zeros((steps, 1))
    if delays is None:
        delays = jnp.zeros((steps,), jnp.int32)
    state, traj = jax.jit(lambda s: sampler.run(s, batches, delays))(state)
    return np.asarray(traj)


@pytest.mark.parametrize("mode,tau", [("sync", 0), ("pipeline", 0),
                                      ("consistent", 4), ("inconsistent", 4)])
def test_stationary_distribution(quad, mode, tau):
    """For quadratic U, Langevin targets N(x*, sigma * A^-1): every read
    model must land near the closed-form moments (paper's core claim —
    delays do not destroy convergence in measure)."""
    delays = jnp.asarray(constant_delays(tau, N_STEPS).delays) if tau else None
    traj = _run(quad, mode, tau=tau, delays=delays)
    samp = traj[BURN:]
    target_var = np.asarray(quad.stationary_cov(SIGMA))
    assert np.allclose(samp.mean(0), np.asarray(quad.x_star), atol=0.15)
    assert np.allclose(samp.var(0), target_var, rtol=0.35)


def test_delay_increases_bias_not_order(quad):
    """Larger tau inflates the W2 error floor polynomially but must not
    diverge (Cor 2.1: same order, worse constants)."""
    errs = []
    for tau in (1, 4, 8):
        delays = jnp.asarray(constant_delays(tau, N_STEPS).delays)
        traj = _run(quad, "consistent", tau=tau, delays=delays)
        m = traj[BURN:].mean(0)
        errs.append(float(np.linalg.norm(m - np.asarray(quad.x_star))))
    assert max(errs) < 0.5  # no divergence even at tau=8
    assert all(np.isfinite(errs))


def test_decreasing_gamma_schedule_converges(quad):
    from repro.core.schedules import poly_decay

    # low temperature: this test checks the schedule mechanics (drift to
    # x*), not the stationary spread — keep estimator noise small
    cfg = SGLDConfig(mode="sync", gamma=poly_decay(0.1, alpha=0.4, t0=10.0),
                     sigma=0.02)
    sampler = SGLDSampler(cfg, lambda p, b: quad.grad(p, b))
    state = sampler.init(jnp.zeros(4) + 5.0, jax.random.PRNGKey(2))
    batches = jnp.zeros((N_STEPS, 1))
    delays = jnp.zeros((N_STEPS,), jnp.int32)
    _, traj = jax.jit(lambda s: sampler.run(s, batches, delays))(state)
    start_err = float(np.linalg.norm(5.0 - np.asarray(quad.x_star)))
    late_err = float(np.linalg.norm(np.asarray(traj[-2000:]).mean(0)
                                    - np.asarray(quad.x_star)))
    assert late_err < 0.4, late_err
    assert late_err < 0.1 * start_err


def test_pipeline_equals_one_step_stale_gradient(quad):
    """pipeline mode is exactly W-Con with tau=1 on the gradient sequence:
    with sigma=0 and constant gamma, params_{k+1} = params_k - g(params_{k-1})."""
    cfg = SGLDConfig(mode="pipeline", gamma=0.1, sigma=0.0)
    sampler = SGLDSampler(cfg, lambda p, b: quad.grad(p, b))
    state = sampler.init(jnp.ones(4), jax.random.PRNGKey(3))
    # manual reference
    x = jnp.ones(4)
    pending = jnp.zeros(4)
    for _ in range(5):
        state, _ = sampler.step(state, None, 0)
        g = quad.grad(x, None)
        x = x - 0.1 * pending
        pending = g
    np.testing.assert_allclose(np.asarray(state.params), np.asarray(x),
                               rtol=1e-5)


def test_aux_metrics_surface(quad):
    def grad_with_aux(p, b):
        return quad.grad(p, b), {"loss": quad.value(p, b)}

    cfg = SGLDConfig(mode="sync", gamma=GAMMA, sigma=SIGMA)
    sampler = SGLDSampler(cfg, grad_with_aux, has_aux=True)
    state = sampler.init(jnp.zeros(4), jax.random.PRNGKey(4))
    state, aux = sampler.step(state, None, 0)
    assert "loss" in aux and np.isfinite(float(aux["loss"]))


def test_sync_variance_reduction_vs_async_small_batch():
    """Paper §3: Sync effectively averages P gradients (larger batch);
    per-update gradient noise must be lower for sync."""
    quad = Quadratic.make(jax.random.PRNGKey(5), d=2, m=1.0, L=1.0,
                          grad_noise=1.0)
    key = jax.random.PRNGKey(6)

    def noisy_grad(p, key):
        return quad.grad(p, None, key=key)

    p0 = jnp.zeros(2)
    keys = jax.random.split(key, 256)
    singles = jnp.stack([noisy_grad(p0, k) for k in keys[:64]])
    summed = jnp.stack([
        jnp.mean(jnp.stack([noisy_grad(p0, k) for k in keys[i:i + 8]]), 0)
        for i in range(0, 256, 8)])
    assert float(summed.var(0).mean()) < float(singles.var(0).mean()) / 4

"""Corollary 2.1 constants: shape of the tau-dependence."""


import pytest

from repro.core import (
    ProblemConstants,
    gamma_eps_kl,
    gamma_eps_w2,
    gamma_terms,
    n_eps_kl,
    n_eps_w2,
)
from repro.core.theory import inconsistent_read_bias


def consts(tau):
    return ProblemConstants(m=1.0, L=3.0, d=10, G=5.0, sigma=0.5, tau=tau,
                            w2sq_0=4.0)


def test_gamma_terms_positive():
    g = gamma_terms(consts(4), eps=0.1)
    assert all(v > 0 for v in g.values())


def test_gamma_shrinks_with_tau():
    eps = 0.1
    gs = [gamma_eps_kl(consts(tau), eps) for tau in (0, 2, 8, 32)]
    assert all(a >= b for a, b in zip(gs, gs[1:]))


def test_n_eps_grows_polynomially_with_tau():
    eps = 0.1
    ns = [n_eps_kl(consts(tau), eps) for tau in (1, 4, 16)]
    assert ns[0] < ns[1] < ns[2]
    # tau enters gamma^1 as tau^2 -> n_eps growth is polynomial, not exp:
    # going tau 4 -> 16 must grow less than (16/4)^4
    assert ns[2] / ns[1] < (16 / 4) ** 4


def test_n_eps_scales_with_inverse_eps():
    n1 = n_eps_kl(consts(2), 0.1)
    n2 = n_eps_kl(consts(2), 0.05)
    assert n2 > 1.5 * n1  # at least ~1/eps^2-ish growth


def test_w2_variant_tighter_stepsize():
    c = consts(4)
    assert gamma_eps_w2(c, 0.1) < gamma_eps_kl(c, 0.1)
    assert n_eps_w2(c, 0.1) > 0


def test_inconsistent_bias_scaling():
    c = consts(8)
    b1 = inconsistent_read_bias(c, 1e-3)
    b2 = inconsistent_read_bias(consts(16), 1e-3)
    assert b2 == pytest.approx(2 * b1)  # linear in tau

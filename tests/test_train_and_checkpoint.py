"""Train-loop substrate: microbatch accumulation, checkpoint roundtrip,
schedules."""

import os
from dataclasses import replace

import jax
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ShapeConfig, get_reduced
from repro.core.schedules import clip_to_theory, constant, poly_decay, wsd
from repro.data import make_batch
from repro.models.transformer import Model, init_params
from repro.train.loop import make_grad_fn


def test_microbatch_accumulation_matches_full_batch():
    cfg = replace(get_reduced("qwen3-4b"), dtype="float32")
    model = Model(cfg, mesh=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    batch = make_batch(cfg, shape, jax.random.PRNGKey(1), "train")
    g1, m1 = make_grad_fn(model, 1)(params, batch)
    g4, m4 = make_grad_fn(model, 4)(params, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = replace(get_reduced("musicgen-medium"), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, step=7)
    restored = restore_checkpoint(path, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    from repro.checkpoint.io import checkpoint_step
    assert checkpoint_step(path) == 7


def test_schedules():
    s = poly_decay(1.0, alpha=0.5)
    assert float(s(0)) == 1.0
    assert float(s(99)) == pytest.approx(0.1, rel=1e-3)
    w = wsd(1.0, warmup_steps=10, stable_steps=100, decay_steps=100)
    assert float(w(0)) == pytest.approx(0.1)
    assert float(w(50)) == pytest.approx(1.0)
    assert float(w(209)) == pytest.approx(0.109, abs=0.02)
    c = clip_to_theory(constant(1.0), 0.25)
    assert float(c(5)) == 0.25


import pytest  # noqa: E402

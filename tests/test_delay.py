"""Ring buffer + delay process properties (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    WorkerModel,
    constant_delays,
    init_ring,
    push,
    read_consistent,
    read_inconsistent,
    sample_coordinate_delays,
    simulate_async,
    simulate_sync,
    speedup_vs_sync,
)


def test_ring_push_and_consistent_read():
    params = {"w": jnp.zeros((3,))}
    ring = init_ring(params, tau=3)
    for k in range(1, 7):
        ring = push(ring, {"w": jnp.full((3,), float(k))})
    # delay 0 -> most recent (6); delay 2 -> 4
    assert float(read_consistent(ring, 0)["w"][0]) == 6.0
    assert float(read_consistent(ring, 2)["w"][0]) == 4.0
    assert float(read_consistent(ring, 3)["w"][0]) == 3.0
    # clamped beyond depth
    assert float(read_consistent(ring, 99)["w"][0]) == 3.0


@given(tau=st.integers(1, 6), delay=st.integers(0, 6), d=st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_inconsistent_read_bounds(tau, delay, d):
    """Every coordinate of the W-Icon read equals SOME snapshot value in the
    admissible window [k-tau, k] (Assumption 2.3)."""
    params = {"w": jnp.zeros((d,))}
    ring = init_ring(params, tau=tau)
    vals = []
    for k in range(1, tau + 2):
        ring = push(ring, {"w": jnp.full((d,), float(k))})
        vals.append(float(k))
    delays = sample_coordinate_delays(jax.random.PRNGKey(0), ring,
                                      jnp.int32(delay))
    x_hat = read_inconsistent(ring, delays)["w"]
    eff = min(delay, tau)
    admissible = set(vals[-(eff + 1):])
    assert set(np.asarray(x_hat).tolist()) <= admissible


def test_async_delays_statistics():
    wm = WorkerModel(num_workers=8, seed=0)
    tr = simulate_async(wm, 4000, seed=0)
    # staleness ~= P-1 on average in steady state
    assert 4.0 < tr.mean_delay < 12.0
    assert tr.delays.min() >= 0
    assert np.all(np.diff(tr.commit_times) >= 0)
    # deterministic given the seed
    tr2 = simulate_async(WorkerModel(num_workers=8, seed=0), 4000, seed=0)
    np.testing.assert_array_equal(tr.delays, tr2.delays)


def test_sync_trace_no_delay_and_slower_rounds():
    wm = WorkerModel(num_workers=16, seed=1)
    ts = simulate_sync(wm, 100, seed=1)
    ta = simulate_async(wm, 1600, seed=1)
    assert ts.delays.max() == 0
    sp = speedup_vs_sync(ta, ts)
    assert sp > 1.0, f"async must beat barrier execution, got {sp}"


def test_constant_delay_warmup():
    tr = constant_delays(5, 100)
    assert tr.delays[0] == 0 and tr.delays[10] == 5
    assert tr.max_delay == 5

"""repro.obs: span tracer, metrics registry, Chrome-trace timeline export.

The observability contract: spans are host-side only (a traced warm decode
stream keeps empty ``stream_flags()`` and jaxlint stays silent on the obs
package), the exported timelines are valid Chrome-trace-event JSON with the
attributes the paper's diagnostics need (per-commit staleness, per-token
slices), and ``log_hook``'s printed format is byte-identical with the
metrics registry wired in.
"""

import json
import os
import re
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.analysis.instrument import instrument
from repro.cluster import DecodeEngine, WorkerSchedule
from repro.configs import get_reduced
from repro.models.transformer import Model, init_params
from repro.obs.metrics import (
    LATENCY_MS_BUCKETS,
    STALENESS_BUCKETS,
    Registry,
    registry,
)
from repro.obs.timeline import (
    cluster_timeline,
    decode_timeline,
    paged_timeline,
    summarize,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.trace import Tracer, span, trace_hook, tracer
from repro.train.engine import log_hook

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_disabled_span_is_shared_noop_context():
    tr = tracer()
    assert not tr.enabled  # global tracer starts disabled
    ctx1, ctx2 = span("a"), span("b", attr=1)
    assert ctx1 is ctx2  # one shared null context, no allocation
    with ctx1 as sp:
        sp.set(ignored=True)  # null span swallows attributes
    assert tr.spans == []


def test_spans_nest_with_parent_links_across_instrument_regions():
    tr = Tracer(enabled=True)
    with instrument():
        with tr.span("outer", level=0) as outer:
            with instrument():  # nested instrument regions don't break spans
                with tr.span("inner", level=1) as inner:
                    pass
            with tr.span("sibling") as sibling:
                pass
    spans = {sp.name: sp for sp in tr.spans}
    assert spans["inner"].parent_id == outer.span_id
    assert spans["sibling"].parent_id == outer.span_id
    assert spans["outer"].parent_id is None
    assert inner.t0 >= outer.t0 and inner.t1 <= spans["outer"].t1
    assert spans["outer"].attrs == {"level": 0}


def test_record_backfills_span_under_live_parent():
    tr = Tracer(enabled=True)
    with tr.span("chunk_loop") as parent:
        tr.record("chunk", 1.0, 2.0, start=0, end=50)
    (rec,) = [sp for sp in tr.spans if sp.name == "chunk"]
    assert rec.parent_id == parent.span_id
    assert (rec.t0, rec.t1) == (1.0, 2.0)
    assert tr.drain() and tr.spans == []  # drain clears the buffer


def test_trace_hook_emits_one_span_per_chunk_boundary():
    tr = Tracer(enabled=True)
    hook = trace_hook(to=tr)
    hook(50, None, None)
    hook(100, None, None)
    spans = tr.spans
    assert [sp.attrs for sp in spans] == [{"start": 0, "end": 50},
                                          {"start": 50, "end": 100}]
    assert spans[0].t1 <= spans[1].t0  # contiguous boundary intervals


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_is_monotone():
    reg = Registry()
    c = reg.counter("x", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_is_idempotent_and_kind_checked():
    reg = Registry()
    assert reg.counter("a") is reg.counter("a")
    with pytest.raises(TypeError):
        reg.gauge("a")


def test_histogram_buckets_and_quantiles():
    reg = Registry()
    h = reg.histogram("lat", (1.0, 10.0, 100.0))
    h.observe_many([0.5, 5.0, 5.0, 50.0, 500.0])
    assert h.counts == [1, 2, 1, 1]  # last bucket is +inf overflow
    assert h.total == 5
    assert h.mean == pytest.approx(112.1)
    assert h.quantile(0.5) == 10.0  # conservative: bucket upper bound
    assert h.quantile(0.99) == float("inf")
    with pytest.raises(ValueError):
        reg.histogram("bad", (3.0, 1.0))


def test_snapshot_is_json_ready_and_omits_nan_gauges():
    reg = Registry()
    reg.counter("c").inc(2)
    reg.gauge("g_set").set(1.5)
    reg.gauge("g_never_set")
    reg.histogram("h", (1.0,)).observe(0.5)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert set(snap) == {"c", "g_set", "h"}  # NaN gauge dropped
    assert snap["c"] == {"type": "counter", "value": 2.0}
    assert snap["h"]["counts"] == [1, 0]


def test_prometheus_exposition_format():
    reg = Registry()
    reg.counter("decode.tokens", "tokens out").inc(7)
    h = reg.histogram("serve.request_ms", (1.0, 10.0), "latency")
    h.observe_many([0.5, 5.0, 50.0])
    text = reg.prometheus()
    assert "# TYPE decode_tokens counter\ndecode_tokens 7" in text
    assert '# HELP decode_tokens tokens out' in text
    assert 'serve_request_ms_bucket{le="1"} 1' in text
    assert 'serve_request_ms_bucket{le="10"} 2' in text  # cumulative
    assert 'serve_request_ms_bucket{le="+Inf"} 3' in text
    assert "serve_request_ms_count 3" in text


def test_write_snapshot_and_append_jsonl(tmp_path):
    reg = Registry()
    reg.counter("n").inc()
    snap = reg.write_snapshot(tmp_path / "m.json")
    assert json.loads((tmp_path / "m.json").read_text()) == snap
    reg.append_jsonl(tmp_path / "trail.jsonl", run=1)
    reg.counter("n").inc()
    reg.append_jsonl(tmp_path / "trail.jsonl", run=2)
    lines = [json.loads(ln)
             for ln in (tmp_path / "trail.jsonl").read_text().splitlines()]
    assert [ln["run"] for ln in lines] == [1, 2]
    assert lines[1]["metrics"]["n"]["value"] == 2.0


# ---------------------------------------------------------------------------
# log_hook keeps its printed format, and lands in the registry
# ---------------------------------------------------------------------------
def test_log_hook_format_byte_identical_and_metrics_recorded():
    lines = []
    hook = log_hook(every=1, log_fn=lines.append, key="loss")
    before = registry().counter("train.log_lines").value
    hook(1, None, {"loss": np.asarray([0.125])})
    assert len(lines) == 1
    # the pinned format: "step {i:5d} {key} {v:8.4f} ({t:6.1f}s)"
    assert re.fullmatch(r"step     0 loss   0\.1250 \(\s*\d+\.\ds\)",
                        lines[0])
    assert registry().counter("train.log_lines").value == before + 1
    assert registry().gauge("train.last_loss").value == 0.125


# ---------------------------------------------------------------------------
# timeline export
# ---------------------------------------------------------------------------
def _schedule():
    # 2 workers round-robin, version read 2 commits back of the newest
    k = np.arange(6)
    return WorkerSchedule(
        read_versions=np.maximum(k - 2, 0).astype(np.int32),
        worker_ids=(k % 2).astype(np.int32),
        commit_times=(0.5 + 0.5 * k).astype(np.float64),
        num_workers=2,
        batch_sizes=np.full(6, 8, np.int32))


def test_cluster_timeline_is_valid_and_carries_staleness():
    trace = cluster_timeline([_schedule(), _schedule()], max_chains=1)
    assert validate_chrome_trace(trace) == []
    commits = [ev for ev in trace["traceEvents"] if ev.get("ph") == "X"]
    assert len(commits) == 6  # max_chains dropped the second chain
    by_commit = {ev["args"]["commit"]: ev for ev in commits}
    assert by_commit[5]["args"]["staleness"] == 2
    assert by_commit[5]["args"]["read_version"] == 3
    assert by_commit[5]["args"]["batch_size"] == 8
    # worker 1's commit 5 starts at its own previous commit (k=3, t=2.0)
    assert by_commit[5]["tid"] == 1
    assert by_commit[5]["ts"] == pytest.approx(2.0e6)
    assert by_commit[5]["dur"] == pytest.approx(1.0e6)


def test_decode_timeline_amortizes_token_slices():
    spans = [{"name": "decode.generate", "id": 7, "parent": None,
              "t0": 1.0, "t1": 2.0, "tid": 123,
              "attrs": {"B": 3, "T": 5, "b_rung": 4, "t_rung": 8,
                        "new_tokens": 2, "chains": 4}}]
    trace = decode_timeline(spans)
    assert validate_chrome_trace(trace) == []
    evs = [ev for ev in trace["traceEvents"] if ev.get("ph") == "X"]
    names = [ev["name"] for ev in evs]
    assert names == ["decode.generate", "decode.prefill", "decode.token",
                     "decode.token"]
    # 1s split over t_rung + new_tokens = 10 position units
    unit_us = 1e6 / 10
    assert evs[1]["dur"] == pytest.approx(8 * unit_us)  # prefill: 8 cached
    assert evs[2]["dur"] == pytest.approx(unit_us)
    assert evs[3]["ts"] == pytest.approx(evs[2]["ts"] + evs[2]["dur"])
    assert all(ev["args"]["amortized"] for ev in evs[1:])
    assert all(ev["args"]["request_span"] == 7 for ev in evs[1:])


def test_paged_timeline_per_slot_rows_and_queue_wait():
    """Slot rows carry prefill + residency, the queue wait is derived from
    submission to the *first* admit (an evicted request admits twice), and
    decode chunks land on the scheduler row."""
    spans = [
        {"name": "paged.admit", "id": 1, "parent": None, "t0": 1.0,
         "t1": 1.2, "tid": 9,
         "attrs": {"slot": 0, "request_id": 41, "T": 5, "t_rung": 8,
                   "pages": 2}},
        # request 41 was evicted and re-admitted later on slot 1
        {"name": "paged.admit", "id": 2, "parent": None, "t0": 2.0,
         "t1": 2.1, "tid": 9,
         "attrs": {"slot": 1, "request_id": 41, "T": 5, "t_rung": 8,
                   "pages": 2}},
        {"name": "paged.decode_chunk", "id": 3, "parent": None, "t0": 1.2,
         "t1": 1.5, "tid": 9, "attrs": {"active": 2, "chunk": 4}},
        {"name": "paged.request", "id": 4, "parent": None, "t0": 0.5,
         "t1": 2.5, "tid": 9,
         "attrs": {"slot": 1, "request_id": 41, "new_tokens": 6,
                   "evictions": 1}},
    ]
    trace = paged_timeline(spans)
    assert validate_chrome_trace(trace) == []
    evs = {ev["name"]: ev for ev in trace["traceEvents"]
           if ev.get("ph") == "X"}
    # wait slice: submission (0.5) until the FIRST prefill start (1.0),
    # rendered on the first admitting slot's row
    assert evs["paged.wait"]["ts"] == pytest.approx(0.5e6)
    assert evs["paged.wait"]["dur"] == pytest.approx(0.5e6)
    assert evs["paged.wait"]["tid"] == 0
    assert evs["paged.request"]["tid"] == 1  # finished on slot 1
    assert evs["paged.request"]["args"]["evictions"] == 1
    # scheduler row sits above the highest slot row
    assert evs["paged.decode_chunk"]["tid"] == 2
    names = {(ev["pid"], ev.get("tid")): ev["args"]["name"]
             for ev in trace["traceEvents"] if ev.get("ph") == "M"}
    assert names[(0, 0)] == "slot 0"
    assert names[(0, 1)] == "slot 1"
    assert names[(0, 2)] == "scheduler"


def test_paged_timeline_from_live_engine():
    """The spans a real PagedDecodeEngine records export to a valid
    timeline with one admit per (admission incl. eviction replays) and one
    residency per completed request."""
    from repro.cluster import PagedDecodeEngine
    from repro.cluster.api import Request

    cfg = get_reduced("qwen3-4b")
    model = Model(cfg, remat=False)
    bank = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), 2))
    tr = tracer().enable()
    tr.clear()
    try:
        eng = PagedDecodeEngine(model=model, params=bank, num_slots=2,
                                page_size=8, max_seq=32, decode_chunk=4)
        rng = np.random.default_rng(0)
        for t, n in [(5, 4), (3, 2), (6, 5)]:
            eng.submit(Request(
                tokens=rng.integers(0, cfg.vocab_size, (t,),
                                    dtype=np.int32), max_new_tokens=n))
        comps = eng.drain()
        trace = paged_timeline(tr.drain())
    finally:
        tr.disable()
    assert validate_chrome_trace(trace) == []
    evs = [ev for ev in trace["traceEvents"] if ev.get("ph") == "X"]
    by = lambda n: [ev for ev in evs if ev["name"] == n]  # noqa: E731
    assert len(by("paged.request")) == len(comps) == 3
    assert len(by("paged.admit")) == 3  # no evictions in this stream
    assert len(by("paged.wait")) == 3
    assert len(by("paged.decode_chunk")) >= 1
    assert {ev["args"]["new_tokens"] for ev in by("paged.request")} \
        == {4, 2, 5}


def test_to_chrome_trace_and_summarize_roundtrip(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("a", k=1):
        with tr.span("b"):
            pass
    trace = write_chrome_trace(tmp_path / "t.json", tr.spans)
    assert validate_chrome_trace(trace) == []
    reread = json.loads((tmp_path / "t.json").read_text())
    assert reread == trace
    s = summarize(reread)
    assert s["makespan_s"] > 0 and s["critical"] is not None
    with pytest.raises(ValueError):
        write_chrome_trace(tmp_path / "bad.json", {"not_a_trace": 1})


def test_summarize_staleness_histogram():
    s = summarize(cluster_timeline(_schedule()))
    # delays of the fixture: k - max(k - 2, 0) = [0, 1, 2, 2, 2, 2]
    assert s["staleness_hist"] == {0: 1, 1: 1, 2: 4}


# ---------------------------------------------------------------------------
# traced warm decode stream: tracing is host-side only
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_traced_warm_decode_stream_keeps_stream_flags_empty():
    cfg = get_reduced("qwen3-4b")
    model = Model(cfg, remat=False)
    bank = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), 2))
    eng = DecodeEngine(model=model, params=bank, max_seq=32)
    prompt = np.zeros((2, 4), np.int32)
    eng.generate(prompt, 3)  # warm the (rung, max_new) trace
    tr = tracer()
    tr.clear()
    tr.enable()
    try:
        with instrument() as rep:
            for _ in range(3):
                eng.generate(prompt, 3)
    finally:
        tr.disable()
    # the tentpole invariant: tracing adds no retrace / pad alloc
    assert rep.stream_flags() == {"retraced_in_stream": False,
                                  "pad_allocs_in_stream": 0}
    spans = [sp for sp in tr.drain() if sp.name == "decode.generate"]
    assert len(spans) == 3
    assert spans[0].attrs["new_tokens"] == 3
    trace = decode_timeline(spans)
    assert validate_chrome_trace(trace) == []
    assert sum(ev["name"] == "decode.token"
               for ev in trace["traceEvents"]) == 9


def test_decode_metrics_land_in_registry():
    before = registry().counter("decode.requests").value
    cfg = get_reduced("qwen3-4b")
    bank = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(1), 2))
    eng = DecodeEngine(model=Model(cfg, remat=False), params=bank, max_seq=32)
    eng.generate(np.zeros((2, 4), np.int32), 2)
    assert registry().counter("decode.requests").value == before + 1
    assert registry().gauge("decode.bank_rungs").value >= 1.0
    assert registry().histogram(
        "decode.per_token_ms", LATENCY_MS_BUCKETS).total >= 1


# ---------------------------------------------------------------------------
# lint: the obs package (and everything that imports it) stays jaxlint-clean
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_jaxlint_silent_on_obs_and_benchmarks():
    # the CI lint job's exact command; obs spans must not introduce JL004
    # host-sync sites or any other finding into the linted tree
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "jaxlint.py"),
         os.path.join(ROOT, "src"), os.path.join(ROOT, "benchmarks")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# obstool CLI
# ---------------------------------------------------------------------------
def test_obstool_cli_smoke(tmp_path, capsys):
    sys.path.insert(0, os.path.join(ROOT, "scripts"))
    try:
        import obstool
    finally:
        sys.path.pop(0)
    write_chrome_trace(tmp_path / "t.json", cluster_timeline(_schedule()))
    reg = Registry()
    reg.counter("cluster.commits", "").inc(6)
    reg.histogram("lat", (1.0, 10.0)).observe_many([0.5, 5.0])
    reg.write_snapshot(tmp_path / "m.json")
    rc = obstool.main([str(tmp_path / "t.json"),
                       "--metrics", str(tmp_path / "m.json")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "critical path" in out and "staleness over commit spans" in out
    assert "cluster.commits" in out and "p99<=10" in out
    # an invalid timeline is reported and exits non-zero
    (tmp_path / "bad.json").write_text('{"traceEvents": [{"ph": "Z"}]}')
    assert obstool.main([str(tmp_path / "bad.json")]) == 1


def test_staleness_buckets_cover_ring_depths():
    # tau=0 (synchronous) must be distinguishable from tau>=1
    assert STALENESS_BUCKETS[0] == 0 and STALENESS_BUCKETS[1] == 1

"""repro.cluster: ensemble parity with the single-chain Engine, executable
schedule semantics, retrace flatness, staleness validation, sharded
equivalence, and convergence-in-measure via empirical W2."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.cluster import (
    ClusterEngine,
    StalenessError,
    WorkerSchedule,
    chain_positions,
    ensemble_async,
    ensemble_w2,
    w2_recorder,
)
from repro.core import Quadratic, WorkerModel, constant_delays, simulate_async
from repro.train.engine import Engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
C, STEPS, TAU = 8, 37, 8


@pytest.fixture(scope="module")
def quad():
    return Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)


@pytest.fixture(scope="module")
def quad_sampler(quad):
    return samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                         gamma=0.01, sigma=0.5, tau=TAU)


@pytest.fixture(scope="module")
def schedules():
    return ensemble_async(WorkerModel(num_workers=4, seed=1), STEPS, C, seed=0)


# ---------------------------------------------------------------------------
# schedule semantics
# ---------------------------------------------------------------------------
def test_schedule_roundtrips_trace():
    trace = simulate_async(WorkerModel(num_workers=4, seed=0), 50, seed=3)
    sched = WorkerSchedule.from_trace(trace)
    np.testing.assert_array_equal(sched.delays, trace.delays)
    np.testing.assert_array_equal(sched.worker_ids, trace.worker_ids)
    np.testing.assert_array_equal(sched.to_trace().commit_times,
                                  trace.commit_times)
    # read versions are causal: a commit can't read the future
    assert np.all(sched.read_versions <= np.arange(50))


def test_schedule_rejects_acausal_reads():
    with pytest.raises(ValueError):
        WorkerSchedule(read_versions=np.array([0, 2], np.int32),
                       worker_ids=np.zeros(2, np.int32),
                       commit_times=np.arange(2, dtype=np.float64),
                       num_workers=1)


def test_schedule_validate_ring():
    sched = WorkerSchedule.from_delays(np.array([0, 1, 2, 3], np.int32))
    sched.validate_ring(4)  # max delay 3 fits depth 4
    with pytest.raises(StalenessError):
        sched.validate_ring(3)


# ---------------------------------------------------------------------------
# ensemble parity: the acceptance-criterion bitwise check
# ---------------------------------------------------------------------------
def test_chain_parity_bitwise_vs_single_chain_engine(quad_sampler, schedules):
    """Chain c of the vmapped C-chain ensemble must equal an independent
    single-chain Engine.run with the same per-chain key and trace, bit for
    bit — vmap and the endogenous version-derived delays change nothing."""
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10)
    key = jax.random.PRNGKey(42)
    state = engine.init(jnp.zeros(4), key)
    state, _ = engine.run(state, steps=STEPS, schedule=schedules)
    assert np.all(np.asarray(state.step) == STEPS)

    chain_keys = jax.random.split(key, C)
    for c in range(C):
        single = Engine(quad_sampler, chunk_size=10)
        st = quad_sampler.init(jnp.zeros(4), chain_keys[c])
        st, _ = single.run(st, steps=STEPS, delays=schedules[c].to_trace())
        assert np.array_equal(np.asarray(st.params),
                              np.asarray(state.params[c])), f"chain {c}"


def test_no_retrace_across_delay_values_and_schedules(quad_sampler, schedules):
    """Distinct schedules (distinct delay values) at fixed shapes must not
    retrigger compilation — delays enter as traced int32 read versions."""
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(0))
    state, _ = engine.run(state, steps=30, schedule=schedules)
    assert engine.num_traces == 1, engine.num_traces
    other = ensemble_async(WorkerModel(num_workers=2, seed=9), 30, C, seed=50)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(1))
    state, _ = engine.run(state, steps=30, schedule=other)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(2))
    state, _ = engine.run(state, steps=30)  # sync (tau=0) schedule
    assert engine.num_traces == 1, engine.num_traces


def test_staleness_validation_raises(quad):
    """A schedule staler than the ring depth must fail loudly, not clamp."""
    shallow = samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                            gamma=0.01, sigma=0.5, tau=2)
    engine = ClusterEngine(shallow, num_chains=C, chunk_size=10)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(0))
    deep = constant_delays(5, 20)  # max delay 5 >= depth 3
    with pytest.raises(StalenessError, match="does not fit the iterate ring"):
        engine.run(state, steps=20,
                   schedule=WorkerSchedule.from_trace(deep))


def test_continuation_run_rebases_read_versions(quad_sampler, schedules):
    """Resuming an advanced ensemble must realize the schedule's tau_k —
    read versions are rebased onto the state's commit counter, so the second
    leg stays bitwise-equal to a resumed single-chain Engine (not a
    silently-clamped stale read)."""
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10)
    key = jax.random.PRNGKey(11)
    state = engine.init(jnp.zeros(4), key)
    state, _ = engine.run(state, steps=20, schedule=schedules)
    state, _ = engine.run(state, steps=17, schedule=schedules)  # resume

    chain_keys = jax.random.split(key, C)
    single = Engine(quad_sampler, chunk_size=10)
    st = quad_sampler.init(jnp.zeros(4), chain_keys[2])
    st, _ = single.run(st, steps=20, delays=schedules[2].to_trace())
    st, _ = single.run(st, steps=17, delays=schedules[2].to_trace())
    assert np.array_equal(np.asarray(st.params), np.asarray(state.params[2]))


def test_per_chain_schedules_of_unequal_length(quad_sampler):
    """Chains may carry schedules of different lengths as long as each
    covers the requested steps — they are trimmed before stacking."""
    scheds = [WorkerSchedule.from_delays(np.zeros(10 + c, np.int64))
              for c in range(C)]
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=5)
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(12))
    state, _ = engine.run(state, steps=10, schedule=scheds)
    assert np.all(np.asarray(state.step) == 10)
    with pytest.raises(ValueError, match="covers 10 commits"):
        engine.run(state, steps=11, schedule=scheds)


def test_per_chain_batches_from_batch_fn(quad):
    """batch_fn keys are split per (step, chain): every chain sees its own
    minibatch and the ensemble stays finite."""
    noisy = Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0,
                           grad_noise=0.5)
    sampler = samplers.sgld(
        "sync", lambda p, batch: noisy.grad(p, None, key=batch["key"]),
        gamma=0.01, sigma=0.5)
    engine = ClusterEngine(sampler, num_chains=C, chunk_size=8,
                           batch_fn=lambda k: {"key": jax.random.fold_in(k, 0)})
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(1))
    state, _ = engine.run(state, steps=24, key=jax.random.PRNGKey(2))
    params = np.asarray(state.params)
    assert params.shape == (C, 4) and np.all(np.isfinite(params))
    # independent batches: no two chains may share a trajectory
    assert len({params[c].tobytes() for c in range(C)}) == C


def test_explicit_batches_broadcast_even_with_batch_fn(quad_sampler):
    """Explicit `batches` follow the per_chain_batches contract (broadcast
    by default) even when a batch_fn is also configured on the engine."""
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=10,
                           batch_fn=lambda k: jnp.zeros(3))
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(13))
    state, _ = engine.run(state, steps=20, batches=jnp.zeros((20, 3)))
    assert np.all(np.asarray(state.step) == 20)


@pytest.mark.slow
def test_ensemble_w2_measures_convergence_in_measure():
    """Overdispersed chain cloud contracts onto the Gibbs posterior: the
    empirical W2 (exact 1-D quantile estimator) must drop well below its
    starting value — the honest replacement for the single-chain proxy."""
    quad = Quadratic.make(jax.random.PRNGKey(3), d=1, m=1.0, L=1.0)
    sigma = 0.5
    chains = 64
    scheds = ensemble_async(WorkerModel(num_workers=4, seed=0), 200, chains,
                            seed=7)
    tau = max(s.max_delay for s in scheds)
    sampler = samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                            gamma=0.05, sigma=sigma, tau=tau)
    target = quad.x_star + jnp.sqrt(quad.stationary_cov(sigma)) * \
        jax.random.normal(jax.random.PRNGKey(4), (chains, 1))
    rec = w2_recorder(target, every=40)
    engine = ClusterEngine(sampler, num_chains=chains, chunk_size=40,
                           hooks=[rec])
    state = engine.init(jnp.zeros(1), jax.random.PRNGKey(5), jitter=4.0)
    w2_start = float(ensemble_w2(chain_positions(state.params), target))
    state, _ = engine.run(state, steps=200, schedule=scheds)
    w2_end = rec.record[-1]["w2"]
    assert rec.record[-1]["commit_time"] is not None  # wall clock threaded
    assert w2_end < 0.25 * w2_start, (w2_start, w2_end)


# ---------------------------------------------------------------------------
# sharded equivalence (subprocess: 8 forced host devices, debug mesh)
# ---------------------------------------------------------------------------
SCRIPT_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro import samplers
from repro.cluster import ClusterEngine, ensemble_async
from repro.core import Quadratic, WorkerModel
from repro.launch.mesh import make_debug_mesh

quad = Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)
sampler = samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                        gamma=0.01, sigma=0.5, tau=8)
C, steps = 8, 20
scheds = ensemble_async(WorkerModel(num_workers=4, seed=1), steps, C, seed=0)
key = jax.random.PRNGKey(42)

local = ClusterEngine(sampler, num_chains=C, chunk_size=10)
s_local = local.init(jnp.zeros(4), key)
s_local, _ = local.run(s_local, steps=steps, schedule=scheds)

mesh = make_debug_mesh(data=2, model=2)
sharded = ClusterEngine(sampler, num_chains=C, chunk_size=10, mesh=mesh)
s_shard = sharded.init(jnp.zeros(4), key)
s_shard, _ = sharded.run(s_shard, steps=steps, schedule=scheds)

spec = s_shard.params.sharding.spec
print(json.dumps({
    "bitwise_equal": bool(np.array_equal(np.asarray(s_local.params),
                                         np.asarray(s_shard.params))),
    "chain_axis_sharded": "data" in (spec[0] if spec else ()) or spec[0] == "data",
    "traces": sharded.num_traces,
}))
"""


@pytest.mark.slow
def test_sharded_matches_unsharded_on_debug_mesh():
    from subproc import run_json

    res = run_json(SCRIPT_SHARDED, timeout=600)
    assert res["bitwise_equal"], res
    assert res["chain_axis_sharded"], res
    assert res["traces"] == 1, res


# ---------------------------------------------------------------------------
# cross-chain diagnostics: split-R-hat and ESS over the chain axis
# ---------------------------------------------------------------------------
def test_split_rhat_near_one_for_iid_and_large_for_separated():
    from repro.cluster import split_rhat

    rng = np.random.default_rng(0)
    iid = jnp.asarray(rng.standard_normal((8, 128, 3)), jnp.float32)
    r = np.asarray(split_rhat(iid))
    assert r.shape == (3,)
    assert np.all(np.abs(r - 1.0) < 0.05)
    separated = iid + jnp.arange(8, dtype=jnp.float32)[:, None, None] * 3.0
    assert np.all(np.asarray(split_rhat(separated)) > 2.0)


def test_split_rhat_catches_within_chain_drift():
    """Splitting each chain in half flags chains that agree with each other
    but are still moving — plain R-hat's blind spot."""
    from repro.cluster import split_rhat

    rng = np.random.default_rng(1)
    iid = jnp.asarray(rng.standard_normal((8, 128, 2)), jnp.float32)
    drifting = iid + jnp.linspace(0.0, 5.0, 128)[None, :, None]
    assert np.all(np.asarray(split_rhat(drifting)) > 1.2)


def test_ess_full_for_iid_and_collapsed_for_correlated():
    from repro.cluster import ess

    rng = np.random.default_rng(2)
    C_, N_ = 8, 128
    iid = jnp.asarray(rng.standard_normal((C_, N_, 2)), jnp.float32)
    e = np.asarray(ess(iid))
    assert e.shape == (2,)
    assert np.all(e > 0.7 * C_ * N_)  # iid: near the nominal C*N
    phi = 0.95
    x = np.zeros((C_, N_, 2), np.float32)
    eps = rng.standard_normal((C_, N_, 2)).astype(np.float32)
    for t in range(1, N_):
        x[:, t] = phi * x[:, t - 1] + np.sqrt(1 - phi**2) * eps[:, t]
    assert np.all(np.asarray(ess(jnp.asarray(x))) < 0.2 * C_ * N_)


def test_ess_collapses_for_chains_stuck_in_different_modes():
    """The between-chain variance term (Vehtari/Stan) matters: chains that
    are each iid around a *different* mode look uncorrelated from the
    inside but carry almost no information about the pooled law."""
    from repro.cluster import ess

    rng = np.random.default_rng(3)
    C_, N_ = 8, 128
    iid = jnp.asarray(rng.standard_normal((C_, N_, 2)), jnp.float32)
    stuck = iid + jnp.arange(C_, dtype=jnp.float32)[:, None, None] * 5.0
    assert np.all(np.asarray(ess(stuck)) < 0.05 * C_ * N_)
    assert np.all(np.asarray(ess(iid)) > 0.7 * C_ * N_)  # unchanged for iid


def test_diagnostics_recorder_hook_records_next_to_w2(quad, quad_sampler,
                                                      schedules):
    """diagnostics_recorder rides the same hook seam as w2_recorder and
    emits (rhat_max, ess_min) rows once its window fills, plus a flush row."""
    from repro.cluster import diagnostics_recorder

    hook = diagnostics_recorder(every=1, window=8)
    engine = ClusterEngine(quad_sampler, num_chains=C, chunk_size=2,
                           batch_fn=lambda k: quad.sample_batch(k, 8),
                           hooks=(hook,))
    state = engine.init(jnp.zeros(4), jax.random.PRNGKey(0), jitter=0.5)
    state, _ = engine.run(state, steps=24, schedule=schedules[:1] * C,
                          key=jax.random.PRNGKey(1))
    hook.flush(24, state)
    assert hook.record, "window never filled"
    row = hook.record[-1]
    assert set(row) == {"step", "rhat_max", "ess_min", "n_draws"}
    assert row["step"] == 24
    assert np.isfinite(row["rhat_max"]) and row["rhat_max"] > 0.0
    assert 0.0 < row["ess_min"] <= C * row["n_draws"]

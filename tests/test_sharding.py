"""Sharding integration: runs in a SUBPROCESS with 8 forced host devices so
the main pytest process keeps seeing 1 device (per the dry-run isolation
rule).  Verifies that the sharded MoE path equals the local path and that a
small mesh train step lowers, compiles, and executes."""

import os

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from dataclasses import replace
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import moe as moe_mod
from repro.models.moe import apply_moe, init_moe
from repro.utils import use_mesh

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = replace(get_reduced("phi3.5-moe-42b-a6.6b"), dtype="float32",
              num_experts=8, experts_per_token=2)
p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

# Dispatch-plumbing equivalence holds only drop-free: per-shard capacity
# necessarily drops different tokens than global capacity, so compare with
# headroom that admits every routed token.
moe_mod.CAPACITY_FACTOR = 1e9
y_local, aux_local = apply_moe(p, x, cfg, mesh=None)
with use_mesh(mesh):
    y_shard, aux_shard = jax.jit(
        lambda p, x: apply_moe(p, x, cfg, mesh=mesh, batch_axes=("data",)))(p, x)
err = float(jnp.abs(y_local - y_shard).max())
rel = err / float(jnp.abs(y_local).max())

# production capacity factor: path must still run and stay finite
moe_mod.CAPACITY_FACTOR = 1.25
with use_mesh(mesh):
    y_drop, _ = jax.jit(
        lambda p, x: apply_moe(p, x, cfg, mesh=mesh, batch_axes=("data",)))(p, x)
print(json.dumps({"rel_err": rel,
                  "aux_err": abs(float(aux_local) - float(aux_shard)),
                  "drop_finite": bool(np.isfinite(np.asarray(y_drop)).all())}))
"""

SCRIPT_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from dataclasses import replace
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced, ShapeConfig
from repro.data import make_batch
from repro.models.common import partition_tree
from repro.models.transformer import Model, init_params
from repro.launch.steps import make_sgld_train_step, sanitized_named

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = replace(get_reduced("qwen3-4b"), dtype="float32")
shape = ShapeConfig("t", seq_len=64, global_batch=4, kind="train",
                    num_microbatches=2)
model = Model(cfg, mesh=mesh, batch_axes=("data",))
params = init_params(jax.random.PRNGKey(0), cfg)
specs = partition_tree(params, cfg.param_sharding, cfg=cfg,
                       model_size=mesh.shape["model"])
pshard = sanitized_named(mesh, specs, params)
params = jax.device_put(params, pshard)
batch = make_batch(cfg, shape, jax.random.PRNGKey(1), "train")
step = make_sgld_train_step(model, shape, mode="sync", gamma=1e-3, sigma=1e-8)
from repro.utils import use_mesh
with use_mesh(mesh):
    jstep = jax.jit(step, out_shardings=(pshard, NamedSharding(mesh, P())))
    new_params, loss = jstep(params, batch, jnp.array([0, 1], jnp.uint32))
    loss2 = None
    # unsharded reference
model0 = Model(cfg, mesh=None)
step0 = make_sgld_train_step(model0, shape, mode="sync", gamma=1e-3, sigma=1e-8)
_, loss_ref = jax.jit(step0)(jax.device_get(params), batch,
                             jnp.array([0, 1], jnp.uint32))
print(json.dumps({"loss": float(loss), "loss_ref": float(loss_ref),
                  "finite": bool(np.isfinite(float(loss)))}))
"""


def _run(script: str) -> dict:
    from subproc import run_json

    return run_json(script, timeout=600)


@pytest.mark.slow
def test_sharded_moe_matches_local():
    res = _run(SCRIPT_MOE)
    assert res["rel_err"] < 5e-5, res
    # aux is computed per data shard then averaged (standard practice);
    # it differs from the global statistic by O(shard-variance)
    assert res["aux_err"] < 0.1, res
    assert res["drop_finite"], res


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded_loss():
    res = _run(SCRIPT_TRAIN)
    assert res["finite"], res
    assert abs(res["loss"] - res["loss_ref"]) < 5e-3, res

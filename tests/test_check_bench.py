"""Unit tests for the CI perf-regression gates in scripts/check_bench.py:
the cluster gate (speedup / W2-at-budget / batch-policy advantage), the
serve gate (QPS floor, p99 ceiling, retrace flag, row presence), and the
decode gate (tokens/sec floor, per-token p99 ceiling, exact trace-count
match, sublinearity, and the continuous-batching uplift block)."""

import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))
import check_bench  # noqa: E402


@pytest.fixture
def cluster_baseline():
    return {
        "config": {"num_chains": 8, "seed": 0},
        "speedup_vs_sync": 1.3,
        "final_w2_async": 0.55,
        "batch_policy": {"het_wallclock_advantage": 2.2},
    }


@pytest.fixture
def serve_baseline():
    return {
        "config": {"requests": 60, "seed": 0},
        "rows": [
            {"chains": 8, "shards": 1, "qps": 40000.0, "p99_ms": 1.0,
             "retraced_in_stream": False},
            {"chains": 32, "shards": 4, "qps": 8000.0, "p99_ms": 4.5,
             "retraced_in_stream": False},
        ],
    }


# ---------------------------------------------------------------------------
# cluster gate
# ---------------------------------------------------------------------------
def test_cluster_gate_passes_identical_payload(cluster_baseline):
    assert check_bench.check(copy.deepcopy(cluster_baseline),
                             cluster_baseline) == []


def test_cluster_gate_fails_on_speedup_regression(cluster_baseline):
    bad = copy.deepcopy(cluster_baseline)
    bad["speedup_vs_sync"] = 1.01  # > 1 but far below the 20% band
    msgs = check_bench.check(bad, cluster_baseline)
    assert len(msgs) == 1 and "speedup regressed" in msgs[0]
    bad["speedup_vs_sync"] = 0.9
    assert "does not exceed 1" in check_bench.check(bad, cluster_baseline)[0]


def test_cluster_gate_fails_on_w2_regression(cluster_baseline):
    bad = copy.deepcopy(cluster_baseline)
    bad["final_w2_async"] = 0.55 * 1.6  # above the 50% band
    msgs = check_bench.check(bad, cluster_baseline)
    assert len(msgs) == 1 and "W2-at-budget regressed" in msgs[0]


def test_cluster_gate_fails_when_het_advantage_lost(cluster_baseline):
    bad = copy.deepcopy(cluster_baseline)
    bad["batch_policy"]["het_wallclock_advantage"] = 0.97
    msgs = check_bench.check(bad, cluster_baseline)
    assert len(msgs) == 1 and "wall-clock advantage" in msgs[0]
    bad["batch_policy"]["het_wallclock_advantage"] = None  # never crossed
    assert len(check_bench.check(bad, cluster_baseline)) == 1


def test_cluster_gate_tolerates_payloads_without_batch_policy(
        cluster_baseline):
    old = {k: v for k, v in cluster_baseline.items() if k != "batch_policy"}
    assert check_bench.check(copy.deepcopy(old), cluster_baseline) == []


# ---------------------------------------------------------------------------
# serve gate
# ---------------------------------------------------------------------------
def test_serve_gate_passes_within_band(serve_baseline):
    ok = copy.deepcopy(serve_baseline)
    ok["rows"][0]["qps"] *= 0.5   # inside the wide 75% band
    ok["rows"][0]["p99_ms"] *= 3  # inside the 4x band
    assert check_bench.check(ok, serve_baseline) == []


def test_serve_gate_fails_on_seeded_qps_regression(serve_baseline):
    bad = copy.deepcopy(serve_baseline)
    bad["rows"][0]["qps"] = 40000.0 * 0.2  # below the 25% floor
    msgs = check_bench.check(bad, serve_baseline)
    assert len(msgs) == 1 and "QPS regressed" in msgs[0]
    assert "chains=8 shards=1" in msgs[0]


def test_serve_gate_fails_on_seeded_p99_regression(serve_baseline):
    bad = copy.deepcopy(serve_baseline)
    bad["rows"][1]["p99_ms"] = 4.5 * 6.0  # above the 5x ceiling
    msgs = check_bench.check(bad, serve_baseline)
    assert len(msgs) == 1 and "p99 latency regressed" in msgs[0]


def test_serve_gate_fails_on_in_stream_retrace_exactly(serve_baseline):
    bad = copy.deepcopy(serve_baseline)
    bad["rows"][0]["retraced_in_stream"] = True  # no tolerance band
    msgs = check_bench.check(bad, serve_baseline)
    assert len(msgs) == 1 and "retraced" in msgs[0]


def test_serve_gate_fails_on_missing_row(serve_baseline):
    bad = copy.deepcopy(serve_baseline)
    del bad["rows"][1]
    msgs = check_bench.check(bad, serve_baseline)
    assert len(msgs) == 1 and "row missing" in msgs[0]


def test_serve_gate_custom_tolerances(serve_baseline):
    tight = copy.deepcopy(serve_baseline)
    tight["rows"][0]["qps"] *= 0.85
    assert check_bench.check(tight, serve_baseline) == []
    assert check_bench.check(tight, serve_baseline, tol_qps=0.10) != []


# ---------------------------------------------------------------------------
# decode gate
# ---------------------------------------------------------------------------
@pytest.fixture
def decode_baseline():
    return {
        "kind": "decode",
        "config": {"requests": 12, "max_new": 8, "seed": 0},
        "rows": [
            {"chains": 1, "shards": 1, "tokens_per_s": 5000.0,
             "per_token_p50_ms": 0.8, "per_token_p99_ms": 1.5, "traces": 5,
             "retraced_in_stream": False, "pad_allocs_in_stream": 0},
            {"chains": 8, "shards": 8, "tokens_per_s": 3000.0,
             "per_token_p50_ms": 1.4, "per_token_p99_ms": 2.5, "traces": 5,
             "retraced_in_stream": False, "pad_allocs_in_stream": 0},
        ],
        "sublinear": {"chains": 8, "c1_per_token_ms": 0.8,
                      "sharded_per_token_ms": 1.4, "linear_bound_ms": 6.4,
                      "speedup_vs_linear": 4.57, "pass": True},
        "continuous": {
            "config": {"requests": 12, "num_slots": 4, "seed": 2},
            "static": {"qps": 1.0, "p99_ttft_ms": 9000.0,
                       "wasted_token_frac": 0.55,
                       "retraced_in_stream": False,
                       "pad_allocs_in_stream": 0},
            "paged": {"qps": 1.5, "p99_ttft_ms": 3000.0,
                      "page_utilization_mean": 0.5, "traces": 2,
                      "new_traces_in_stream": 0,
                      "retraced_in_stream": False,
                      "pad_allocs_in_stream": 0},
            "qps_uplift": 1.5, "pass": True,
        },
    }


def test_decode_gate_passes_within_band(decode_baseline):
    ok = copy.deepcopy(decode_baseline)
    ok["rows"][0]["tokens_per_s"] *= 0.5   # inside the wide 75% band
    ok["rows"][1]["per_token_p99_ms"] *= 3  # inside the 4x band
    assert check_bench.check(ok, decode_baseline) == []


def test_decode_gate_fails_on_seeded_tokens_per_s_regression(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    bad["rows"][0]["tokens_per_s"] = 5000.0 * 0.2  # below the 25% floor
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "tokens/sec regressed" in msgs[0]
    assert "chains=1 shards=1" in msgs[0]


def test_decode_gate_fails_on_seeded_p99_regression(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    bad["rows"][1]["per_token_p99_ms"] = 2.5 * 6.0  # above the 5x ceiling
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "per-token p99 regressed" in msgs[0]


def test_decode_gate_requires_exact_trace_count_match(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    bad["rows"][0]["traces"] = 6  # no band: one extra program compiled
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "trace count changed" in msgs[0]
    bad["rows"][0]["traces"] = 5
    bad["rows"][1]["retraced_in_stream"] = True
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "retraced inside" in msgs[0]
    bad["rows"][1]["retraced_in_stream"] = False
    bad["rows"][1]["pad_allocs_in_stream"] = 3
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "allocated per request" in msgs[0]


def test_decode_gate_fails_when_sublinearity_lost(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    bad["sublinear"]["pass"] = False
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "sublinearity" in msgs[0]
    bad["sublinear"] = None  # sharded rows vanished entirely
    assert len(check_bench.check(bad, decode_baseline)) == 1


def test_decode_gate_fails_on_missing_row_and_custom_band(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    del bad["rows"][1]
    bad["sublinear"] = decode_baseline["sublinear"]
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "row missing" in msgs[0]
    tight = copy.deepcopy(decode_baseline)
    tight["rows"][0]["tokens_per_s"] *= 0.9
    assert check_bench.check(tight, decode_baseline) == []
    assert check_bench.check(tight, decode_baseline, tol_tps=0.05) != []


def test_decode_gate_fails_when_continuous_uplift_lost(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    bad["continuous"]["qps_uplift"] = 0.97
    bad["continuous"]["pass"] = False
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "lost its sustained-QPS uplift" in msgs[0]
    # the pass flag gates even if the recorded uplift looks fine
    bad["continuous"]["qps_uplift"] = 1.4
    assert len(check_bench.check(bad, decode_baseline)) == 1


def test_decode_gate_continuous_wallclock_bands(decode_baseline):
    ok = copy.deepcopy(decode_baseline)
    ok["continuous"]["paged"]["qps"] = 1.5 * 0.5       # inside the 75% band
    ok["continuous"]["paged"]["p99_ttft_ms"] = 3000.0 * 3  # inside the 4x
    assert check_bench.check(ok, decode_baseline) == []
    bad = copy.deepcopy(decode_baseline)
    bad["continuous"]["paged"]["qps"] = 1.5 * 0.2      # below the 25% floor
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "paged QPS regressed" in msgs[0]
    bad = copy.deepcopy(decode_baseline)
    bad["continuous"]["paged"]["p99_ttft_ms"] = 3000.0 * 6  # above the 5x
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "p99 TTFT regressed" in msgs[0]


def test_decode_gate_continuous_structural_invariants_are_exact(
        decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    bad["continuous"]["paged"]["traces"] = 3  # no band: extra program
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "paged trace count changed" in msgs[0]
    bad["continuous"]["paged"]["traces"] = 2
    bad["continuous"]["paged"]["new_traces_in_stream"] = 1
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "retraced inside the arrival stream" in msgs[0]
    bad["continuous"]["paged"]["new_traces_in_stream"] = 0
    bad["continuous"]["static"]["pad_allocs_in_stream"] = 2
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "static server allocated" in msgs[0]


def test_decode_gate_continuous_block_must_not_vanish(decode_baseline):
    bad = copy.deepcopy(decode_baseline)
    del bad["continuous"]
    msgs = check_bench.check(bad, decode_baseline)
    assert len(msgs) == 1 and "has none" in msgs[0]
    # pre-continuous baselines don't demand the block from fresh runs
    old = copy.deepcopy(decode_baseline)
    del old["continuous"]
    assert check_bench.check(copy.deepcopy(old), old) == []


def test_cli_gates_the_committed_decode_baseline_against_itself(tmp_path):
    root = os.path.join(os.path.dirname(__file__), "..")
    baseline = os.path.join(root, "benchmarks", "baselines",
                            "BENCH_decode.json")
    assert check_bench.main([baseline, "--baseline", baseline]) == 0
    with open(baseline) as f:
        payload = json.load(f)
    payload["rows"][0]["traces"] += 1
    fresh = tmp_path / "BENCH_decode.json"
    fresh.write_text(json.dumps(payload))
    assert check_bench.main([str(fresh), "--baseline", baseline]) == 1


# ---------------------------------------------------------------------------
# CLI end-to-end against the committed baselines
# ---------------------------------------------------------------------------
def test_cli_gates_the_committed_serve_baseline_against_itself(tmp_path):
    root = os.path.join(os.path.dirname(__file__), "..")
    baseline = os.path.join(root, "benchmarks", "baselines",
                            "BENCH_serve.json")
    assert check_bench.main([baseline, "--baseline", baseline]) == 0
    with open(baseline) as f:
        payload = json.load(f)
    payload["rows"][0]["qps"] = 1.0
    fresh = tmp_path / "BENCH_serve.json"
    fresh.write_text(json.dumps(payload))
    assert check_bench.main([str(fresh), "--baseline", baseline]) == 1


def test_cli_gates_the_committed_cluster_baseline_against_itself():
    root = os.path.join(os.path.dirname(__file__), "..")
    baseline = os.path.join(root, "benchmarks", "baselines",
                            "BENCH_cluster.json")
    assert check_bench.main([baseline, "--baseline", baseline]) == 0


# ---------------------------------------------------------------------------
# non-gating metric-snapshot deltas
# ---------------------------------------------------------------------------
def test_metric_deltas_compares_shared_scalars():
    cur = {"decode.tokens": {"type": "counter", "value": 120.0},
           "serve.request_ms": {"type": "histogram", "bounds": [1.0],
                                "counts": [3, 1], "count": 4, "sum": 8.0},
           "new.metric": {"type": "gauge", "value": 1.0}}
    base = {"decode.tokens": {"type": "counter", "value": 100.0},
            "serve.request_ms": {"type": "histogram", "bounds": [1.0],
                                 "counts": [4, 0], "count": 4, "sum": 2.0},
            "old.metric": {"type": "gauge", "value": 2.0}}
    lines = check_bench.metric_deltas(cur, base)
    text = "\n".join(lines)
    assert "decode.tokens: 100 -> 120 (+20.0%)" in text
    assert "serve.request_ms.mean: 0.5 -> 2" in text
    assert "new metrics (no baseline): new.metric" in text
    assert "baseline metrics missing from this run: old.metric" in text
    # identical snapshots produce no lines at all
    assert check_bench.metric_deltas(base, base) == []


def test_metric_deltas_are_printed_but_never_gate(tmp_path, capsys):
    payload = {"config": {}, "speedup_vs_sync": 1.3, "final_w2_async": 0.5,
               "batch_policy": {"het_wallclock_advantage": 2.0}}
    for name, tokens in (("BENCH_cluster.json", 100.0),
                         ("base.json", 50.0)):
        (tmp_path / name).write_text(json.dumps(payload))
        (tmp_path / name.replace(".json", ".metrics.json")).write_text(
            json.dumps({"decode.tokens":
                        {"type": "counter", "value": tokens}}))
    rc = check_bench.main([str(tmp_path / "BENCH_cluster.json"),
                           "--baseline", str(tmp_path / "base.json")])
    out = capsys.readouterr().out
    assert rc == 0  # a 2x metric delta is informative, not a regression
    assert "metric deltas vs baseline snapshot (non-gating):" in out
    assert "decode.tokens: 50 -> 100 (+100.0%)" in out


def test_metric_deltas_skipped_without_snapshots(tmp_path, capsys):
    payload = {"config": {}, "speedup_vs_sync": 1.3, "final_w2_async": 0.5,
               "batch_policy": {"het_wallclock_advantage": 2.0}}
    for name in ("BENCH_cluster.json", "base.json"):
        (tmp_path / name).write_text(json.dumps(payload))
    rc = check_bench.main([str(tmp_path / "BENCH_cluster.json"),
                           "--baseline", str(tmp_path / "base.json")])
    assert rc == 0
    assert "metric deltas" not in capsys.readouterr().out

"""W2 / KL estimator correctness against closed forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.metrics import (
    gaussian_kl,
    gaussian_w2,
    kl_samples_to_gaussian,
    knn_kl_estimate,
    sinkhorn_w2,
    w2_empirical_1d,
    w2_to_gaussian,
)


def test_gaussian_w2_identities():
    mu = jnp.zeros(3)
    cov = jnp.eye(3)
    assert float(gaussian_w2(mu, cov, mu, cov)) < 1e-5
    # pure translation: W2 = ||shift||
    shift = jnp.array([3.0, 4.0, 0.0])
    np.testing.assert_allclose(float(gaussian_w2(mu + shift, cov, mu, cov)),
                               5.0, rtol=1e-5)
    # isotropic scale: W2^2 = d (s1 - s2)^2
    np.testing.assert_allclose(
        float(gaussian_w2(mu, 4.0 * cov, mu, cov)), np.sqrt(3.0), rtol=1e-5)


@given(shift=st.floats(-3, 3), scale=st.floats(0.5, 2.0))
@settings(max_examples=10, deadline=None)
def test_w2_1d_gaussian_quantile(shift, scale):
    """1-D W2 between N(0,1) and N(shift, scale^2):
    W2^2 = shift^2 + (scale-1)^2."""
    x = np.random.default_rng(0).normal(size=20000)
    y = shift + scale * np.random.default_rng(1).normal(size=20000)
    got = float(w2_empirical_1d(jnp.asarray(x), jnp.asarray(y)))
    want = np.sqrt(shift**2 + (scale - 1.0) ** 2)
    assert abs(got - want) < 0.05 + 0.05 * want


def test_sinkhorn_matches_gaussian_closed_form():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (500, 2))
    y = jax.random.normal(jax.random.PRNGKey(1), (500, 2)) + jnp.array([2.0, 0.0])
    got = float(sinkhorn_w2(x, y, eps=0.05))
    assert abs(got - 2.0) < 0.15


def test_w2_to_gaussian_moment_matched():
    key = jax.random.PRNGKey(2)
    samples = 2.0 + 0.5 * jax.random.normal(key, (4000, 3))
    d = float(w2_to_gaussian(samples, jnp.full(3, 2.0), 0.25 * jnp.eye(3)))
    assert d < 0.1


def test_gaussian_kl_identities():
    mu, cov = jnp.zeros(2), jnp.eye(2)
    assert float(gaussian_kl(mu, cov, mu, cov)) < 1e-6
    # KL(N(m,I)||N(0,I)) = ||m||^2/2
    np.testing.assert_allclose(
        float(gaussian_kl(mu + 1.0, cov, mu, cov)), 1.0, rtol=1e-5)


def test_kl_samples_to_gaussian():
    key = jax.random.PRNGKey(3)
    samples = jax.random.normal(key, (5000, 2))
    kl = float(kl_samples_to_gaussian(samples, jnp.zeros(2), jnp.eye(2)))
    assert kl < 0.02


def test_knn_kl_sanity():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (400, 2))
    y = jax.random.normal(jax.random.PRNGKey(5), (400, 2))
    z = jax.random.normal(jax.random.PRNGKey(6), (400, 2)) + 3.0
    same = float(knn_kl_estimate(x, y))
    diff = float(knn_kl_estimate(x, z))
    assert diff > same + 1.0

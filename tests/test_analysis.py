"""repro.analysis: the jaxlint rules each fire on their seeded fixture and
stay silent on the clean variant and on the real tree; pragmas suppress;
the instrument bus reports exact per-engine trace/pad-alloc counts for a
mixed serve+decode stream (the program-structure invariant the benchmark
gates pin)."""

import json
import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis import counters, instrument
from repro.analysis.lint import RULES, lint_file, lint_paths, lint_source
from repro.cluster import DecodeEngine, ServeEngine, bucket_size
from repro.configs import get_reduced
from repro.core import PolyRegression
from repro.models import regression_predict
from repro.models.transformer import Model, init_params

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "jaxlint"


# -- linter: seeded fixtures ------------------------------------------------

@pytest.mark.parametrize("rule", sorted(RULES))
def test_rule_fires_on_seeded_violation(rule):
    findings = lint_file(FIXTURES / f"{rule.lower()}_bad.py")
    active = [f for f in findings if f.rule == rule and not f.suppressed]
    assert active, (f"{rule} did not fire on its seeded fixture; "
                    f"got {[f.format() for f in findings]}")


@pytest.mark.parametrize("rule", sorted(RULES))
def test_rule_silent_on_clean_variant(rule):
    findings = lint_file(FIXTURES / f"{rule.lower()}_good.py")
    hits = [f.format() for f in findings if f.rule == rule]
    assert not hits, f"{rule} false positive on its clean fixture: {hits}"


def test_inline_pragma_suppresses_but_records():
    findings = lint_file(FIXTURES / "pragma_suppressed.py")
    assert findings, "the pragma fixture's seeded violations went undetected"
    assert all(f.suppressed for f in findings), \
        [f.format() for f in findings if not f.suppressed]
    assert {f.rule for f in findings} == {"JL003", "JL004"}


def test_file_wide_pragma():
    findings = lint_file(FIXTURES / "pragma_file_wide.py")
    jl003 = [f for f in findings if f.rule == "JL003"]
    assert jl003 and all(f.suppressed for f in jl003)


def test_syntax_error_reported_not_raised(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    findings = lint_file(bad)
    assert [f.rule for f in findings] == ["JL000"]


def test_real_tree_is_clean():
    """The CI gate: src/benchmarks/examples carry no active findings."""
    findings = [f for f in lint_paths([REPO / "src", REPO / "benchmarks",
                                       REPO / "examples"])
                if not f.suppressed]
    assert not findings, "\n".join(f.format() for f in findings)


def test_import_alias_resolution():
    src = (
        "import jax.random as jr\n"
        "def sample(key, shape):\n"
        "    a = jr.normal(key, shape)\n"
        "    b = jr.uniform(key, shape)\n"
        "    return a + b\n"
    )
    assert [f.rule for f in lint_source(src)] == ["JL003"]


def test_cli_baseline_json():
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "jaxlint.py"), "--baseline",
         str(FIXTURES)],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert proc.returncode == 0, proc.stderr
    report = json.loads(proc.stdout)
    assert set(report) == {"rules", "findings", "counts"}
    assert report["counts"]["active"] > 0  # the seeded violations
    assert report["counts"]["suppressed"] >= 3  # the pragma fixtures
    rules_hit = {f["rule"] for f in report["findings"]}
    assert set(RULES) <= rules_hit


def test_cli_exits_nonzero_on_findings():
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "jaxlint.py"),
         str(FIXTURES / "jl003_bad.py")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "JL003" in proc.stdout


# -- instrument: the event bus ------------------------------------------------

def test_counters_broadcast_and_nesting():
    c = counters("X")
    with instrument() as outer:
        c.trace("f")
        with instrument() as inner:
            c.trace("f")
            c.pad_alloc()
    c.trace("g")  # outside both regions: handle counts it, reports don't
    assert (c.traces, c.pad_allocs) == (3, 1)
    assert c.per_fn == {"f": 2, "g": 1}
    assert outer.num_traces == 2 and inner.num_traces == 1
    assert outer.traces == {("X", "f"): 2}
    assert inner.pad_allocs == {"X": 1} and outer.num_pad_allocs == 1
    assert inner.stream_flags() == {"retraced_in_stream": True,
                                    "pad_allocs_in_stream": 1}
    empty = instrument()
    with empty as rep:
        pass
    assert rep.stream_flags() == {"retraced_in_stream": False,
                                  "pad_allocs_in_stream": 0}


def test_report_to_dict_is_json_ready():
    c = counters("Eng")
    with instrument() as rep:
        c.trace("stats")
        c.pad_alloc()
    d = json.loads(json.dumps(rep.to_dict()))
    assert d["traces"] == {"Eng/stats": 1}
    assert d["pad_allocs"] == {"Eng": 1}
    assert set(d) == {"traces", "pad_allocs", "xla_compiles",
                      "compile_ms", "donation_warnings"}
    assert all(isinstance(v, float) for v in d["compile_ms"].values())


def test_donation_warnings_captured_others_reemitted():
    with pytest.warns(UserWarning, match="unrelated"):
        with instrument() as rep:
            warnings.warn("Some donated buffers were not usable: f32[3]")
            warnings.warn("unrelated warning", UserWarning)
    assert len(rep.donation_warnings) == 1
    assert "donated" in rep.donation_warnings[0]


def test_transfer_guard_gives_jl004_teeth():
    import jax.numpy as jnp

    x = jnp.arange(4.0)
    with pytest.raises(Exception, match="[Dd]isallowed.*transfer"):
        with instrument(transfer_guard="disallow"):
            x[0].item()  # the index is an implicit host->device transfer


def test_mixed_serve_decode_stream_trace_counts():
    """The regression the benches gate on, pinned exactly: a cold mixed
    serve+decode stream traces once per shape rung and allocates one pad
    scratch per rung; replaying the same stream warm is silent."""
    reg = PolyRegression.make(jax.random.PRNGKey(0))
    serve = ServeEngine(predict_fn=regression_predict(reg),
                        params=jax.random.normal(jax.random.PRNGKey(1),
                                                 (4, 5)))
    cfg = get_reduced("qwen3-4b")
    decode = DecodeEngine(
        model=Model(cfg, remat=False),
        params=jax.vmap(lambda k: init_params(k, cfg))(
            jax.random.split(jax.random.PRNGKey(2), 2)),
        max_seq=32)

    rng = np.random.default_rng(0)
    queries = [rng.uniform(-1, 1, n).astype(np.float32)
               for n in (3, 5, 3, 17, 6)]
    prompts = [rng.integers(0, cfg.vocab_size, (b, t), dtype=np.int32)
               for b, t in ((2, 5), (3, 5), (2, 9), (2, 5))]
    serve_rungs = {bucket_size(q.size) for q in queries}            # 4, 8, 32
    decode_rungs = {(bucket_size(b), bucket_size(t))
                    for b, t in ((2, 5), (3, 5), (2, 9), (2, 5))}

    def replay():
        for q in queries:
            serve(q)
        for p in prompts:
            decode.generate(p, 4)

    with instrument() as cold:
        replay()
    assert cold.traces == {("ServeEngine", "stats"): len(serve_rungs),
                           ("DecodeEngine", "decode"): len(decode_rungs)}
    assert cold.pad_allocs == {"ServeEngine": len(serve_rungs),
                               "DecodeEngine": len(decode_rungs)}
    # the engines' public counters are views over the same bus
    assert serve.num_traces == cold.traces_for("ServeEngine")
    assert decode.num_traces == cold.traces_for("DecodeEngine")
    assert serve.num_host_pad_allocs == len(serve_rungs)
    assert decode.num_host_pad_allocs == len(decode_rungs)

    with instrument() as warm:
        replay()
    assert warm.stream_flags() == {"retraced_in_stream": False,
                                   "pad_allocs_in_stream": 0}
    assert warm.traces == {}

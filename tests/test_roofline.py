"""Roofline machinery: HLO collective parsing (loop-aware) and jaxpr cost."""

import jax
import jax.numpy as jnp

from repro.launch.jaxpr_cost import step_cost
from repro.launch.roofline import (
    _buffer_bytes,
    collective_bytes,
    model_flops,
)


def test_buffer_bytes():
    assert _buffer_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
    assert _buffer_bytes("(f32[8], f32[8])") == 64
    assert _buffer_bytes("u32[]") == 0 or _buffer_bytes("u32[]") == 4  # scalar


def test_collective_parse_flat():
    hlo = """
HloModule test

ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16] parameter(0)
  ROOT %ar = f32[16]{0} all-reduce(%p), replica_groups={}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 64


def test_collective_parse_loop_aware():
    hlo = """
HloModule test

%body (t: (s32[], f32[32])) -> (s32[], f32[32]) {
  %t = (s32[], f32[32]) parameter(0)
  %g = f32[32]{0} get-tuple-element(%t), index=1
  %ar = f32[32]{0} all-reduce(%g), replica_groups={}
  ROOT %out = (s32[], f32[32]) tuple(%g, %ar)
}

%cond (t: (s32[], f32[32])) -> pred[] {
  %t = (s32[], f32[32]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p: f32[32]) -> f32[32] {
  %p = f32[32] parameter(0)
  %init = (s32[], f32[32]) tuple(%p)
  %w = (s32[], f32[32]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[32]{0} get-tuple-element(%w), index=1
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 10 * 32 * 4  # trip count x buffer


def test_jaxpr_cost_exact_matmul():
    def f(x, w):
        return x @ w

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = step_cost(f, x, w)
    assert c.flops == 2 * 64 * 128 * 32


def test_jaxpr_cost_multiplies_scan():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = step_cost(f, x, w)
    assert c.flops == 10 * 2 * 64 * 64 * 64


def test_jaxpr_cost_grad_includes_backward():
    def f(x, w):
        return jnp.sum((x @ w) ** 2)

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    fwd = step_cost(f, x, w).flops
    bwd = step_cost(jax.grad(f, argnums=(0, 1)), x, w).flops
    assert bwd >= 2.5 * fwd  # fwd + 2 backward matmuls


def test_model_flops_kinds():
    from repro.configs import get_arch, get_shape
    cfg = get_arch("qwen3-4b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    assert tr == 6.0 * cfg.param_count() * 256 * 4096
    assert pf == 2.0 * cfg.param_count() * 32 * 32768
    assert dc == 2.0 * cfg.param_count() * 128

import os
import sys

# NOTE: no xla_force_host_platform_device_count here — unit/smoke tests see
# the real single CPU device.  Sharding tests spawn subprocesses that set it.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

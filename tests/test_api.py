"""cluster.api: the request-level front door and its compatibility pins.

``ServeEngine.serve`` and ``DecodeEngine.generate`` are thin shims over the
shared ``submit()``/``drain()`` endpoint — this file pins them **bitwise**
against the request-level path, pins the unified ``from_checkpoint`` /
``from_cluster`` constructor surface (including the legacy positional
order), and covers the Completion/timing contract plus the LRU cap on the
decode engine's persistent per-rung cache bank."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.cluster import DecodeEngine, ServeEngine
from repro.cluster.api import (
    FINISH_LENGTH,
    FINISH_QUERY,
    Completion,
    Request,
)
from repro.configs import get_reduced
from repro.core import PolyRegression
from repro.models import regression_predict, transformer_next_token_predict
from repro.models.transformer import Model, init_params

C = 4


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("qwen3-4b")


@pytest.fixture(scope="module")
def model(cfg):
    return Model(cfg, remat=False)


@pytest.fixture(scope="module")
def bank(cfg):
    return jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), C))


@pytest.fixture(scope="module")
def reg():
    return PolyRegression.make(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reg_bank():
    return jax.random.normal(jax.random.PRNGKey(1), (8, 5))


def prompt_batch(b, t, vocab, seed=1):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0,
                                         vocab, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# shim pinning: batch APIs are bitwise-equal to submit/drain
# ---------------------------------------------------------------------------
def test_generate_is_bitwise_equal_to_submit_drain(cfg, model, bank):
    """The batch-level ``generate`` and the request-level path must produce
    the same bits: the shim splits rows into Requests and the drain stacks
    them back into one batch trace."""
    prompt = prompt_batch(3, 5, cfg.vocab_size)
    a = DecodeEngine(model=model, params=bank, max_seq=32,
                     return_logits=True)
    b = DecodeEngine(model=model, params=bank, max_seq=32,
                     return_logits=True)
    res = a.generate(prompt, 6)
    ids = [b.submit(Request(tokens=prompt[i], max_new_tokens=6))
           for i in range(prompt.shape[0])]
    comps = {c.request_id: c for c in b.drain()}
    assert np.array_equal(np.stack([comps[r].tokens for r in ids]),
                          res.tokens)
    assert np.array_equal(np.stack([comps[r].logits for r in ids]),
                          res.logits)
    # same grouped batch => same single trace on both engines
    assert a.num_traces == b.num_traces == 1


def test_generate_shim_groups_by_shape_and_key(cfg, model, bank):
    """Requests sharing (T, max_new, key object) batch together; a request
    with its own key decodes in its own group, all in one drain."""
    eng = DecodeEngine(model=model, params=bank, max_seq=32)
    ref = DecodeEngine(model=model, params=bank, max_seq=32)
    key = np.asarray(jax.random.PRNGKey(3), np.uint32)
    p = prompt_batch(2, 5, cfg.vocab_size, seed=2)
    ids_g = [eng.submit(Request(tokens=p[i], max_new_tokens=4))
             for i in range(2)]
    id_s = eng.submit(Request(tokens=p[0], max_new_tokens=4, key=key))
    comps = {c.request_id: c for c in eng.drain()}
    want_g = ref.generate(p, 4)
    want_s = ref.generate(p[:1], 4, key=jnp.asarray(key))
    assert np.array_equal(np.stack([comps[r].tokens for r in ids_g]),
                          want_g.tokens)
    assert np.array_equal(comps[id_s].tokens, want_s.tokens[0])


def test_serve_is_bitwise_equal_to_submit_drain(reg, reg_bank):
    """``serve`` and per-query submit/drain agree bitwise on mean, var and
    every quantile row."""
    queries = jax.random.normal(jax.random.PRNGKey(5), (5,))
    a = ServeEngine(predict_fn=regression_predict(reg), params=reg_bank)
    b = ServeEngine(predict_fn=regression_predict(reg), params=reg_bank)
    res = a.serve(queries)
    ids = [b.submit(Request(tokens=np.asarray(queries[i])))
           for i in range(5)]
    comps = {c.request_id: c for c in b.drain()}
    rows = [comps[r].stats for r in ids]
    assert np.array_equal(np.stack([r.mean for r in rows]), res.mean)
    assert np.array_equal(np.stack([r.var for r in rows]), res.var)
    assert np.array_equal(np.stack([r.quantiles for r in rows], axis=1),
                          res.quantiles)
    assert a.num_traces == b.num_traces == 1


def test_serve_drain_groups_mixed_query_structures(reg, reg_bank, cfg,
                                                   model, bank):
    """A drain holding queries of different trailing shapes batches each
    structure separately and still answers every request."""
    eng = ServeEngine(predict_fn=regression_predict(reg), params=reg_bank)
    scalars = [np.float32(0.1), np.float32(0.7)]
    ids = [eng.submit(Request(tokens=s)) for s in scalars]
    comps = {c.request_id: c for c in eng.drain()}
    ref = ServeEngine(predict_fn=regression_predict(reg), params=reg_bank)
    want = ref.serve(np.asarray(scalars))
    for i, rid in enumerate(ids):
        assert comps[rid].finish_reason == FINISH_QUERY
        assert np.array_equal(comps[rid].stats.mean, want.mean[i])


# ---------------------------------------------------------------------------
# Request / Completion contract
# ---------------------------------------------------------------------------
def test_completion_fields_and_timing(cfg, model, bank):
    eng = DecodeEngine(model=model, params=bank, max_seq=32)
    rid = eng.submit(Request(tokens=prompt_batch(1, 5, cfg.vocab_size)[0],
                             max_new_tokens=3))
    (comp,) = eng.drain()
    assert isinstance(comp, Completion)
    assert comp.request_id == rid
    assert comp.finish_reason == FINISH_LENGTH
    assert comp.tokens.shape == (3,) and comp.tokens.dtype == np.int32
    assert comp.timing["submitted"] <= comp.timing["first_token"] \
        <= comp.timing["finished"]


def test_request_ids_are_unique_and_drain_is_idempotent(cfg, model, bank):
    eng = DecodeEngine(model=model, params=bank, max_seq=32)
    p = prompt_batch(2, 4, cfg.vocab_size)
    r1 = eng.submit(Request(tokens=p[0], max_new_tokens=2))
    r2 = eng.submit(Request(tokens=p[1], max_new_tokens=2))
    assert r1 != r2
    assert len(eng.drain()) == 2
    assert eng.drain() == []  # nothing pending: a drain is a no-op


def test_serve_engine_rejects_decode_requests(reg, reg_bank):
    eng = ServeEngine(predict_fn=regression_predict(reg), params=reg_bank)
    with pytest.raises(ValueError, match="decode engine"):
        eng.submit(Request(tokens=np.float32(0.5), max_new_tokens=4))


def test_decode_engine_validates_at_submit(cfg, model, bank):
    eng = DecodeEngine(model=model, params=bank, max_seq=8)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(tokens=np.zeros((4,), np.int32)))
    with pytest.raises(ValueError, match="overflows"):
        eng.submit(Request(tokens=np.zeros((6,), np.int32),
                           max_new_tokens=5))
    assert eng._pending == []  # rejected requests never enqueue


# ---------------------------------------------------------------------------
# unified constructor surface
# ---------------------------------------------------------------------------
def test_from_checkpoint_unified_and_legacy_orders(cfg, model, bank,
                                                   tmp_path):
    """One ``(path, like, front)`` signature across engines, with the
    legacy ``DecodeEngine.from_checkpoint(path, model, like)`` positional
    order auto-detected and swapped."""
    path = str(tmp_path / "bank.npz")
    save_checkpoint(path, bank)
    like = jax.tree_util.tree_map(lambda x: x[0], bank)
    unified = DecodeEngine.from_checkpoint(path, like, model, max_seq=32)
    legacy = DecodeEngine.from_checkpoint(path, model, like, max_seq=32)
    kws = DecodeEngine.from_checkpoint(path, like=like, model=model,
                                       max_seq=32)
    assert unified.num_chains == legacy.num_chains == kws.num_chains == C
    p = prompt_batch(2, 5, cfg.vocab_size, seed=8)
    a = unified.generate(p, 3).tokens
    assert np.array_equal(a, legacy.generate(p, 3).tokens)
    assert np.array_equal(a, kws.generate(p, 3).tokens)
    serve = ServeEngine.from_checkpoint(
        path, like, transformer_next_token_predict(model), donate=False)
    assert serve.num_chains == C


def test_from_cluster_shared_signature(cfg, model, bank, reg, reg_bank):
    """``from_cluster(state, front)`` maps ``front`` onto each engine's own
    front field (model / predict_fn)."""
    dec = DecodeEngine.from_cluster(bank, model, max_seq=32)
    srv = ServeEngine.from_cluster(reg_bank, regression_predict(reg))
    assert dec.num_chains == C and dec._model.cfg is not None
    assert srv.num_chains == 8
    p = prompt_batch(2, 4, cfg.vocab_size, seed=9)
    live = DecodeEngine(model=model, params=bank, max_seq=32)
    assert np.array_equal(dec.generate(p, 3).tokens,
                          live.generate(p, 3).tokens)


# ---------------------------------------------------------------------------
# LRU cap on the persistent per-rung cache bank
# ---------------------------------------------------------------------------
def test_cache_bank_lru_cap_and_eviction_counter(cfg, model, bank):
    """``max_cache_rungs`` bounds the persistent KV banks the engine keeps
    alive; the least-recently-used rung is dropped and counted on the
    ``decode.bank_evictions`` metric."""
    eng = DecodeEngine(model=model, params=bank, max_seq=32,
                       max_cache_rungs=2)
    before = eng._m_bank_evictions.value
    eng.generate(prompt_batch(1, 4, cfg.vocab_size), 2)   # rung B=1
    eng.generate(prompt_batch(2, 4, cfg.vocab_size), 2)   # rung B=2
    assert set(eng._cache) == {1, 2}
    eng.generate(prompt_batch(1, 4, cfg.vocab_size), 2)   # touch B=1 (MRU)
    eng.generate(prompt_batch(4, 4, cfg.vocab_size), 2)   # rung B=4 evicts 2
    assert set(eng._cache) == {1, 4}
    assert eng._m_bank_evictions.value == before + 1
    # the evicted rung re-admits — displacing the now-LRU B=1 bank — and
    # retraces nothing: traces are per rung shape, cached separately from
    # the bank buffers
    traces = eng.num_traces
    eng.generate(prompt_batch(2, 4, cfg.vocab_size), 2)
    assert set(eng._cache) == {2, 4}
    assert eng._m_bank_evictions.value == before + 2
    assert eng.num_traces == traces

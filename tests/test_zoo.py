"""Sampler-zoo edge cases: SVRG anchor refresh across chunk boundaries
(bitwise vs unchunked), stale_correction reducing to plain SGLD at
staleness 0 (bitwise), SGHMC momentum surviving a checkpoint round-trip,
and AR(1) stream reproducibility from a seed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import Quadratic, constant_delays
from repro.data import ar1_stream
from repro.train import Engine

GAMMA = 0.01
SIGMA = 0.5
STEPS = 60
TAU = 3


@pytest.fixture(scope="module")
def quad():
    return Quadratic.make(jax.random.PRNGKey(0), d=4, m=1.0, L=3.0)


def _grad_fns(quad, noise_scale=0.5):
    """Minibatch oracle with additive data noise + matching full-data
    gradient (data mean 0), so SVRG's control variate has something real
    to cancel."""
    grad = lambda p, b: quad.grad(p, None) + noise_scale * jnp.mean(  # noqa: E731
        b, axis=0)
    full_grad = lambda p: quad.grad(p, None)  # noqa: E731
    return grad, full_grad


def _batches(steps, d, seed=5):
    return jax.random.normal(jax.random.PRNGKey(seed), (steps, 8, d))


# -- SVRG ---------------------------------------------------------------------

def test_svrg_anchor_refresh_across_chunks_bitwise(quad):
    """Anchor refreshes landing mid-chunk and across chunk boundaries must
    be invisible: the anchor lives in the scanned carry, so an Engine run
    with a chunk size coprime to anchor_every matches the single-scan
    Sampler.run trajectory bit for bit."""
    grad, full_grad = _grad_fns(quad)
    delays = jnp.asarray(constant_delays(TAU, STEPS).delays)
    batches = _batches(STEPS, quad.d)

    def make():
        return samplers.svrg("consistent", grad, full_grad, anchor_every=16,
                             gamma=GAMMA, sigma=SIGMA, tau=TAU)

    s = make()
    st = s.init(jnp.zeros(quad.d), jax.random.PRNGKey(1))
    _, traj_ref = jax.jit(lambda st: s.run(st, batches, delays))(st)

    # chunk_size=7 never divides anchor_every=16: refreshes at steps 16,
    # 32, 48 land inside chunks 3, 5 and on the boundary of chunk 7
    s2 = make()
    engine = Engine(s2, chunk_size=7, collect_aux=False)
    st2 = s2.init(jnp.zeros(quad.d), jax.random.PRNGKey(1))
    fin, _ = engine.run(st2, steps=STEPS, batches=batches,
                        delays=np.asarray(delays))
    _, traj_chunked = jax.jit(lambda st: s2.run(st, batches, delays))(
        s2.init(jnp.zeros(quad.d), jax.random.PRNGKey(1)))

    np.testing.assert_array_equal(np.asarray(traj_ref),
                                  np.asarray(traj_chunked))
    # and the chunked engine's final params equal the scan's final params
    ref_fin, _ = jax.jit(lambda st: s.run(st, batches, delays))(
        s.init(jnp.zeros(quad.d), jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(np.asarray(ref_fin.params),
                                  np.asarray(fin.params))


def test_svrg_reduces_gradient_variance(quad):
    """With additive data noise, the control variate cancels the noise term
    exactly: the SVRG trajectory between refreshes equals noise-free SGLD's
    whenever the anchor is fresh enough that mu ~= g(x_anchor)."""
    noise_scale = 0.5
    grad, full_grad = _grad_fns(quad, noise_scale)
    batches = _batches(STEPS, quad.d)
    # anchor_every=1: refresh every step => corrected grad == full gradient
    s = samplers.svrg("sync", grad, full_grad, anchor_every=1,
                      gamma=GAMMA, sigma=SIGMA)
    st = s.init(jnp.zeros(quad.d), jax.random.PRNGKey(1))
    _, traj = jax.jit(lambda st: s.run(st, batches))(st)

    clean = samplers.sgld("sync", lambda p, b: quad.grad(p, None),
                          gamma=GAMMA, sigma=SIGMA)
    stc = clean.init(jnp.zeros(quad.d), jax.random.PRNGKey(1))
    _, traj_clean = jax.jit(lambda st: clean.run(st, batches))(stc)
    np.testing.assert_allclose(np.asarray(traj), np.asarray(traj_clean),
                               atol=1e-6)


def test_svrg_validates_anchor_every(quad):
    grad, full_grad = _grad_fns(quad)
    with pytest.raises(ValueError, match="anchor_every"):
        samplers.svrg_gradients(grad, full_grad, anchor_every=0)


# -- stale correction ---------------------------------------------------------

def test_stale_correction_noop_at_zero_staleness_bitwise(quad):
    """At staleness 0 every commit takes the uncorrected branch and the
    step shrink divides by exactly 1.0 — the corrected sampler must be
    bitwise-identical to the plain SGLD preset (the acceptance pin)."""
    grad = lambda p, b: quad.grad(p, None)  # noqa: E731
    batches = _batches(STEPS, quad.d)
    plain = samplers.sgld("sync", grad, gamma=GAMMA, sigma=SIGMA)
    corrected = samplers.sgld("sync", grad, gamma=GAMMA, sigma=SIGMA,
                              stale_strength=1.0, stale_gamma_scale=0.5)
    sp = plain.init(jnp.zeros(quad.d), jax.random.PRNGKey(2))
    sc = corrected.init(jnp.zeros(quad.d), jax.random.PRNGKey(2))
    _, tp = jax.jit(lambda s: plain.run(s, batches))(sp)
    _, tc = jax.jit(lambda s: corrected.run(s, batches))(sc)
    np.testing.assert_array_equal(np.asarray(tp), np.asarray(tc))


def test_stale_correction_noop_at_zero_delay_trace_bitwise(quad):
    """Same pin through the delayed-read path: a consistent-mode run whose
    realized delays are all zero must also match plain SGLD bitwise."""
    grad = lambda p, b: quad.grad(p, None)  # noqa: E731
    batches = _batches(STEPS, quad.d)
    zero_delays = jnp.zeros(STEPS, jnp.int32)
    plain = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA, tau=2)
    corrected = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA,
                              tau=2, stale_strength=1.0,
                              stale_gamma_scale=0.5)
    sp = plain.init(jnp.zeros(quad.d), jax.random.PRNGKey(2))
    sc = corrected.init(jnp.zeros(quad.d), jax.random.PRNGKey(2))
    _, tp = jax.jit(lambda s: plain.run(s, batches, zero_delays))(sp)
    _, tc = jax.jit(lambda s: corrected.run(s, batches, zero_delays))(sc)
    np.testing.assert_array_equal(np.asarray(tp), np.asarray(tc))


def test_stale_correction_changes_stale_commits(quad):
    grad = lambda p, b: quad.grad(p, None)  # noqa: E731
    batches = _batches(STEPS, quad.d)
    delays = jnp.asarray(constant_delays(TAU, STEPS).delays)
    plain = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA,
                          tau=TAU)
    corrected = samplers.sgld("consistent", grad, gamma=GAMMA, sigma=SIGMA,
                              tau=TAU, stale_strength=1.0)
    x0 = jnp.ones(quad.d)
    sp = plain.init(x0, jax.random.PRNGKey(2))
    sc = corrected.init(x0, jax.random.PRNGKey(2))
    _, tp = jax.jit(lambda s: plain.run(s, batches, delays))(sp)
    _, tc = jax.jit(lambda s: corrected.run(s, batches, delays))(sc)
    assert not np.array_equal(np.asarray(tp), np.asarray(tc))


def test_stale_correction_requires_gradients():
    s = samplers.Sampler(
        transform=samplers.chain(samplers.stale_correction()), gamma=GAMMA)
    st = s.init(jnp.zeros(2), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="gradients"):
        s.step(st, jnp.zeros((1,)))


# -- SGHMC --------------------------------------------------------------------

def test_sghmc_momentum_survives_checkpoint_roundtrip(quad, tmp_path):
    """Splitting an SGHMC run at an arbitrary step through a save/restore
    of the full sampler state (momentum included) must reproduce the
    uninterrupted trajectory bitwise."""
    grad = lambda p, b: quad.grad(p, None)  # noqa: E731
    batches = _batches(STEPS, quad.d)
    delays = jnp.asarray(constant_delays(TAU, STEPS).delays)
    s = samplers.sghmc("consistent", grad, gamma=GAMMA, sigma=SIGMA,
                       friction=2.0, tau=TAU)
    st = s.init(jnp.ones(quad.d), jax.random.PRNGKey(3))
    _, traj_ref = jax.jit(lambda st: s.run(st, batches, delays))(st)

    cut = 23  # not chunk- or anything-aligned
    st2 = s.init(jnp.ones(quad.d), jax.random.PRNGKey(3))
    mid, traj_a = jax.jit(lambda st: s.run(st, batches[:cut], delays[:cut]))(
        st2)
    path = str(tmp_path / "sghmc_state")
    save_checkpoint(path, mid, step=cut)
    restored = restore_checkpoint(path, like=mid)
    # the momentum buffer is inside state.inner; a lossy round-trip would
    # show up as a trajectory split brighter than float exactness
    fin, traj_b = jax.jit(lambda st: s.run(st, batches[cut:], delays[cut:]))(
        restored)
    stitched = np.concatenate([np.asarray(traj_a), np.asarray(traj_b)])
    np.testing.assert_array_equal(stitched, np.asarray(traj_ref))


def test_sghmc_momentum_state_shape(quad):
    grad = lambda p, b: quad.grad(p, None)  # noqa: E731
    s = samplers.sghmc("sync", grad, gamma=GAMMA, sigma=SIGMA)
    st = s.init(jnp.zeros(quad.d), jax.random.PRNGKey(0))
    # chain state is a tuple of member states; the momentum leaf is the
    # params-shaped buffer of the final (sghmc_update) member
    momentum = st.inner[-1]
    assert momentum.shape == (quad.d,)
    np.testing.assert_array_equal(np.asarray(momentum), 0.0)


def test_sghmc_preconditioner_scales_updates(quad):
    """A scalar preconditioner rescales the gradient drift; P=1 is the
    identity and P=0.25 moves less far down the potential per step."""
    grad = lambda p, b: quad.grad(p, None)  # noqa: E731
    batches = _batches(STEPS, quad.d)
    x0 = 3.0 * jnp.ones(quad.d)

    def final_dist(precond):
        s = samplers.sghmc("sync", grad, gamma=GAMMA, sigma=0.0,
                           friction=2.0, precond=precond)
        st = s.init(x0, jax.random.PRNGKey(4))
        fin, _ = jax.jit(lambda st: s.run(st, batches))(st)
        return float(jnp.linalg.norm(fin.params - quad.x_star))

    assert final_dist(0.25) > final_dist(1.0)


def test_sghmc_validates_friction(quad):
    with pytest.raises(ValueError, match="friction"):
        samplers.sghmc_update(SIGMA, friction=0.0)


# -- AR(1) stream -------------------------------------------------------------

def test_ar1_stream_reproducible_from_seed():
    k = jax.random.PRNGKey(11)
    a = ar1_stream(k, steps=50, batch=4, d=3, rho=0.8)
    b = ar1_stream(k, steps=50, batch=4, d=3, rho=0.8)
    assert a.shape == (50, 4, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = ar1_stream(jax.random.PRNGKey(12), steps=50, batch=4, d=3, rho=0.8)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_ar1_stream_dependence_and_marginal():
    x = np.asarray(ar1_stream(jax.random.PRNGKey(0), steps=4000, batch=2,
                              d=1, rho=0.9, mean=1.0, scale=2.0))
    flat = x.reshape(4000, -1)
    # stationary marginal keeps (mean, scale) regardless of rho
    assert abs(flat.mean() - 1.0) < 0.25
    assert abs(flat.std() - 2.0) < 0.25
    corr = np.corrcoef(flat[:-1, 0], flat[1:, 0])[0, 1]
    assert 0.8 < corr < 0.97


def test_ar1_stream_rho_zero_is_iid_marginal():
    x = np.asarray(ar1_stream(jax.random.PRNGKey(0), steps=2000, batch=2,
                              d=1, rho=0.0))
    flat = x.reshape(2000, -1)
    corr = np.corrcoef(flat[:-1, 0], flat[1:, 0])[0, 1]
    assert abs(corr) < 0.1


def test_ar1_stream_validates_args():
    with pytest.raises(ValueError, match="rho"):
        ar1_stream(jax.random.PRNGKey(0), steps=4, batch=2, d=1, rho=1.0)
    with pytest.raises(ValueError, match="steps"):
        ar1_stream(jax.random.PRNGKey(0), steps=0, batch=2, d=1)

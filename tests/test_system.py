"""End-to-end behaviour: the paper's experiments at test scale.

These are the system-level assertions behind EXPERIMENTS.md §Repro-*:
(1) SGLD (all read models) samples the correct regression posterior,
(2) async modes tolerate realistic simulated delays,
(3) RICA objective decreases under SGLD,
(4) the theory-prescribed (gamma_eps, n_eps) reaches the epsilon ball.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PolyRegression,
    ProblemConstants,
    Quadratic,
    RICA,
    SGLDConfig,
    SGLDSampler,
    WorkerModel,
    gamma_eps_w2,
    simulate_async,
)
from repro.metrics import w2_to_gaussian


@pytest.fixture(scope="module")
def reg():
    return PolyRegression.make(jax.random.PRNGKey(0), nu_std=0.1)


def _run_regression(reg, mode, tau, steps=8000, sigma=1e-3, batch=256,
                    seed=0):
    gamma = 2e-4
    cfg = SGLDConfig(mode=mode, gamma=gamma, sigma=sigma,
                     tau=tau if mode in ("consistent", "inconsistent") else 0)

    def grad(p, key):
        batch_data = reg.sample_batch(key, batch)
        return jax.grad(reg.value)(p, batch_data)

    sampler = SGLDSampler(cfg, grad)
    mu, cov, _ = reg.posterior_moments(sigma=sigma)
    state = sampler.init(mu + 0.5, jax.random.PRNGKey(seed))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), steps)
    if mode in ("consistent", "inconsistent"):
        trace = simulate_async(WorkerModel(num_workers=8, seed=seed), steps,
                               seed=seed)
        delays = jnp.asarray(np.minimum(trace.delays, tau))
    else:
        delays = jnp.zeros((steps,), jnp.int32)
    state, traj = jax.jit(lambda s: sampler.run(s, keys, delays))(state)
    return np.asarray(traj), mu, cov


@pytest.mark.slow
@pytest.mark.parametrize("mode,tau", [("sync", 0), ("consistent", 8),
                                      ("inconsistent", 8), ("pipeline", 0)])
def test_regression_posterior_all_modes(reg, mode, tau):
    """Paper §3.2: every read model reaches a small W2 to the posterior."""
    traj, mu, cov = _run_regression(reg, mode, tau)
    w2 = float(w2_to_gaussian(jnp.asarray(traj[3000:]), mu, cov))
    w2_start = float(np.linalg.norm(traj[0] - np.asarray(mu)))
    assert w2 < 0.25 * w2_start, (mode, w2, w2_start)


@pytest.mark.slow
def test_async_matches_sync_convergence(reg):
    """Paper's headline: async convergence-per-iteration ~ sync."""
    t_sync, mu, cov = _run_regression(reg, "sync", 0)
    t_async, _, _ = _run_regression(reg, "consistent", 8)
    w_sync = float(w2_to_gaussian(jnp.asarray(t_sync[4000:]), mu, cov))
    w_async = float(w2_to_gaussian(jnp.asarray(t_async[4000:]), mu, cov))
    assert w_async < 3.0 * w_sync + 0.05, (w_sync, w_async)


@pytest.mark.slow
def test_rica_objective_decreases():
    """Paper §3.3: SGLD on RICA drives the (non-convex) objective down."""
    rica = RICA(patch_dim=64, num_features=32)
    w0 = rica.init_params(jax.random.PRNGKey(0))
    cfg = SGLDConfig(mode="consistent", gamma=2e-3, sigma=1e-6, tau=4)

    def grad(p, key):
        return rica.grad(p, rica.sample_batch(key, 256))

    sampler = SGLDSampler(cfg, grad)
    state = sampler.init(w0, jax.random.PRNGKey(1))
    keys = jax.random.split(jax.random.PRNGKey(2), 400)
    from repro.core import constant_delays
    delays = jnp.asarray(constant_delays(4, 400).delays)
    state, _ = jax.jit(lambda s: sampler.run(s, keys, delays,
                                             collect=False))(state)
    key_eval = jax.random.PRNGKey(3)
    before = float(rica.value(w0, rica.sample_batch(key_eval, 512)))
    after = float(rica.value(state.params, rica.sample_batch(key_eval, 512)))
    assert after < 0.8 * before, (before, after)


@pytest.mark.slow
def test_theory_prescription_reaches_epsilon():
    """Corollary 2.1 W2 variant at small scale: running at (gamma_eps, n_eps)
    lands inside the epsilon ball (constants are conservative)."""
    quad = Quadratic.make(jax.random.PRNGKey(1), d=2, m=1.0, L=2.0)
    eps = 0.25
    sigma = 0.1
    tau = 3
    c = ProblemConstants(m=quad.m, L=quad.L, d=2, G=4.0, sigma=sigma, tau=tau,
                         w2sq_0=float(jnp.sum(quad.x_star**2)))
    gamma = gamma_eps_w2(c, eps)
    n = min(60_000, 2 * int(np.ceil(np.log(4 * c.w2sq_0 / eps) / (gamma * c.m))))
    cfg = SGLDConfig(mode="consistent", gamma=float(gamma), sigma=sigma,
                     tau=tau)
    sampler = SGLDSampler(cfg, lambda p, b: quad.grad(p, b))
    from repro.core import constant_delays
    delays = jnp.asarray(constant_delays(tau, n).delays)
    batches = jnp.zeros((n, 1))

    # the W2 bound is on the LAW of X_n: estimate from independent chains
    def chain(key):
        st = sampler.init(jnp.zeros(2), key)
        st, _ = sampler.run(st, batches, delays, collect=False)
        return st.params

    finals = jax.jit(jax.vmap(chain))(
        jax.random.split(jax.random.PRNGKey(2), 128))
    w2 = float(w2_to_gaussian(finals, quad.x_star,
                              jnp.diag(quad.stationary_cov(sigma))))
    assert w2**2 < eps, (w2**2, eps, gamma, n)
